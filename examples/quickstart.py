"""Quickstart: one serving front door — resident, HeteGen-offloaded,
streaming, and the event-loop AsyncLLM, all through
:mod:`repro.serving.api`.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.hw import PAPER_A10
from repro.models import model as M
from repro.serving.api import AsyncLLM, LLM
from repro.serving.backends import HeteGenBackend
from repro.serving.sampling import SamplingParams


def main():
    cfg = get_config("opt-125m")
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.0f}M params)")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, 16)) for _ in range(2)]

    print("\n-- resident (all weights on device) --")
    with LLM(cfg, params) as llm:
        outs = llm.generate(prompts, max_new=12)
        print("tokens:", outs[0].tokens[:8], "…")
        print(f"executor={llm.last_executor}, "
              f"{llm.stats()['tokens_per_s']:.1f} tok/s decode")

        print("\n-- streaming (tokens delivered as they decode) --")
        line = []
        for tok in llm.stream(prompts[0], max_new=8,
                              sampling=SamplingParams(kind="topp",
                                                      top_p=0.9, seed=7)):
            line.append(tok)
            print(f"  got {tok}", flush=True)
        print("streamed:", line)

        print("\n-- logprobs (recorded straight out of the sampler) --")
        rid = llm.submit(prompts[0], max_new=3,
                         sampling=SamplingParams(logprobs=2))
        out = llm.drain()[rid]
        for e in out.logprobs:
            alts = ", ".join(f"{t}:{lp:.2f}" for t, lp in e["top"].items())
            print(f"  token {e['token']} logprob={e['logprob']:.3f} "
                  f"(top: {alts})")

    print("\n-- AsyncLLM (event loop owns the step() crank) --")
    with AsyncLLM(cfg, params, policy="priority") as allm:
        handle = allm.submit(prompts[1], max_new=8)      # runs in background
        line = list(allm.stream(prompts[0], max_new=8))  # no step() anywhere
        print("streamed async:", line)
        print("background request:", handle.result().tokens)

    print("\n-- HeteGen offload (weights in host memory, alpha-split) --")
    backend = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0)
    with LLM(cfg, backend=backend, own_backend=True) as off:
        res = off.generate(prompts, max_new=12)
        st = off.stats()
        print("tokens:", res[0].tokens[:8], "…")
        print("phase plans (compute-bound prefill vs link-bound decode):")
        for ph, a in sorted(st["phase_alpha"].items()):
            print(f"  {ph}: alpha={a:.3f}")
        print("outputs match resident:",
              [o.tokens for o in res] == [o.tokens for o in outs])
        s = st["stream"]
        print(f"stream busy (s): cpu={s.cpu:.3f} pin={s.pin:.3f} "
              f"trans={s.trans:.3f} dev={s.dev:.3f}")


if __name__ == "__main__":
    main()
