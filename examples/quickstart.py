"""Quickstart: generate with a tiny LM, resident vs HeteGen-offloaded.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.hw import PAPER_A10
from repro.models import model as M
from repro.serving.engine import Generator
from repro.serving.offload_runtime import OffloadGenerator


def main():
    cfg = get_config("opt-125m")
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.0f}M params)")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16)).astype(np.int32)

    print("\n-- resident (all weights on device) --")
    gen = Generator(cfg, params)
    r = gen.generate({"tokens": jnp.asarray(prompt)}, 12)
    print("tokens:", r.tokens[0][:8], "…")
    print(f"decode: {r.tokens_per_s:.1f} tok/s")

    print("\n-- HeteGen offload (weights in host memory, alpha-split) --")
    off = OffloadGenerator(cfg, params, hw=PAPER_A10, budget_bytes=0)
    res = off.generate(prompt, 12)
    print("tokens:", res["tokens"].tolist()[0][:8], "…")
    print(f"alpha = {res['alpha']:.3f}; outputs match: "
          f"{res['tokens'].tolist() == r.tokens}")
    st = res["stream_stats"]
    print(f"stream busy (s): cpu={st.cpu:.3f} pin={st.pin:.3f} "
          f"trans={st.trans:.3f} dev={st.dev:.3f}")
    off.close()


if __name__ == "__main__":
    main()
