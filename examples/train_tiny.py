"""Train a small LM on the synthetic corpus with checkpoint/restart.

    PYTHONPATH=src python examples/train_tiny.py --steps 120
(~100M-param config available via --arch opt-125m --steps 300 given time.)
"""
import argparse
import tempfile

import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import make_training_data
from repro.train.loop import TrainConfig, Trainer
from repro.train.optimizer import OptimizerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    tcfg = TrainConfig(accum_steps=2,
                       optimizer=OptimizerConfig(lr=3e-3),
                       warmup=20, total_steps=args.steps)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    print(f"model {cfg.name} ({cfg.param_count()/1e6:.1f}M) "
          f"-> checkpoints in {ckpt}")

    data = make_training_data(cfg, batch=args.batch, seq=args.seq)
    batches = ({"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])} for b in data)
    tr = Trainer(cfg, tcfg, checkpoint_dir=ckpt, checkpoint_every=25)
    last = tr.run(batches, args.steps)
    first = tr.metrics_log[0]["loss"]
    print(f"loss {first:.3f} -> {last['loss']:.3f} "
          f"(uniform = {jnp.log(cfg.vocab_size):.3f}) "
          f"in {tr.step} steps; stragglers: "
          f"{tr.straggler.fleet_summary().get('stragglers', 0)}")
    assert last["loss"] < first


if __name__ == "__main__":
    main()
