"""Run the alpha benchmark + module scheduler against THIS host's measured
CPU/staging speeds (paper §4.4-4.5 end to end).

    PYTHONPATH=src python examples/alpha_tuning.py
"""
from repro.configs import get_config
from repro.core.alpha import alpha_analytic
from repro.core.alpha_benchmark import calibrated_speeds, refine_alpha
from repro.core.hw import TPU_V5E
from repro.core.policy import build_policy
from repro.serving.offload_runtime import enumerate_linears


def main():
    print("calibrating this host (matmul + staging copy)...")
    sp = calibrated_speeds(4096, 4096)
    for k, v in sp.items():
        print(f"  {k}: {v/1e9:.2f} GB/s")
    a0 = alpha_analytic(sp["v_cpu"], sp["v_gpu"], sp["v_com"])
    print(f"analytic prior alpha0 = {a0:.4f}")

    nbytes = 4096 * 4096 * 4
    fit = refine_alpha(
        lambda a: (1 - a) * nbytes / sp["v_cpu"],
        lambda a: max(a * nbytes / sp["v_pin"], a * nbytes / sp["v_com"]),
        a0)
    print(f"refined alpha = {fit.alpha:.4f} "
          f"(predicted module time {fit.predicted_time*1e3:.2f} ms)")

    cfg = get_config("opt-6.7b")
    linears = enumerate_linears(cfg)
    for frac, label in ((0.0, "fully offloaded"), (0.5, "half budget"),
                        (1.0, "full budget")):
        budget = frac * sum(s.nbytes for s in linears)
        pol = build_policy(linears, TPU_V5E, budget_bytes=budget)
        n_res = sum(1 for p in pol.plan if p.mode == "resident")
        print(f"{label:16s}: alpha={pol.alpha:.3f} resident={n_res}/"
              f"{len(pol.plan)} modules, predicted step "
              f"{pol.predicted_step_time*1e3:.1f} ms")


if __name__ == "__main__":
    main()
