"""End-to-end serving driver (the paper's deployment): batched requests
through the continuous batcher — over resident weights AND over
HeteGen-offloaded weights — plus batch-aware offloaded generation.

    PYTHONPATH=src python examples/serve_offload.py [--requests 8]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.hw import PAPER_A10
from repro.models import model as M
from repro.serving.backends import HeteGenBackend
from repro.serving.batcher import ContinuousBatcher
from repro.serving.offload_runtime import OffloadGenerator


def drive(b: ContinuousBatcher, cfg, rng, n_requests: int):
    """Submit staggered requests and run the batcher dry."""
    t0 = time.perf_counter()
    steps = 0
    for _ in range(n_requests):
        n = int(rng.integers(4, 16))
        b.submit(list(rng.integers(0, cfg.vocab_size, n)),
                 max_new=int(rng.integers(8, 24)))
        b.step(); steps += 1          # requests join mid-flight
    while b.queue or b.active.any():
        b.step(); steps += 1
    dt = time.perf_counter() - t0
    done = [r for r in b.requests.values() if r.done]
    toks = sum(len(r.generated) for r in done)
    print(f"completed {len(done)} requests, {toks} tokens, "
          f"{steps} engine steps in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s aggregate)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    print(f"== continuous batching (resident): {args.requests} staggered "
          "requests ==")
    b = ContinuousBatcher(cfg, params, max_slots=args.slots, max_len=128)
    drive(b, cfg, rng, args.requests)

    print("\n== continuous batching over HeteGen-offloaded weights ==")
    backend = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                             batch=args.slots)
    print(f"plan tuned for batch={backend.policy.batch}: "
          f"alpha={backend.policy.alpha:.3f}")
    rng = np.random.default_rng(0)      # same request stream
    ob = ContinuousBatcher(cfg, backend=backend, max_slots=args.slots,
                           max_len=128)
    drive(ob, cfg, rng, args.requests)
    backend.close()

    print("\n== HeteGen batched generation (weights in host memory) ==")
    for batch in (1, 4):
        off = OffloadGenerator(cfg, params, hw=PAPER_A10, budget_bytes=0,
                               batch=batch)
        prompt = rng.integers(0, cfg.vocab_size, (batch, 12)).astype(np.int32)
        res = off.generate(prompt, 16)
        print(f"batch={batch}: alpha={res['alpha']:.3f} "
              f"resident={res['resident_bytes']/1e6:.1f}MB "
              f"pinned-ring={res['pinned_overhead_bytes']/1e6:.1f}MB "
              f"{res['tokens_per_s']:.1f} tok/s "
              "(CPU-only container; see benchmarks/fig8 for the A10 model)")
        off.close()


if __name__ == "__main__":
    main()
