"""End-to-end serving driver (the paper's deployment): staggered
requests with per-request sampling through the one front door
(:class:`repro.serving.api.LLM`) — over resident weights AND over
HeteGen-offloaded weights with phase-aware placement plans.

    PYTHONPATH=src python examples/serve_offload.py [--requests 8]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.hw import PAPER_A10
from repro.models import model as M
from repro.serving.api import LLM
from repro.serving.backends import HeteGenBackend
from repro.serving.sampling import SamplingParams

SAMPLERS = [SamplingParams(),                                   # greedy
            SamplingParams(kind="topp", top_p=0.9, seed=1),
            SamplingParams(kind="topk", top_k=40,
                           temperature=0.8, seed=2),
            SamplingParams(kind="temperature", temperature=1.2, seed=3)]


def drive(llm: LLM, cfg, rng, n_requests: int):
    """Submit staggered mixed-sampler requests and run the facade dry."""
    t0 = time.perf_counter()
    for i in range(n_requests):
        n = int(rng.integers(4, 16))
        llm.submit(list(rng.integers(0, cfg.vocab_size, n)),
                   max_new=int(rng.integers(8, 24)),
                   sampling=SAMPLERS[i % len(SAMPLERS)])
        llm.step()                    # requests join mid-flight
    outs = llm.drain()
    dt = time.perf_counter() - t0
    toks = sum(len(o.tokens) for o in outs.values())
    print(f"completed {len(outs)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s aggregate, mixed samplers per batch)")
    return outs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    print(f"== continuous batching (resident): {args.requests} staggered "
          "requests ==")
    with LLM(cfg, params, max_slots=args.slots, max_len=128) as llm:
        res_outs = drive(llm, cfg, np.random.default_rng(0), args.requests)

    print("\n== continuous batching over HeteGen-offloaded weights ==")
    backend = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                             batch=args.slots)
    with LLM(cfg, backend=backend, own_backend=True, max_slots=args.slots,
             max_len=128) as off:
        off_outs = drive(off, cfg, np.random.default_rng(0), args.requests)
        st = off.stats()
        print("phase plans: " + "  ".join(
            f"{ph}: alpha={a:.3f} (batch={st['phase_batch'][ph][0]}, "
            f"tokens/seq={st['phase_batch'][ph][1]})"
            for ph, a in sorted(st["phase_alpha"].items())))
    same = all(res_outs[r].tokens == off_outs[r].tokens for r in res_outs)
    print(f"offloaded == resident token-for-token (per-request PRNG "
          f"streams): {same}")

    print("\n== one-shot offloaded generation (requests arrive together) ==")
    rng = np.random.default_rng(1)
    for batch in (1, 4):
        backend = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                                 batch=batch)
        with LLM(cfg, backend=backend, own_backend=True) as one:
            prompts = [list(rng.integers(0, cfg.vocab_size, 12))
                       for _ in range(batch)]
            one.generate(prompts, max_new=16)
            st = one.stats()
            al = st["phase_alpha"]
            print(f"batch={batch}: executor={st['executor']} "
                  f"decode-alpha={al['decode']:.3f} "
                  f"prefill-alpha={al['prefill']:.3f} "
                  f"resident={st['resident_bytes']/1e6:.1f}MB "
                  f"{st['tokens_per_s']:.1f} tok/s "
                  "(CPU-only container; see benchmarks/fig8 for the A10 "
                  "model)")


if __name__ == "__main__":
    main()
