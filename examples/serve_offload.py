"""End-to-end serving driver (the paper's deployment): staggered
requests with per-request sampling through the one front door
(:class:`repro.serving.api.LLM`) — over resident weights AND over
HeteGen-offloaded weights with phase-aware placement plans, plus the
scheduler seam under pressure: a page-tight pool where the ``priority``
policy preempts (host-swap resume) and the event-loop
:class:`repro.serving.api.AsyncLLM` drives everything with no manual
``step()``.

    PYTHONPATH=src python examples/serve_offload.py [--requests 8]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.hw import PAPER_A10
from repro.models import model as M
from repro.serving.api import AsyncLLM, LLM
from repro.serving.backends import HeteGenBackend
from repro.serving.sampling import SamplingParams

SAMPLERS = [SamplingParams(),                                   # greedy
            SamplingParams(kind="topp", top_p=0.9, seed=1),
            SamplingParams(kind="topk", top_k=40,
                           temperature=0.8, seed=2),
            SamplingParams(kind="temperature", temperature=1.2, seed=3)]


def drive(llm: LLM, cfg, rng, n_requests: int):
    """Submit staggered mixed-sampler requests and run the facade dry."""
    t0 = time.perf_counter()
    for i in range(n_requests):
        n = int(rng.integers(4, 16))
        llm.submit(list(rng.integers(0, cfg.vocab_size, n)),
                   max_new=int(rng.integers(8, 24)),
                   sampling=SAMPLERS[i % len(SAMPLERS)])
        llm.step()                    # requests join mid-flight
    outs = llm.drain()
    dt = time.perf_counter() - t0
    toks = sum(len(o.tokens) for o in outs.values())
    print(f"completed {len(outs)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s aggregate, mixed samplers per batch)")
    return outs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    print(f"== continuous batching (resident): {args.requests} staggered "
          "requests ==")
    with LLM(cfg, params, max_slots=args.slots, max_len=128) as llm:
        res_outs = drive(llm, cfg, np.random.default_rng(0), args.requests)

    print("\n== continuous batching over HeteGen-offloaded weights ==")
    backend = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                             batch=args.slots)
    with LLM(cfg, backend=backend, own_backend=True, max_slots=args.slots,
             max_len=128) as off:
        off_outs = drive(off, cfg, np.random.default_rng(0), args.requests)
        st = off.stats()
        print("phase plans: " + "  ".join(
            f"{ph}: alpha={a:.3f} (batch={st['phase_batch'][ph][0]}, "
            f"tokens/seq={st['phase_batch'][ph][1]})"
            for ph, a in sorted(st["phase_alpha"].items())))
    same = all(res_outs[r].tokens == off_outs[r].tokens for r in res_outs)
    print(f"offloaded == resident token-for-token (per-request PRNG "
          f"streams): {same}")

    print("\n== scheduler under page pressure (priority policy) ==")
    # a pool ~half the worst case: optimistic paging admits every tenant,
    # the late high-priority arrival evicts one (host-swap resume), and
    # the victim still finishes token-exactly
    rng = np.random.default_rng(2)
    with LLM(cfg, params, max_slots=2, max_len=96, paged=True,
             page_size=16, n_pages=7, policy="priority") as sched_llm:
        low = [sched_llm.submit(list(rng.integers(0, cfg.vocab_size, 12)),
                                max_new=24) for _ in range(2)]
        for _ in range(4):
            sched_llm.step()           # tenants take their pages
        hi = sched_llm.submit(list(rng.integers(0, cfg.vocab_size, 20)),
                              max_new=8, priority=5)
        budgets = {low[0]: 24, low[1]: 24, hi: 8}
        done_order = []
        while len(done_order) < len(budgets):
            sched_llm.step()
            done_order += [
                r for r, n in budgets.items() if r not in done_order
                and len(sched_llm.result(r).tokens) >= n]
        sched_llm.drain()
        sc = sched_llm.stats()["scheduler"]
        print(f"finish order {done_order} (high-priority rid {hi} jumped "
              f"{len(low)} tenants); preemptions={sc['preemptions']}")

    print("\n== AsyncLLM: the event loop owns step() ==")
    with AsyncLLM(cfg, params, max_slots=args.slots, max_len=96,
                  policy="fair_share") as allm:
        handles = [allm.submit(list(rng.integers(0, cfg.vocab_size, 12)),
                               max_new=16) for _ in range(args.requests)]
        toks = sum(len(h.result().tokens) for h in handles)
        st = allm.stats()
        print(f"{len(handles)} requests, {toks} tokens via "
              f"{st['executor']} with no caller-driven step(): "
              f"{st['tokens_per_s']:.1f} tok/s")

    print("\n== one-shot offloaded generation (requests arrive together) ==")
    rng = np.random.default_rng(1)
    for batch in (1, 4):
        backend = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                                 batch=batch)
        with LLM(cfg, backend=backend, own_backend=True) as one:
            prompts = [list(rng.integers(0, cfg.vocab_size, 12))
                       for _ in range(batch)]
            one.generate(prompts, max_new=16)
            st = one.stats()
            al = st["phase_alpha"]
            print(f"batch={batch}: executor={st['executor']} "
                  f"decode-alpha={al['decode']:.3f} "
                  f"prefill-alpha={al['prefill']:.3f} "
                  f"resident={st['resident_bytes']/1e6:.1f}MB "
                  f"{st['tokens_per_s']:.1f} tok/s "
                  "(CPU-only container; see benchmarks/fig8 for the A10 "
                  "model)")


if __name__ == "__main__":
    main()
