"""Mamba2 SSD equivalences: recurrent == chunked == decode steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (mamba_block, ssd_chunked, ssd_decode_step,
                              ssd_recurrent)


def _inputs(rng, b=2, l=32, h=3, p=8, n=4, g=1):
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.standard_normal((b, l, h)), jnp.float32))
    a = -jnp.abs(jnp.asarray(rng.standard_normal((h,)), jnp.float32))
    bm = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((h,)), jnp.float32)
    return x, dt, a, bm, cm, d


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunked_equals_recurrent(rng, chunk):
    x, dt, a, bm, cm, d = _inputs(rng)
    y_r, h_r = ssd_recurrent(x, dt, a, bm, cm, d)
    y_c, h_c = ssd_chunked(x, dt, a, bm, cm, d, chunk=chunk)
    np.testing.assert_allclose(y_c, y_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_c, h_r, rtol=2e-4, atol=2e-4)


def test_decode_steps_equal_recurrent(rng):
    x, dt, a, bm, cm, d = _inputs(rng, l=16)
    y_r, h_r = ssd_recurrent(x, dt, a, bm, cm, d)
    h = jnp.zeros((2, 3, 8, 4), jnp.float32)
    ys = []
    for t in range(16):
        rep = 3 // bm.shape[2]
        h, yt = ssd_decode_step(h, x[:, t], dt[:, t], a, bm[:, t], cm[:, t],
                                d)
        ys.append(yt)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_step, y_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h, h_r, rtol=2e-4, atol=2e-4)


def test_state_carry_across_segments(rng):
    """Processing [0:16] then [16:32] with carried state == full pass."""
    x, dt, a, bm, cm, d = _inputs(rng, l=32)
    y_full, h_full = ssd_recurrent(x, dt, a, bm, cm, d)
    y1, h1 = ssd_chunked(x[:, :16], dt[:, :16], a, bm[:, :16], cm[:, :16],
                         d, chunk=8)
    y2, h2 = ssd_chunked(x[:, 16:], dt[:, 16:], a, bm[:, 16:], cm[:, 16:],
                         d, chunk=8, h0=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h2, h_full, rtol=2e-4, atol=2e-4)


def test_mamba_block_prefill_then_decode(rng):
    """Block-level: prefill S tokens then decode 4 == full S+4 pass."""
    from repro.configs import get_config, reduced
    from repro.models.model import init_params
    cfg = reduced(get_config("mamba2-2.7b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    blk = jax.tree.map(lambda x: x[0][0], params["blocks"])
    x = jnp.asarray(rng.standard_normal((2, 20, cfg.d_model)), jnp.float32)
    y_full, _, _ = mamba_block(cfg, blk, x, chunked=False)
    y1, s1, c1 = mamba_block(cfg, blk, x[:, :16], chunked=False)
    ys = [y1]
    s, c = s1, c1
    for t in range(16, 20):
        yt, s, c = mamba_block(cfg, blk, x[:, t:t + 1], ssm_state=s,
                               conv_state=c)
        ys.append(yt)
    y_inc = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_inc, y_full, rtol=2e-4, atol=2e-4)
