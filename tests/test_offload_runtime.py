"""The paper's runtime: offloaded generation == resident generation, and
scheduling behaves per the hardware model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.hw import PAPER_A10
from repro.models import model as M
from repro.serving.engine import Generator
from repro.serving.offload_runtime import OffloadGenerator, enumerate_linears


@pytest.fixture(scope="module")
def opt_setup():
    cfg = reduced(get_config("opt-6.7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


@pytest.mark.parametrize("budget", [0, 200_000, None])
def test_offload_matches_resident(opt_setup, rng, budget):
    cfg, params = opt_setup
    prompt = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    ref = Generator(cfg, params).generate(
        {"tokens": jnp.asarray(prompt)}, 6)
    off = OffloadGenerator(cfg, params, hw=PAPER_A10, budget_bytes=budget)
    res = off.generate(prompt, 6)
    assert res["tokens"].tolist() == ref.tokens
    off.close()


def test_alpha_override_still_exact(opt_setup, rng):
    cfg, params = opt_setup
    prompt = rng.integers(0, cfg.vocab_size, (1, 6)).astype(np.int32)
    ref = Generator(cfg, params).generate(
        {"tokens": jnp.asarray(prompt)}, 4)
    for alpha in (0.0, 0.3, 1.0):
        off = OffloadGenerator(cfg, params, hw=PAPER_A10,
                               budget_bytes=0, alpha_override=alpha)
        res = off.generate(prompt, 4)
        assert res["tokens"].tolist() == ref.tokens, alpha
        off.close()


def test_scheduler_promotes_under_budget(opt_setup):
    cfg, params = opt_setup
    linears = enumerate_linears(cfg)
    total = sum(s.nbytes for s in linears)
    off = OffloadGenerator(cfg, params, hw=PAPER_A10, budget_bytes=total * 2)
    # ample budget: everything resident
    assert all(p.mode == "resident" for p in off.policy.plan)
    off.close()
    off0 = OffloadGenerator(cfg, params, hw=PAPER_A10, budget_bytes=0)
    assert all(p.mode == "hetegen" for p in off0.policy.plan)
    assert 0.0 < off0.policy.alpha < 1.0
    off0.close()


def test_gqa_model_supported(rng):
    cfg = reduced(get_config("mistral-nemo-12b"))
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    prompt = rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)
    ref = Generator(cfg, params).generate({"tokens": jnp.asarray(prompt)}, 4)
    off = OffloadGenerator(cfg, params, hw=PAPER_A10, budget_bytes=0)
    res = off.generate(prompt, 4)
    assert res["tokens"].tolist() == ref.tokens
    off.close()


def test_unsupported_family_raises():
    cfg = reduced(get_config("mamba2-2.7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        OffloadGenerator(cfg, params)
