"""Async parameter manager: staging order, ring bounds, no deadlock."""
import numpy as np
import pytest

from repro.core.param_manager import AsyncParamManager, plan_prefetch_order


def _mk(names, shape=(64, 64)):
    rng = np.random.default_rng(0)
    return {n: rng.standard_normal(shape).astype(np.float32) for n in names}


def test_acquire_returns_exact_weights():
    w = _mk(["a", "b", "c", "d"])
    mgr = AsyncParamManager(w, {n: "g" for n in w})
    for n in ["a", "b", "c", "d", "a", "c"]:   # includes out-of-order reuse
        got = mgr.acquire(n)
        np.testing.assert_array_equal(got, w[n])
        mgr.release(n)
    mgr.shutdown()


def test_prefetch_overlap_order():
    w = _mk([f"m{i}" for i in range(6)])
    groups = {n: "g" for n in w}
    mgr = AsyncParamManager(w, groups)
    order = list(w)
    nxt = plan_prefetch_order(order, groups)
    mgr.prefetch(order[0])
    for n in order:
        if nxt[n]:
            mgr.prefetch(nxt[n])
        got = mgr.acquire(n)
        np.testing.assert_array_equal(got, w[n])
        mgr.release(n)
    ops = [e[0] for e in mgr.events]
    # at least one pin started before the previous acquire completed
    assert "pin_start" in ops
    mgr.shutdown()


def test_ring_bound_two_slots_per_group():
    w = _mk([f"m{i}" for i in range(8)])
    mgr = AsyncParamManager(w, {n: ("attn" if i % 2 else "mlp")
                                for i, n in enumerate(w)})
    per_slot = 64 * 64 * 4
    assert mgr.pinned_overhead_bytes() == 2 * 2 * per_slot
    mgr.shutdown()


def test_groups_isolated():
    w = _mk(["a1", "a2", "m1", "m2"], shape=(32, 32))
    mgr = AsyncParamManager(w, {"a1": "attn", "a2": "attn",
                                "m1": "mlp", "m2": "mlp"})
    mgr.prefetch("a1"); mgr.prefetch("m1")
    np.testing.assert_array_equal(mgr.acquire("a1"), w["a1"])
    np.testing.assert_array_equal(mgr.acquire("m1"), w["m1"])
    mgr.release("a1"); mgr.release("m1")
    mgr.shutdown()


def test_eviction_unclogs_ring():
    """Prefetched-but-unconsumed entries must not deadlock acquire."""
    w = _mk(["a", "b", "c"])
    mgr = AsyncParamManager(w, {n: "g" for n in w})
    mgr.prefetch("a"); mgr.prefetch("b")     # ring full with a, b
    got = mgr.acquire("c")                   # must evict, not hang
    np.testing.assert_array_equal(got, w["c"])
    mgr.release("c")
    mgr.shutdown()


def test_wrap_around_prefetch_order():
    groups = {"x0": "g", "x1": "g", "x2": "g"}
    nxt = plan_prefetch_order(["x0", "x1", "x2"], groups)
    assert nxt == {"x0": "x1", "x1": "x2", "x2": "x0"}
