"""Tests for the static invariant analyzer (repro.analysis.lint).

Each fixture violates exactly one rule; the analyzer must (a) flag it,
naming file/line/rule, and (b) report nothing on the real tree — the
clean-tree run is what tools/ci.sh gates on.
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.lint import (check_hotpath, check_locks, check_prng,
                                 check_telemetry, findings_for_callable)
from repro.analysis.lint.__main__ import run as lint_main
from repro.analysis.lint.diagnostics import Finding, SuppressionIndex
from repro.serving.kv_cache import (PagedCacheCorruption, PagedKVCache,
                                    PagesExhausted)


# ---------------------------------------------------------------------------
# kernel checker: fixture pallas calls, one violation each
# ---------------------------------------------------------------------------

def _call_fixture_kernel(imap_in, block_in=(8, 128), shape=(16, 128)):
    """A minimal 1-in/1-out pallas call with a pluggable input index map."""
    from jax.experimental import pallas as pl

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    x = jnp.zeros(shape, jnp.float32)
    nrows = shape[0] // block_in[0]
    pl.pallas_call(
        kern,
        grid=(nrows,),
        in_specs=[pl.BlockSpec(block_in, imap_in)],
        out_specs=pl.BlockSpec((shape[0], shape[1]), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(shape, jnp.float32),
    )(x)


def test_kernel_oob_index_map_flagged():
    # off-by-one: grid point i=1 returns row-block 2, valid range [0, 2)
    found = findings_for_callable(
        _call_fixture_kernel, lambda i: (i + 1, 0))
    bounds = [f for f in found if f.rule == "kernel-grid-bounds"]
    assert bounds, found
    assert "valid range [0, 2)" in bounds[0].message
    assert bounds[0].path.endswith("test_lint.py") and bounds[0].line > 0


def test_kernel_in_bounds_map_clean():
    found = findings_for_callable(_call_fixture_kernel, lambda i: (i, 0))
    assert found == []


def test_kernel_misaligned_tile_flagged():
    # lane dim 64 is neither a multiple of 128 nor the operand extent 128
    found = findings_for_callable(
        _call_fixture_kernel, lambda i: (i, 0), (8, 64))
    align = [f for f in found if f.rule == "kernel-tile-alignment"]
    assert align, found
    assert "lane dim 64" in align[0].message


def test_kernel_scalar_arity_and_dtype():
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def kern(bt_ref, x_ref, o_ref, extra_ref):   # one ref too many
        o_ref[...] = x_ref[...]

    def entry(bt):
        x = jnp.zeros((8, 128), jnp.float32)
        pl.pallas_call(
            kern,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(1,),
                in_specs=[pl.BlockSpec((8, 128), lambda i, bt: (0, 0))],
                out_specs=pl.BlockSpec((8, 128), lambda i, bt: (0, 0))),
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
        )(bt, x)

    found = findings_for_callable(entry, jnp.zeros((4,), jnp.int32))
    assert any(f.rule == "kernel-scalar-arity" for f in found), found
    # a float block table is a dtype violation on top of the arity one
    found = findings_for_callable(entry, jnp.zeros((4,), jnp.float32))
    assert any(f.rule == "kernel-dtype" for f in found), found


def test_tree_kernels_clean():
    from repro.analysis.lint import check_kernels
    assert check_kernels() == []


# ---------------------------------------------------------------------------
# AST lints: tmp-tree fixtures, one violation each
# ---------------------------------------------------------------------------

def _write(root, rel, src):
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(src))
    return rel


def test_hotpath_item_flagged(tmp_path):
    rel = _write(tmp_path, "src/repro/serving/fixture_step.py", """\
        class Stepper:
            def step(self):
                return self._advance()

            def _advance(self):
                return self.tok.item()
        """)
    found = check_hotpath(tmp_path, files=[rel],
                          entries=[(rel, "Stepper", "step")], sinks=set())
    assert [f.rule for f in found] == ["hot-path-sync"]
    assert found[0].path == rel and found[0].line == 6
    assert ".item()" in found[0].message


def test_hotpath_sink_whitelisted(tmp_path):
    rel = _write(tmp_path, "src/repro/serving/fixture_sink.py", """\
        class Stepper:
            def step(self):
                return self._sample()

            def _sample(self):
                return self.tok.item()
        """)
    found = check_hotpath(tmp_path, files=[rel],
                          entries=[(rel, "Stepper", "step")],
                          sinks={(rel, "Stepper", "_sample")})
    assert found == []


def test_hotpath_unreachable_not_flagged(tmp_path):
    rel = _write(tmp_path, "src/repro/serving/fixture_cold.py", """\
        class Stepper:
            def step(self):
                return 1

            def debug_dump(self):
                return self.tok.item()
        """)
    found = check_hotpath(tmp_path, files=[rel],
                          entries=[(rel, "Stepper", "step")], sinks=set())
    assert found == []


def test_telemetry_sync_flagged(tmp_path):
    rel = _write(tmp_path, "src/repro/telemetry/fixture_sync.py", """\
        class Tracer:
            def span(self, name):
                return self._record(name)

            def _record(self, name):
                return self.t.block_until_ready()
        """)
    found = check_telemetry(tmp_path, files=[rel],
                            entries=[(rel, "Tracer", "span")])
    assert [f.rule for f in found] == ["telemetry-no-sync"]
    assert found[0].line == 6
    assert "block_until_ready" in found[0].message


def test_telemetry_unreachable_not_flagged(tmp_path):
    rel = _write(tmp_path, "src/repro/telemetry/fixture_cold.py", """\
        class Tracer:
            def span(self, name):
                return name

            def debug_sync(self):
                return self.t.item()
        """)
    assert check_telemetry(tmp_path, files=[rel],
                           entries=[(rel, "Tracer", "span")]) == []


def test_telemetry_tree_clean():
    from repro.analysis.lint.diagnostics import REPO_ROOT
    assert check_telemetry(REPO_ROOT) == []


def test_prng_raw_key_flagged(tmp_path):
    rel = _write(tmp_path, "bad_prng.py", """\
        import jax

        def sample(seed):
            key = jax.random.PRNGKey(seed)
            return jax.random.fold_in(key, 0)
        """)
    found = check_prng(tmp_path, files=[rel])
    assert [f.rule for f in found] == ["prng-discipline"]
    assert found[0].line == 4          # fold_in is sanctioned, not flagged


def test_lock_unlocked_write_flagged(tmp_path):
    rel = _write(tmp_path, "bad_locks.py", """\
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0
                self._t = threading.Thread(target=self._worker)

            def _worker(self):
                self.count += 1

            def bump(self):
                with self._lock:
                    self.count += 1
        """)
    found = check_locks(tmp_path, files=[rel])
    assert [f.rule for f in found] == ["lock-discipline"]
    assert found[0].line == 10 and "Counter._worker" in found[0].message


def test_lock_held_helper_clean(tmp_path):
    # the fixpoint: a helper whose every call site holds the lock is safe
    rel = _write(tmp_path, "good_locks.py", """\
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0
                self._t = threading.Thread(target=self._worker)

            def _bump(self):
                self.count += 1

            def _worker(self):
                with self._lock:
                    self._bump()

            def bump(self):
                with self._lock:
                    self._bump()
        """)
    assert check_locks(tmp_path, files=[rel]) == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_suppression_with_justification(tmp_path):
    rel = _write(tmp_path, "s.py", """\
        # lint: allow[some-rule] this site is exempt because reasons
        x = 1
        y = 2
        """)
    idx = SuppressionIndex(tmp_path)
    assert not idx.apply([Finding("some-rule", rel, 2, "m")])
    # different rule or uncovered line: untouched
    assert idx.apply([Finding("other-rule", rel, 2, "m")])
    assert idx.apply([Finding("some-rule", rel, 3, "m")])


def test_bare_suppression_warns(tmp_path):
    rel = _write(tmp_path, "s.py", """\
        # lint: allow[some-rule]
        x = 1
        """)
    out = SuppressionIndex(tmp_path).apply(
        [Finding("some-rule", rel, 2, "m")])
    assert [f.rule for f in out] == ["bare-suppression"]
    assert out[0].severity == "warning"


# ---------------------------------------------------------------------------
# runtime self-check (PagedKVCache(check=True))
# ---------------------------------------------------------------------------

@pytest.fixture()
def kv(tiny_cfg):
    return PagedKVCache(tiny_cfg, 4, 64, page_size=8, check=True)


def test_selfcheck_double_release(kv):
    kv.alloc(0, 10)
    kv.free(0)
    with pytest.raises(PagedCacheCorruption, match="double release"):
        kv.free(0)


def test_selfcheck_detects_corrupt_internals(kv):
    kv.alloc(0, 10)
    kv._tables[0, 0] = kv.n_pages + 5          # out-of-range page
    with pytest.raises(PagedCacheCorruption, match="out-of-range"):
        kv.validate()


def test_selfcheck_detects_refcount_drift(kv):
    kv.alloc(0, 10)
    kv._ref[int(kv._tables[0, 0])] += 1        # phantom reference
    with pytest.raises(PagedCacheCorruption, match="ref-count"):
        kv.validate()


def test_selfcheck_truncate_after_fork(kv, tiny_cfg):
    cache = kv.init_cache()
    kv.alloc(0, 20)                            # 3 pages, last partial
    cache = kv.fork(cache, 0, 1, 20)           # 2 shared + 1 copied
    assert kv.stats()["refcount_max"] == 2
    # shrink the fork below the shared boundary: pure-metadata rollback
    cache = kv.truncate(cache, 1, 8)
    kv.validate()
    kv.free(0)
    kv.free(1)
    st = kv.close()
    assert st["pages_leaked"] == 0 and st["free_pages"] == kv.usable_pages


def test_selfcheck_close_reports_leak(kv):
    kv._free.pop()                             # lose a page
    with pytest.raises(PagedCacheCorruption, match="leaked"):
        kv.close()


def test_stats_cheap_without_check(tiny_cfg):
    kv = PagedKVCache(tiny_cfg, 2, 32, page_size=8)    # check=False
    kv.alloc(0, 9)
    st = kv.stats()
    assert st["mapped_pages"] == 2 and st["pages_leaked"] == 0
    kv.free(0)
    kv.free(0)                                 # silent no-op when unchecked
    assert kv.close()["pages_leaked"] == 0


# ---------------------------------------------------------------------------
# the tree itself is clean — the CI gate
# ---------------------------------------------------------------------------

def test_clean_tree_strict_exit_zero(capsys):
    assert lint_main(["--strict", "--skip-kernels"]) == 0
    assert "lint: clean" in capsys.readouterr().out
