"""int8 gradient compression with error feedback."""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.distributed.compression import (dequantize_int8, ef_compress,
                                           ef_init, quantize_int8)


@given(n=st.integers(1, 5000), scale=st.floats(1e-3, 1e3))
@settings(max_examples=25, deadline=None)
def test_quant_error_bounded(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q, s, shp = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s, shp)) - np.asarray(x))
    # per-chunk bound: half a quantization step
    assert err.max() <= float(s.max()) * 0.51 + 1e-9


def test_wire_bytes_ratio():
    x = jnp.ones((4096,), jnp.float32)
    q, s, _ = quantize_int8(x, chunk=2048)
    wire = q.size * 1 + s.size * 4
    assert wire < 0.3 * x.size * 4     # ~3.9x compression


def test_error_feedback_unbiased_over_steps():
    """With EF, the *accumulated* applied update converges to the
    accumulated true gradient (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(1000) * 0.01, jnp.float32)
    ef = ef_init({"g": g_true})
    applied = np.zeros(1000)
    for step in range(20):
        payload, ef = ef_compress({"g": g_true}, ef)
        q, s, shp = payload["g"]
        applied += np.asarray(dequantize_int8(q, s, shp))
    total_true = np.asarray(g_true) * 20
    resid = np.abs(applied - total_true).max()
    one_step_err = float(s.max())
    assert resid <= one_step_err * 2   # error does not accumulate
