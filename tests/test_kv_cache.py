"""The paged KV subsystem: block-pool allocator, block-table plumbing,
and the continuous batcher's page map/unmap admit/release path.

The contract under test: ``ContinuousBatcher(paged=True)`` over
ResidentBackend / HeteGenBackend is *token-identical* to the dense-cache
path for interleaved admit/release schedules, admission performs no
whole-cache slice merges (page map/unmap only), page exhaustion queues
requests until a release returns pages, and prefix ``fork`` shares pages
by ref-count with reclaim only at the last release.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.hw import PAPER_A10
from repro.kernels import ref
from repro.models import model as M
from repro.serving.backends import (HeteGenBackend, ResidentBackend,
                                    ScanResidentBackend)
from repro.serving.batcher import ContinuousBatcher
from repro.serving.kv_cache import (TRASH_PAGE, PagedKVCache, PagesExhausted,
                                    slot_view)


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("tiny")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def opt_setup():
    cfg = reduced(get_config("opt-6.7b"), layers=3)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _mixed_requests(rng, cfg, n=5):
    prompts = [list(rng.integers(0, cfg.vocab_size, k))
               for k in (5, 9, 3, 7, 4)[:n]]
    max_news = [6, 4, 5, 3, 7][:n]
    return prompts, max_news


def _allocator_consistent(kv: PagedKVCache):
    """Pool invariant: every page is free xor mapped (ref-counted)."""
    mapped = {}
    for s in range(kv.max_slots):
        for pid in kv.mapped_pages(s):
            mapped[pid] = mapped.get(pid, 0) + 1
    assert TRASH_PAGE not in mapped
    for pid, cnt in mapped.items():
        assert kv.refcount(pid) == cnt, pid
        assert pid not in kv._free
    assert len(kv._free) + len(mapped) == kv.n_pages - 1
    assert len(set(kv._free)) == len(kv._free)          # no double-free


# ---------------------------------------------------------------------------
# token-exact equivalence vs the dense path
# ---------------------------------------------------------------------------

def test_paged_vs_dense_resident_interleaved(tiny_setup, rng):
    """Interleaved admit/release (5 requests through 2 slots): the paged
    batcher samples the same tokens as the dense-cache batcher."""
    cfg, params = tiny_setup
    prompts, max_news = _mixed_requests(rng, cfg)

    dense = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                              max_slots=2, max_len=64)
    dids = [dense.submit(p, m) for p, m in zip(prompts, max_news)]
    dout = dense.run_until_done()

    paged = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                              max_slots=2, max_len=64, paged=True,
                              page_size=8)
    pids = [paged.submit(p, m) for p, m in zip(prompts, max_news)]
    pout = paged.run_until_done()

    for d, p in zip(dids, pids):
        assert dout[d] == pout[p], (d, p)
    # release unmapped everything: the pool drained back to full
    assert paged.kv.free_pages == paged.kv.n_pages - 1
    assert paged.kv.stats()["pages_leaked"] == 0
    _allocator_consistent(paged.kv)


def test_paged_vs_dense_hetegen_batcher(opt_setup, rng):
    """Acceptance: ContinuousBatcher over HeteGenBackend with PagedKVCache
    is token-identical to the dense-cache path on offloaded weights."""
    cfg, params = opt_setup
    prompts, max_news = _mixed_requests(rng, cfg, n=4)

    dense = ContinuousBatcher(cfg, params, max_slots=3, max_len=64)
    dids = [dense.submit(p, m) for p, m in zip(prompts, max_news)]
    dout = dense.run_until_done()

    hb = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0, batch=3)
    paged = ContinuousBatcher(cfg, backend=hb, max_slots=3, max_len=64,
                              paged=True, page_size=8)
    pids = [paged.submit(p, m) for p, m in zip(prompts, max_news)]
    pout = paged.run_until_done()

    for d, p in zip(dids, pids):
        assert dout[d] == pout[p], (d, p)
    assert paged.kv.free_pages == paged.kv.n_pages - 1
    assert paged.kv.stats()["pages_leaked"] == 0
    hb.close()


def test_paged_logits_match_dense(tiny_setup, rng):
    """Stronger than token equality: prefill + decode logits through the
    paged plumbing match the dense backend cache to fp tolerance."""
    cfg, params = tiny_setup
    be = ResidentBackend(cfg, params)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 6)), jnp.int32)

    dc = be.init_cache(2, 32)
    dc, dlog = be.prefill({"tokens": toks}, dc)

    kv = be.init_paged_cache(2, 32, page_size=8)
    kv.alloc(0, 12)
    kv.alloc(1, 12)
    pc = kv.init_cache()
    pc["len"] = jnp.zeros((), jnp.int32)    # scalar len: batched prefill
    pc, plog = be.prefill({"tokens": toks}, pc)
    np.testing.assert_allclose(plog, dlog, rtol=1e-5, atol=1e-5)

    tok = jnp.argmax(dlog, -1).astype(jnp.int32)
    for _ in range(3):
        dc, dlog = be.decode(tok, dc)
        pc, plog = be.decode(tok, pc)
        np.testing.assert_allclose(plog, dlog, rtol=1e-5, atol=1e-5)
        tok = jnp.argmax(dlog, -1).astype(jnp.int32)


def test_admission_is_map_only(tiny_setup, rng, monkeypatch):
    """Paged admit/release never takes the dense whole-slice merge path —
    the only cache writes are page scatters through the block table."""
    cfg, params = tiny_setup
    prompts, max_news = _mixed_requests(rng, cfg)
    b = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                          max_slots=2, max_len=64, paged=True, page_size=8)

    def boom(self, *a, **k):
        raise AssertionError("dense slice merge on the paged path")
    monkeypatch.setattr(ContinuousBatcher, "_prefill_dense_slot", boom)
    for p, m in zip(prompts, max_news):
        b.submit(p, m)
    out = b.run_until_done()
    assert all(len(v) for v in out.values())


# ---------------------------------------------------------------------------
# page exhaustion / fragmentation
# ---------------------------------------------------------------------------

def test_pages_exhausted_queues_until_release(tiny_setup, rng):
    """Classic reservation (optimistic=False): a pool too small for two
    concurrent requests serializes them — the second stays queued (its
    slot empty) until the first releases.  The optimistic default would
    instead admit both and preempt under pressure
    (tests/test_scheduler.py)."""
    cfg, params = tiny_setup
    prompts = [list(rng.integers(0, cfg.vocab_size, 9)) for _ in range(2)]
    # 19 tokens -> 3 pages of 8 each; 4 usable pages fit only one request
    b = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                          max_slots=2, max_len=32, paged=True, page_size=8,
                          n_pages=5, optimistic=False)
    r0 = b.submit(prompts[0], 10)
    r1 = b.submit(prompts[1], 10)
    b.step()
    assert b.active.sum() == 1 and len(b.queue) == 1   # r1 starved of pages
    out = b.run_until_done()
    assert len(out[r0]) == 10 and len(out[r1]) == 10
    assert b.kv.free_pages == 4

    # and the tokens match an uncontended dense run (queueing changed
    # scheduling, not results)
    dense = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                              max_slots=2, max_len=32)
    d0 = dense.submit(prompts[0], 10)
    d1 = dense.submit(prompts[1], 10)
    dout = dense.run_until_done()
    assert out[r0] == dout[d0]


def test_fragmentation_churn_reuses_pages(tiny_setup, rng):
    """Admit/release churn over a small pool: pages recycle through the
    free list with the allocator invariant intact and nothing leaked."""
    cfg, params = tiny_setup
    b = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                          max_slots=2, max_len=32, paged=True, page_size=8,
                          n_pages=9)
    for i in range(8):
        b.submit(list(rng.integers(0, cfg.vocab_size, 3 + (i % 5))),
                 2 + (i % 4))
    out = b.run_until_done()
    assert len(out) == 8 and all(len(v) for v in out.values())
    assert b.kv.free_pages == 8
    assert b.kv.stats()["pages_leaked"] == 0
    _allocator_consistent(b.kv)


def test_alloc_all_or_nothing(tiny_setup):
    cfg, _ = tiny_setup
    kv = PagedKVCache(cfg, 2, 64, page_size=8, n_pages=3)   # 2 usable
    with pytest.raises(PagesExhausted):
        kv.alloc(0, 24)                                     # needs 3
    assert kv.free_pages == 2 and kv.mapped_pages(0) == []
    with pytest.raises(ValueError):
        kv.alloc(0, 100)                                    # > max_len
    kv.alloc(0, 16)
    assert kv.free_pages == 0 and len(kv.mapped_pages(0)) == 2


# ---------------------------------------------------------------------------
# prefix sharing (fork) and ref-count reclaim
# ---------------------------------------------------------------------------

def test_fork_shares_pages_and_reclaims_by_refcount(tiny_setup, rng):
    cfg, _ = tiny_setup
    kv = PagedKVCache(cfg, 2, 64, page_size=8)
    kv.alloc(0, 20)                                 # 3 pages
    cache = kv.init_cache()
    # stamp recognizable values through the slot-0 block table
    pool = cache["pages_k0"]
    for j, pid in enumerate(kv.mapped_pages(0)):
        pool = pool.at[pid].set(float(j + 1))
    cache["pages_k0"] = pool

    cache = kv.fork(cache, 0, 1, 17)                # 2 full + 1 partial
    src, dst = kv.mapped_pages(0), kv.mapped_pages(1)
    assert dst[:2] == src[:2]                       # full pages aliased
    assert dst[2] != src[2]                         # partial page copied
    assert kv.refcount(src[0]) == 2 and kv.refcount(src[1]) == 2
    assert kv.refcount(src[2]) == 1 and kv.refcount(dst[2]) == 1
    np.testing.assert_array_equal(cache["pages_k0"][dst[2]],
                                  cache["pages_k0"][src[2]])
    # the forked slot reads the identical prefix through its own table
    bt = kv.device_block_tables()
    g = ref.gather_pages(cache["pages_k0"], bt)
    np.testing.assert_array_equal(g[0, :, :17], g[1, :, :17])

    free0 = kv.free_pages
    kv.free(0)                                      # shared pages survive
    assert kv.refcount(src[0]) == 1 and kv.refcount(src[1]) == 1
    assert kv.free_pages == free0 + 1               # only src partial page
    kv.free(1)                                      # last owner: reclaim
    assert kv.free_pages == kv.n_pages - 1
    st = kv.stats()
    assert st["pages_leaked"] == 0
    assert st["refcount_max"] >= 2                  # the fork was recorded
    _allocator_consistent(kv)


def test_fork_rejects_bad_targets(tiny_setup):
    cfg, _ = tiny_setup
    kv = PagedKVCache(cfg, 2, 64, page_size=8)
    kv.alloc(0, 10)
    kv.alloc(1, 8)
    cache = kv.init_cache()
    with pytest.raises(ValueError):
        kv.fork(cache, 0, 1, 8)             # dst still holds pages
    kv.free(1)
    with pytest.raises(ValueError):
        kv.fork(cache, 0, 1, 30)            # past src's mapped pages


# ---------------------------------------------------------------------------
# slot_view / q8 pools
# ---------------------------------------------------------------------------

def test_slot_view_shares_pools(tiny_setup):
    cfg, _ = tiny_setup
    kv = PagedKVCache(cfg, 3, 32, page_size=8)
    kv.alloc(1, 10)
    cache = kv.init_cache()
    one = slot_view(cache, 1)
    assert one["pages_k0"] is cache["pages_k0"]     # pools shared, no copy
    assert one["block_tables"].shape == (1, kv.blocks_per_slot)
    assert one["len"].shape == ()


def test_q8_paged_pools_close_to_fp(tiny_setup, rng):
    """int8 pages + scale pages track the fp paged path within quant
    error (mirrors decode_attention's q8 contract at the model level)."""
    cfg, params = tiny_setup
    be = ResidentBackend(cfg, params)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 6)), jnp.int32)

    def run(kv_dtype):
        kv = be.init_paged_cache(2, 32, page_size=8, kv_dtype=kv_dtype)
        kv.alloc(0, 12)
        kv.alloc(1, 12)
        c = kv.init_cache()
        c["len"] = jnp.zeros((), jnp.int32)
        c, logits = be.prefill({"tokens": toks}, c)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        c, logits = be.decode(tok, c)
        return logits

    fp = run(None)
    q8 = run("int8")
    err = float(jnp.max(jnp.abs(q8 - fp)) / jnp.max(jnp.abs(fp)))
    assert err < 0.05, err


def test_q8_paged_batcher_serves(tiny_setup, rng):
    """kv_dtype='int8' threads through the batcher: q8 paged serving runs
    interleaved admit/release end to end and drains the pool."""
    cfg, params = tiny_setup
    prompts, max_news = _mixed_requests(rng, cfg, n=3)
    b = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                          max_slots=2, max_len=64, paged=True, page_size=8,
                          kv_dtype="int8")
    rids = [b.submit(p, m) for p, m in zip(prompts, max_news)]
    out = b.run_until_done()
    assert [len(out[r]) for r in rids] == max_news[:3]
    assert b.kv.kv_dtype == "int8"
    assert b.cache["pages_k0"].dtype == jnp.int8
    assert b.kv.free_pages == b.kv.n_pages - 1


# ---------------------------------------------------------------------------
# occupancy-driven re-tuning (ROADMAP item)
# ---------------------------------------------------------------------------

def test_occupancy_retune_with_hysteresis(opt_setup, rng):
    """When active slots collapse 3 -> 1, the paged batcher compacts the
    decode batch to the occupancy and re-tunes the HeteGen plan for that
    *executed* batch; the hysteresis margin keeps one-slot wobbles from
    rebuilding the engine, and results stay token-exact."""
    cfg, params = opt_setup
    prompts = [list(rng.integers(0, cfg.vocab_size, 5)) for _ in range(3)]
    max_news = [12, 2, 2]

    dense = ContinuousBatcher(cfg, params, max_slots=3, max_len=64)
    dids = [dense.submit(p, m) for p, m in zip(prompts, max_news)]
    dout = dense.run_until_done()

    hb = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0, batch=3)
    b = ContinuousBatcher(cfg, backend=hb, max_slots=3, max_len=64,
                          paged=True, page_size=8, retune_hysteresis=1)
    pids = [b.submit(p, m) for p, m in zip(prompts, max_news)]
    pout = b.run_until_done()

    assert b.retunes == 1                   # 3 -> 2 absorbed, 3 -> 1 retuned
    assert hb.policy.batch == 1             # plan == executed decode batch
    for d, p in zip(dids, pids):
        assert dout[d] == pout[p]
    hb.close()

    # a wide margin absorbs everything: zero rebuilds
    hb2 = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0, batch=3)
    b2 = ContinuousBatcher(cfg, backend=hb2, max_slots=3, max_len=64,
                           paged=True, page_size=8, retune_hysteresis=10)
    for p, m in zip(prompts, max_news):
        b2.submit(p, m)
    b2.run_until_done()
    assert b2.retunes == 0 and hb2.policy.batch == 3
    hb2.close()

    # dense mode always executes max_slots-wide: never re-tunes
    hb3 = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0, batch=3)
    b3 = ContinuousBatcher(cfg, backend=hb3, max_slots=3, max_len=64,
                           retune_hysteresis=1)
    for p, m in zip(prompts, max_news):
        b3.submit(p, m)
    b3.run_until_done()
    assert b3.retunes == 0 and hb3.policy.batch == 3
    hb3.close()


def test_scan_backend_rejects_paged(tiny_setup):
    cfg, params = tiny_setup
    with pytest.raises(NotImplementedError):
        ContinuousBatcher(cfg, backend=ScanResidentBackend(cfg, params),
                          max_slots=2, max_len=32, paged=True)


# ---------------------------------------------------------------------------
# truncate: speculative rollback's page-table primitive
# ---------------------------------------------------------------------------

def test_truncate_copies_shared_partial_page(tiny_setup):
    """Shrinking onto a ref-counted trailing page must copy it first:
    the slot will overwrite the tail on its next append, and the fork
    sibling still reads the original bytes through its own table."""
    cfg, _ = tiny_setup
    kv = PagedKVCache(cfg, 2, 64, page_size=8)
    kv.alloc(0, 20)                                 # pages A, B, C
    cache = kv.init_cache()
    pool = cache["pages_k0"]
    for j, pid in enumerate(kv.mapped_pages(0)):
        pool = pool.at[pid].set(float(j + 1))
    cache["pages_k0"] = pool

    cache = kv.fork(cache, 0, 1, 16)                # slot 1 aliases A, B
    src = kv.mapped_pages(0)
    free0 = kv.free_pages
    cache = kv.truncate(cache, 0, 12)               # drop C, split B
    now = kv.mapped_pages(0)
    assert now[0] == src[0]                         # full page stays shared
    assert now[1] != src[1]                         # partial page copied
    assert kv.refcount(src[1]) == 1                 # sibling sole owner now
    assert kv.refcount(now[1]) == 1
    np.testing.assert_array_equal(cache["pages_k0"][now[1]],
                                  cache["pages_k0"][src[1]])
    assert kv.mapped_pages(1) == src[:2]            # sibling untouched
    assert kv.free_pages == free0                   # C freed, copy taken
    _allocator_consistent(kv)
    kv.free(0)
    kv.free(1)
    assert kv.free_pages == kv.n_pages - 1


def test_truncate_boundary_releases_and_sole_owner_keeps(tiny_setup):
    cfg, _ = tiny_setup
    kv = PagedKVCache(cfg, 1, 64, page_size=8)
    kv.alloc(0, 20)                                 # 3 pages
    cache = kv.init_cache()
    free0 = kv.free_pages
    cache = kv.truncate(cache, 0, 16)               # exactly 2 pages
    assert len(kv.mapped_pages(0)) == 2
    assert kv.free_pages == free0 + 1               # page boundary: no copy
    pages = kv.mapped_pages(0)
    cache = kv.truncate(cache, 0, 12)               # unaligned, ref-1 page
    assert kv.mapped_pages(0) == pages              # kept in place, no copy
    with pytest.raises(ValueError):
        kv.truncate(cache, 0, 30)                   # truncate cannot grow
    _allocator_consistent(kv)
