"""End-to-end behaviour of the paper's system.

The full pipeline on one host: scheduler stage (alpha benchmark + module
scheduler) -> runtime stage (hybrid heterogeneous engine) -> generation,
checked for token-exactness against the resident path, plus the headline
performance claims under the simulated A10 clock.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.hw import PAPER_A10
from repro.models import model as M
from repro.serving.engine import Generator
from repro.serving.offload_runtime import OffloadGenerator, enumerate_linears


@pytest.fixture(scope="module")
def opt():
    cfg = reduced(get_config("opt-6.7b"), layers=3)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_scheduler_then_runtime_end_to_end(opt, rng):
    """Fig. 4 pipeline: alpha + residency plan, then exact generation."""
    cfg, params = opt
    linears = enumerate_linears(cfg)
    total = sum(s.nbytes for s in linears)
    prompt = rng.integers(0, cfg.vocab_size, (2, 10)).astype(np.int32)
    ref = Generator(cfg, params).generate({"tokens": jnp.asarray(prompt)}, 8)

    off = OffloadGenerator(cfg, params, hw=PAPER_A10,
                           budget_bytes=0.4 * total)
    plan_modes = {p.mode for p in off.policy.plan}
    assert plan_modes == {"resident", "hetegen"}   # mixed placement
    res = off.generate(prompt, 8)
    assert res["tokens"].tolist() == ref.tokens    # token-exact
    assert 0.0 < res["alpha"] < 1.0
    assert res["resident_bytes"] <= 0.4 * total + 1
    # the pinned ring is bounded: 2 slots per size group
    assert res["pinned_overhead_bytes"] < 8 * max(s.nbytes for s in linears)
    off.close()


def test_headline_speedup_claim():
    """HeteGen > 3x over the FlexGen-like baseline somewhere in the memory
    range, and never slower (paper Fig. 8, 'up to 317%')."""
    from benchmarks.common import opt_decode_modules, weight_bytes
    from repro.core.sim import run_strategy

    mods = opt_decode_modules("opt-30b")
    ratios = []
    for frac in (0.0, 0.25, 0.5):
        budget = frac * weight_bytes(mods)
        h = run_strategy(mods, "hetegen", PAPER_A10, gpu_mem_budget=budget)
        f = run_strategy(mods, "sync_offload", PAPER_A10,
                         gpu_mem_budget=budget)
        assert h.tokens_per_s >= f.tokens_per_s - 1e-12
        ratios.append(h.tokens_per_s / f.tokens_per_s)
    assert max(ratios) > 3.0


def test_offload_beats_everything_else_offloaded():
    """Under full offload the hybrid strategy is the fastest of all
    simulated offload strategies (Fig. 5)."""
    from benchmarks.common import opt_decode_modules
    from repro.core.sim import run_strategy

    mods = opt_decode_modules("opt-13b")
    times = {s: run_strategy(mods, s, PAPER_A10).step_time
             for s in ("naive_offload", "sync_offload", "hetegen_basic",
                       "hetegen_pinned", "hetegen")}
    assert times["hetegen"] == min(times.values())


def test_int8_kv_cache_feature(rng):
    """Beyond-paper: int8 KV serving stays within quantization error."""
    import dataclasses
    cfg = reduced(get_config("mistral-nemo-12b"))
    cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 20)), jnp.int32)
    full = M.forward_train(cfg, params, {"tokens": toks})
    cache = M.init_cache(cfg8, 2, 20)
    c, logits = M.prefill(cfg8, params, {"tokens": toks[:, :12]}, cache)
    errs = [float(jnp.abs(logits - full[:, 11]).max())]
    for t in range(12, 20):
        c, logits = M.decode_step(cfg8, params, toks[:, t], c)
        errs.append(float(jnp.abs(logits - full[:, t]).max()))
    assert max(errs) / (float(jnp.abs(full).max()) + 1e-9) < 0.05
