"""The scheduler seam (docs/SERVING.md): pluggable admission/preemption
policies, optimistic paging with token-exact preempt/resume, the
AsyncLLM event loop, and the cross-step prefetch overlap."""
import threading

import jax
import pytest

from repro.configs import get_config
from repro.core.hw import PAPER_A10
from repro.models import model as M
from repro.serving.api import LLM, AsyncLLM
from repro.serving.backends import HeteGenBackend, ResidentBackend
from repro.serving.batcher import ContinuousBatcher
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (FairSharePolicy, FCFSPolicy,
                                     PriorityPolicy, RequestState,
                                     get_policy)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _batcher(cfg, params, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 48)
    return ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                             own_backend=True, **kw)


def _reference(cfg, params, submits, max_len=48):
    """Run the same (rid, prompt, max_new, sampling) set with slots and
    pages to spare: the unpressured baseline every scheduling decision
    must be invisible against."""
    b = _batcher(cfg, params, max_slots=len(submits), max_len=max_len)
    for rid, p, n, sp in submits:
        b.submit(p, n, sampling=sp, rid=rid)
    out = b.run_until_done()
    b.close()
    return out


# ---------------------------------------------------------------------------
# policies as pure functions
# ---------------------------------------------------------------------------

def _st(rid, *, priority=0, arrival=0, generated=0, resumed_at=0):
    st = RequestState(rid, [1, 2], 8, priority=priority, arrival=arrival)
    st.generated = list(range(generated))
    st.resumed_at = resumed_at
    return st


def test_policy_registry():
    assert isinstance(get_policy("fcfs"), FCFSPolicy)
    assert isinstance(get_policy("priority"), PriorityPolicy)
    assert isinstance(get_policy("fair_share"), FairSharePolicy)
    p = FairSharePolicy(quantum=3)
    assert get_policy(p) is p
    assert get_policy(None).name == "fcfs"
    with pytest.raises(ValueError, match="unknown scheduler policy"):
        get_policy("lifo")


def test_policy_orderings():
    a = _st(0, arrival=0, priority=1, generated=4)
    b = _st(1, arrival=1, priority=5, generated=0)
    c = _st(2, arrival=2, priority=1, generated=9)
    fcfs, prio = FCFSPolicy(), PriorityPolicy()
    fair = FairSharePolicy(quantum=2)
    assert [s.rid for s in fcfs.admit_order([c, b, a])] == [0, 1, 2]
    assert [s.rid for s in fcfs.preempt_order([a, b, c])] == [2, 1, 0]
    assert not fcfs.may_preempt(b, a)
    assert [s.rid for s in prio.admit_order([c, a, b])] == [1, 0, 2]
    # lowest priority, newest first, goes to the wall first
    assert [s.rid for s in prio.preempt_order([a, b, c])] == [2, 0, 1]
    assert prio.may_preempt(b, a) and not prio.may_preempt(a, b)
    assert not prio.may_preempt(a, c)          # equal never preempts
    # fair share: least served admits first, most served is sacrificed
    assert [s.rid for s in fair.admit_order([c, a, b])] == [1, 0, 2]
    assert fair.preempt_order([a, b, c])[0].rid == 2
    # a victim is evictable only after its quantum elapsed
    assert fair.may_preempt(b, c)              # c served 9 since resume
    c2 = _st(2, generated=9, resumed_at=8)     # just resumed: 1 token
    assert not fair.may_preempt(b, c2)


def test_preempt_mode_validation(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="preempt_mode"):
        _batcher(cfg, params, preempt_mode="magic")
    with pytest.raises(ValueError, match="swap"):
        _batcher(cfg, params, preempt_mode="swap")   # dense has no pages


# ---------------------------------------------------------------------------
# optimistic paging
# ---------------------------------------------------------------------------

def test_optimistic_admits_past_worst_case(setup, rng):
    """The point of per-step reservation: a pool that worst-case
    reservation serializes (see test_kv_cache's optimistic=False twin)
    runs both requests concurrently, and the outputs still match the
    unpressured dense run token for token."""
    cfg, params = setup
    prompts = [list(rng.integers(0, cfg.vocab_size, 9)) for _ in range(2)]
    b = _batcher(cfg, params, max_len=32, paged=True, page_size=8,
                 n_pages=5)
    r0 = b.submit(prompts[0], 10)
    r1 = b.submit(prompts[1], 10)
    b.step()
    assert b.active.sum() == 2          # conservative mode admits 1
    out = b.run_until_done()
    b.close()
    ref = _reference(cfg, params,
                     [(r0, prompts[0], 10, None), (r1, prompts[1], 10, None)],
                     max_len=32)
    assert out == ref


def test_growth_stall_raises_not_spins(setup, rng):
    """A lone request that outgrows the whole pool can never finish: the
    scheduler raises instead of preempt/resume-flapping forever."""
    cfg, params = setup
    b = _batcher(cfg, params, max_len=64, paged=True, page_size=8,
                 n_pages=3)
    b.submit(list(rng.integers(0, cfg.vocab_size, 9)), 20)
    with pytest.raises(RuntimeError, match="stalled"):
        b.run_until_done()
    b.close()


# ---------------------------------------------------------------------------
# preemption / resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preempt_mode", ["swap", "recompute"])
def test_priority_preempts_and_resumes_token_identical(setup, rng,
                                                       preempt_mode):
    """The acceptance scenario: page pressure + priority policy.  The
    late high-priority request evicts a low-priority tenant and finishes
    first; the victims resume (host-swapped pages or recompute) and every
    request matches its unpressured run bit for bit — stochastic
    samplers included."""
    cfg, params = setup
    prompts = [list(rng.integers(0, cfg.vocab_size, 6)) for _ in range(3)]
    sps = [SamplingParams(),
           SamplingParams(kind="topp", top_p=0.9, temperature=1.4, seed=7),
           SamplingParams(kind="topk", top_k=8, seed=9)]
    b = _batcher(cfg, params, max_len=32, paged=True, page_size=8,
                 n_pages=5, policy="priority", preempt_mode=preempt_mode)
    finish_order = []

    def pump():
        b.step()
        for st in b.requests.values():
            if st.done and st.rid not in finish_order:
                finish_order.append(st.rid)

    lo0 = b.submit(prompts[0], 16, sampling=sps[0], priority=0)
    lo1 = b.submit(prompts[1], 16, sampling=sps[1], priority=0)
    for _ in range(3):
        pump()
    hi = b.submit(prompts[2], 4, sampling=sps[2], priority=5)
    for _ in range(200):
        if not b.queue and not b.active.any():
            break
        pump()
    out = {rid: st.generated for rid, st in b.requests.items()}
    assert b.scheduler.preemptions >= 1
    assert any(st.preemptions for st in b.requests.values())
    assert finish_order[0] == hi        # priority jumped the line
    assert b.kv.free_pages == b.kv.usable_pages    # nothing leaked
    b.close()
    ref = _reference(cfg, params,
                     [(lo0, prompts[0], 16, sps[0]),
                      (lo1, prompts[1], 16, sps[1]),
                      (hi, prompts[2], 4, sps[2])], max_len=32)
    assert out == ref


def test_dense_slot_preemption_recompute(setup, rng):
    """Preemption is not a paged-only feature: with every slot occupied,
    a higher-priority request evicts a dense tenant (recompute resume)
    and tokens still match the unpressured run."""
    cfg, params = setup
    prompts = [list(rng.integers(0, cfg.vocab_size, 5)) for _ in range(2)]
    b = _batcher(cfg, params, max_slots=1, policy="priority")
    lo = b.submit(prompts[0], 10)
    b.step()
    hi = b.submit(prompts[1], 3, priority=2)
    out = b.run_until_done()
    assert b.scheduler.preemptions == 1
    assert b.requests[lo].preemptions == 1
    b.close()
    ref = _reference(cfg, params, [(lo, prompts[0], 10, None),
                                   (hi, prompts[1], 3, None)])
    assert out == ref


def test_fcfs_growth_preempts_newest(setup, rng):
    """Under pure page pressure (no priorities anywhere) the FCFS policy
    sacrifices the newest arrival, serializes through the crunch, and
    still completes everything token-identically."""
    cfg, params = setup
    prompts = [list(rng.integers(0, cfg.vocab_size, 6)) for _ in range(2)]
    b = _batcher(cfg, params, max_len=32, paged=True, page_size=8,
                 n_pages=5)
    r0 = b.submit(prompts[0], 14)
    r1 = b.submit(prompts[1], 14)
    out = b.run_until_done()
    assert b.scheduler.preemptions >= 1
    assert b.requests[r0].preemptions == 0     # the elder is protected
    assert b.requests[r1].preemptions >= 1
    b.close()
    ref = _reference(cfg, params, [(r0, prompts[0], 14, None),
                                   (r1, prompts[1], 14, None)], max_len=32)
    assert out == ref


def test_fair_share_starvation_bound(setup, rng):
    """One slot, three long requests: the quantum bounds how long anyone
    waits.  Every request starts within (n-1) * (quantum + 1) steps, the
    slot round-robins, and slicing never changes tokens."""
    cfg, params = setup
    prompts = [list(rng.integers(0, cfg.vocab_size, 4)) for _ in range(3)]
    b = _batcher(cfg, params, max_slots=1, max_len=64,
                 policy=FairSharePolicy(quantum=3))
    rids = [b.submit(p, 9) for p in prompts]
    started, steps = {}, 0
    while (b.queue or b.active.any()) and steps < 300:
        b.step()
        steps += 1
        for st in b.requests.values():
            if st.generated and st.rid not in started:
                started[st.rid] = steps
    out = {rid: st.generated for rid, st in b.requests.items()}
    assert set(started) == set(rids)
    assert max(started.values()) <= 2 * 4 + 1   # (n-1) * (quantum+1) + 1
    assert b.scheduler.preemptions >= 2         # the slot actually rotated
    assert all(st.preemptions for st in b.requests.values()
               if st.rid != rids[-1])
    b.close()
    ref = _reference(cfg, params,
                     [(r, p, 9, None) for r, p in zip(rids, prompts)],
                     max_len=64)
    assert out == ref


def test_paged_offload_preemption_full_stack(setup, rng):
    """The whole tower at once: HeteGen offloaded weights + paged KV +
    priority preemption + swap resume, equal to the unpressured resident
    dense run."""
    cfg, params = setup
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (6, 9)]
    hb = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0, batch=2)
    b = ContinuousBatcher(cfg, backend=hb, own_backend=True, max_slots=2,
                          max_len=32, paged=True, page_size=8, n_pages=4,
                          policy="priority")
    lo = b.submit(prompts[0], 12)
    for _ in range(5):
        b.step()        # lo holds 2 of the 3 pages when hi arrives
    hi = b.submit(prompts[1], 3, priority=4)   # needs 2 pages up front
    out = b.run_until_done()
    preempted = b.scheduler.preemptions
    b.close()
    ref = _reference(cfg, params, [(lo, prompts[0], 12, None),
                                   (hi, prompts[1], 3, None)], max_len=32)
    assert out == ref
    assert preempted >= 1


def test_custom_policy_cannot_evict_same_plan_start(setup, rng):
    """A pathological policy whose may_preempt always consents must not
    hand the executor a request that is both started and preempted in
    one plan — same-plan starts are never victim candidates."""
    cfg, params = setup

    class EvictAnything(FCFSPolicy):
        name = "evict_anything"

        def may_preempt(self, incoming, victim):
            return True

    prompts = [list(rng.integers(0, cfg.vocab_size, 4)) for _ in range(2)]
    b = _batcher(cfg, params, max_slots=1, policy=EvictAnything())
    r0 = b.submit(prompts[0], 4)
    r1 = b.submit(prompts[1], 4)
    out = b.run_until_done()        # crashed before the candidate filter
    assert sorted(len(v) for v in out.values()) == [4, 4]
    b.close()
    ref = _reference(cfg, params, [(r0, prompts[0], 4, None),
                                   (r1, prompts[1], 4, None)])
    assert out == ref


def test_submit_priority_zero_overrides_request(setup, rng):
    """An explicit priority=0 demotes a prebuilt GenRequest; omitting it
    keeps the request's own priority."""
    from repro.serving.api import GenRequest
    cfg, params = setup
    p = list(rng.integers(0, cfg.vocab_size, 4))
    with LLM(cfg, params, max_slots=2, max_len=32,
             policy="priority") as llm:
        kept = llm.submit(GenRequest(p, 2, priority=7))
        demoted = llm.submit(GenRequest(p, 2, priority=7), priority=0)
        assert llm._batcher.requests[kept].priority == 7
        assert llm._batcher.requests[demoted].priority == 0
        llm.drain()


# ---------------------------------------------------------------------------
# AsyncLLM
# ---------------------------------------------------------------------------

def test_async_llm_streams_without_step(setup, rng):
    """The acceptance clause: AsyncLLM.stream() yields every token with
    no caller-driven step() anywhere, token-identical to the synchronous
    facade."""
    cfg, params = setup
    p = [list(rng.integers(0, cfg.vocab_size, n)) for n in (6, 4)]
    with LLM(cfg, params, max_slots=2, max_len=32, seed=0) as llm:
        r0 = llm.submit(p[0], 5)
        r1 = llm.submit(p[1], 5)
        ref = llm.drain()
        want = [ref[r0].tokens, ref[r1].tokens]
    with AsyncLLM(cfg, params, max_slots=2, max_len=32, seed=0) as allm:
        h = allm.submit(p[0], 5)
        got = list(allm.stream(p[1], 5))
        assert got == want[1]
        assert h.result(60).tokens == want[0]
        assert h.done


def test_async_llm_honors_gen_request_stream_callback(setup, rng):
    """A GenRequest's own per-token callback fires on the async front
    end too, alongside the handle's token queue."""
    from repro.serving.api import GenRequest
    cfg, params = setup
    p = list(rng.integers(0, cfg.vocab_size, 5))
    got = []
    with AsyncLLM(cfg, params, max_slots=2, max_len=32, seed=0) as allm:
        h = allm.submit(GenRequest(p, 4, stream=got.append))
        out = h.result(60)
    assert got == out.tokens and len(got) == 4


def test_async_llm_concurrent_submitters(setup, rng):
    """Many threads share one event loop; every handle resolves to the
    same tokens the facade produces for that rid."""
    cfg, params = setup
    p = [list(rng.integers(0, cfg.vocab_size, 3 + n)) for n in range(4)]
    with LLM(cfg, params, max_slots=2, max_len=32, seed=0) as llm:
        rids = [llm.submit(pi, 4) for pi in p]
        ref = llm.drain()
        want = {r: ref[r].tokens for r in rids}
    results = {}
    with AsyncLLM(cfg, params, max_slots=2, max_len=32, seed=0) as allm:
        def worker(pi):
            h = allm.submit(pi, 4)
            results[h.rid] = h.result(120).tokens
        ts = [threading.Thread(target=worker, args=(pi,)) for pi in p]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    # rids are assigned under the facade lock in submission order per
    # thread scheduling; compare as multisets of token streams
    assert sorted(results.values()) == sorted(want.values())


def test_async_llm_close_semantics(setup, rng):
    """close() drains by default; close(drain=False) abandons in-flight
    requests — their handles raise, new submits refuse, and iteration
    terminates instead of hanging."""
    cfg, params = setup
    p = list(rng.integers(0, cfg.vocab_size, 5))
    # drain=True: the default close finishes in-flight work
    allm = AsyncLLM(cfg, params, max_slots=1, max_len=64, seed=0)
    h = allm.submit(p, 6)
    allm.close()
    assert h.done and len(h.result().tokens) == 6
    allm.close()                                   # idempotent
    # drain=False: abandoned handles fail fast, iterators terminate
    allm = AsyncLLM(cfg, params, max_slots=1, max_len=64, seed=0)
    h2 = allm.submit(p, 50)
    it = iter(h2)
    allm.close(drain=False)
    with pytest.raises(RuntimeError, match="in flight"):
        h2.result()
    with pytest.raises(RuntimeError, match="in flight"):
        list(it)
    with pytest.raises(RuntimeError, match="closed"):
        allm.submit(p, 2)


def test_async_llm_surfaces_scheduler_stall(setup, rng):
    """A stalled pool fails the in-flight handles instead of wedging the
    loop thread."""
    cfg, params = setup
    with AsyncLLM(cfg, params, paged=True, page_size=8, n_pages=3,
                  max_slots=2, max_len=64, seed=0) as allm:
        h = allm.submit(list(rng.integers(0, cfg.vocab_size, 9)), 30)
        with pytest.raises(RuntimeError, match="stalled"):
            h.result(120)
        with pytest.raises(RuntimeError, match="loop failed"):
            allm.submit([1, 2, 3], 2)


def test_async_llm_priority_jumps_queue(setup, rng):
    """The event loop composes with scheduling policy: a high-priority
    submit overtakes earlier long requests (by queue-jumping or by
    preempting, depending on how far the loop got)."""
    cfg, params = setup
    p = [list(rng.integers(0, cfg.vocab_size, 5)) for _ in range(3)]
    with AsyncLLM(cfg, params, max_slots=1, max_len=64, seed=0,
                  policy="priority") as allm:
        hs = [allm.submit(p[0], 20), allm.submit(p[1], 20)]
        hi = allm.submit(p[2], 3, priority=9)
        out = hi.result(300)
        # 40 low-priority tokens cannot all be done when the 3-token
        # high-priority request returns: it overtook at least one
        assert not all(h.done for h in hs)
        assert len(out.tokens) == 3
        for h in hs:
            assert len(h.result(300).tokens) == 20


# ---------------------------------------------------------------------------
# cross-step prefetch overlap
# ---------------------------------------------------------------------------

def test_decode_step_prefetch_overlap(setup, rng):
    """Between a decode step's math and its sampling, the executor
    re-drives the engine's wrap-around prefetch ring: the next step's
    first module of every group is staged while the host tail drains."""
    cfg, params = setup
    hb = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0, batch=2)
    b = ContinuousBatcher(cfg, backend=hb, own_backend=True, max_slots=2,
                          max_len=32)
    b.submit(list(rng.integers(0, cfg.vocab_size, 5)), 4)
    b.submit(list(rng.integers(0, cfg.vocab_size, 7)), 4)
    steps = 0
    while b.queue or b.active.any():
        b.step()
        steps += 1
        eng = hb.engines["decode"]
        if eng.manager is not None and (b.queue or b.active.any()):
            # mid-serve, after the nudge: every group ring holds a staged
            # (or staging) module for the NEXT step even though no linear
            # is currently executing
            for ring in eng.manager.rings.values():
                assert any(s.name is not None for s in ring.slots)
    assert hb.step_prefetches == steps
    b.close()


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("chunk", [1, 5, 8, 64])
def test_chunked_prefill_token_identity(setup, rng, paged, chunk):
    """Chunked prefill is invisible in the tokens: the same requests run
    whole-shot and in chunks (dividing and non-dividing sizes, greedy and
    stochastic samplers) produce bit-identical outputs — chunking only
    reorders WHEN prompt KV is written, never what it contains."""
    cfg, params = setup
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (13, 7)]
    sps = [SamplingParams(),
           SamplingParams(kind="topp", top_p=0.9, temperature=1.3, seed=3)]
    b = _batcher(cfg, params, paged=paged, chunk_tokens=chunk)
    rids = [b.submit(p, 6, sampling=sp) for p, sp in zip(prompts, sps)]
    out = b.run_until_done()
    # only prompts longer than the chunk go through the chunked path
    assert b.scheduler.chunks_planned == sum(
        -(-len(p) // chunk) for p in prompts if len(p) > chunk)
    if paged:
        assert b.kv.free_pages == b.kv.usable_pages
    b.close()
    ref = _reference(cfg, params,
                     [(r, p, 6, sp)
                      for r, p, sp in zip(rids, prompts, sps)])
    assert out == ref


@pytest.mark.parametrize("paged", [False, True])
def test_chunked_admission_never_stalls_decode_tenant(setup, rng, paged):
    """The tentpole scenario: while a long prompt admits in chunks, a
    running decode tenant advances one token on EVERY step — a whole-shot
    admission would have processed the full prompt inside one step
    instead of interleaving."""
    cfg, params = setup
    b = _batcher(cfg, params, paged=paged, chunk_tokens=4, max_len=64)
    # tenant prompt <= chunk: admits whole-shot, decoding from step one
    tenant = b.submit(list(rng.integers(0, cfg.vocab_size, 4)), 30)
    b.step()                                   # tenant admitted + decoding
    long = b.submit(list(rng.integers(0, cfg.vocab_size, 33)), 4)
    chunk_steps = 0
    while b.requests[long].status != "running":
        before = len(b.requests[tenant].generated)
        b.step()
        chunk_steps += 1
        assert len(b.requests[tenant].generated) == before + 1
    assert chunk_steps >= 33 // 4              # the admission interleaved
    out = b.run_until_done()
    b.close()
    ref = _reference(cfg, params,
                     [(tenant, b.requests[tenant].prompt, 30, None),
                      (long, b.requests[long].prompt, 4, None)],
                     max_len=64)
    assert out == ref


def test_chunked_prefill_preempt_resume_token_identical(setup, rng):
    """A mid-prefill victim holds no sampled tokens, so recompute resume
    restarts its chunked prefill from the cursor's zero — and still
    matches the unpressured run bit for bit."""
    cfg, params = setup
    b = _batcher(cfg, params, paged=True, page_size=8, n_pages=7,
                 max_len=56, chunk_tokens=4, policy="priority",
                 prefix_dedupe=False)
    lo = b.submit(list(rng.integers(0, cfg.vocab_size, 25)), 4, priority=0)
    b.step()                                   # lo starts chunking
    assert b.requests[lo].status == "prefilling"
    hi = b.submit(list(rng.integers(0, cfg.vocab_size, 25)), 4, priority=5)
    out = b.run_until_done()
    assert b.requests[lo].preemptions >= 1     # evicted mid-prefill
    assert b.kv.free_pages == b.kv.usable_pages
    b.close()
    ref = _reference(cfg, params,
                     [(lo, b.requests[lo].prompt, 4, None),
                      (hi, b.requests[hi].prompt, 4, None)], max_len=56)
    assert out == ref


def test_prefix_dedupe_forks_shared_pages(setup, rng):
    """Admission-time prefix dedupe: a prompt sharing a page-aligned
    prefix with a resident request forks those pages (metadata only,
    ref-count bump) and prefills only the tail — tokens identical to the
    dedupe-off run, and the pool drains completely at the end."""
    cfg, params = setup
    shared = list(rng.integers(0, cfg.vocab_size, 20))
    tails = [[1, 2, 3], [4, 5]]

    def run(dedupe):
        b = _batcher(cfg, params, paged=True, page_size=8, max_len=48,
                     chunk_tokens=8, prefix_dedupe=dedupe)
        b.submit(shared + tails[0], 12, rid=0)
        for _ in range(4):
            b.step()                  # materialize the first prompt
        b.submit(shared + tails[1], 12, rid=1)
        out = b.run_until_done()
        hits, toks = b.scheduler.dedupe_hits, b.scheduler.dedupe_tokens
        assert b.kv.free_pages == b.kv.usable_pages
        b.close()
        return out, hits, toks

    out_on, hits, toks = run(True)
    out_off, no_hits, _ = run(False)
    assert out_on == out_off
    assert hits == 1 and toks == 16   # two full 8-token pages shared
    assert no_hits == 0


def test_batched_admission_one_prefill_call(setup, rng, monkeypatch):
    """Same-length fresh admissions in one plan run as ONE batched
    prefill call, not a batch-1 loop — and the tokens cannot tell."""
    cfg, params = setup
    prompts = [list(rng.integers(0, cfg.vocab_size, 9)) for _ in range(3)]
    sps = [SamplingParams(),
           SamplingParams(kind="topk", top_k=8, seed=11),
           SamplingParams()]
    for paged in (False, True):
        b = _batcher(cfg, params, max_slots=3, paged=paged)
        calls = []
        orig = b._start_batch
        monkeypatch.setattr(
            b, "_start_batch",
            lambda sts: (calls.append(len(sts)), orig(sts))[1])
        rids = [b.submit(p, 5, sampling=sp)
                for p, sp in zip(prompts, sps)]
        out = b.run_until_done()
        b.close()
        assert calls == [3]           # one call admitted all three
        ref = _reference(cfg, params,
                         [(r, p, 5, sp)
                          for r, p, sp in zip(rids, prompts, sps)])
        assert out == ref
