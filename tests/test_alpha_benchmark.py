"""The alpha benchmark recovers a planted equilibrium (Eq. 10-12)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.alpha_benchmark import probe_schedule, refine_alpha


@given(true_alpha=st.floats(0.1, 0.9), noise=st.floats(0, 0.005),
       quad=st.floats(0, 0.3))
@settings(max_examples=30, deadline=None)
def test_recovers_planted_equilibrium(true_alpha, noise, quad):
    """T_cpu(a) decreasing, T_com(a) increasing (with curvature + noise),
    crossing exactly at true_alpha: the fit must find it."""
    rng = np.random.default_rng(42)

    def t_cpu(a):
        base = (1 - a) + quad * (1 - a) ** 2
        return base + rng.normal(0, noise)

    def t_com(a):
        cross = (1 - true_alpha) + quad * (1 - true_alpha) ** 2
        return cross * a / true_alpha + rng.normal(0, noise)

    # start from a biased prior (the paper refines a misestimated alpha0)
    prior = min(max(true_alpha * 1.15, 0.02), 0.98)
    fit = refine_alpha(t_cpu, t_com, prior, gamma=0.2, lam=0.02)
    assert abs(fit.alpha - true_alpha) < 0.05 + 10 * noise


def test_probe_schedule_bounds():
    probes = probe_schedule(0.05, gamma=0.1, lam=0.02)
    assert all(0.0 <= p <= 1.0 for p in probes)
    assert len(probes) >= 3


def test_fit_result_fields():
    fit = refine_alpha(lambda a: 1 - a, lambda a: a, 0.4, gamma=0.1,
                       lam=0.05)
    assert abs(fit.alpha - 0.5) < 0.02
    assert fit.predicted_time > 0
    assert len(fit.probes) == len(fit.t_cpu) == len(fit.t_com)
