"""HeteGenEngine: split-linear exactness, stream stats, placement modes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HeteGenEngine, ModulePlan


def _engine(rng, modes, n_in=96, n_out=256):
    names = [f"m{i}" for i in range(len(modes))]
    W = {n: rng.standard_normal((n_in, n_out)).astype(np.float32)
         for n in names}
    plan = [ModulePlan(n, "g", mode, alpha)
            for n, (mode, alpha) in zip(names, modes)]
    return W, HeteGenEngine(W, plan)


@pytest.mark.parametrize("mode,alpha", [
    ("resident", 1.0), ("hetegen", 0.5), ("hetegen", 0.25),
    ("stream", 1.0), ("host", 0.0)])
def test_linear_exact_each_mode(rng, mode, alpha):
    W, eng = _engine(rng, [(mode, alpha)] * 3)
    eng.warm_prefetch()
    x = jnp.asarray(rng.standard_normal((4, 96)).astype(np.float32))
    for n in W:
        y = np.asarray(eng.linear(x, n))
        ref = np.asarray(x) @ W[n]
        np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-5)
    eng.close()


def test_bias_applied(rng):
    W = {"m0": rng.standard_normal((64, 128)).astype(np.float32)}
    b = {"m0": rng.standard_normal((128,)).astype(np.float32)}
    eng = HeteGenEngine(W, [ModulePlan("m0", "g", "hetegen", 0.5)], biases=b)
    x = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
    y = np.asarray(eng.linear(x, "m0"))
    np.testing.assert_allclose(y, np.asarray(x) @ W["m0"] + b["m0"],
                               rtol=1e-5, atol=1e-5)
    eng.close()


def test_alpha_quantization_to_tiles(rng):
    W, eng = _engine(rng, [("hetegen", 0.3)], n_out=512)
    # 0.3 * 512 = 153.6 -> nearest 128-tile = 128 cols on device
    assert eng._dev_cols["m0"] == 128
    eng.close()


def test_stream_stats_populated(rng):
    W, eng = _engine(rng, [("hetegen", 0.5)] * 4)
    eng.warm_prefetch()
    x = jnp.asarray(rng.standard_normal((2, 96)).astype(np.float32))
    for n in W:
        eng.linear(x, n)
    st = eng.finish_stats()
    assert st.cpu > 0 and st.dev > 0 and st.wall > 0
    assert st.pin > 0 and st.trans > 0
    eng.close()


def test_resident_bytes_accounting(rng):
    W, eng = _engine(rng, [("resident", 1.0), ("hetegen", 0.5)])
    assert eng.device_resident_bytes() == 96 * 256 * 4
    assert eng.pinned_overhead_bytes() > 0
    eng.close()
