"""The trip-count-aware HLO analyzer against known programs."""
import subprocess
import sys
import textwrap


def test_analyzer_on_known_program():
    """Subprocess (needs multi-device XLA flags before jax import):
    scanned matmul with known flops / collective bytes."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.analysis.hlo_cost import HloCostAnalyzer
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        L = 7
        def step(w, x):
            def body(c, _):
                return jnp.tanh(c @ w), ()
            c, _ = jax.lax.scan(body, x, None, length=L)
            return c.sum()
        ws = NamedSharding(mesh, P(None, "model"))
        xs = NamedSharding(mesh, P("data", None))
        w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
        c = jax.jit(step, in_shardings=(ws, xs),
                    out_shardings=NamedSharding(mesh, P())).lower(w, x
                    ).compile()
        rep = HloCostAnalyzer(c.as_text()).entry_cost()
        expect_flops = L * 2 * 32 * 256 * 64          # per device
        assert abs(rep.flops - expect_flops) / expect_flops < 0.01, rep.flops
        expect_ag = L * 32 * 256 * 4 * 3 / 4          # ring all-gather wire
        ag = rep.collective_bytes.get("all-gather", 0)
        assert abs(ag - expect_ag) / expect_ag < 0.01, ag
        print("ANALYZER_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "ANALYZER_OK" in r.stdout, r.stdout + r.stderr


def test_parse_tuple_types():
    from repro.analysis.hlo_cost import parse_hlo
    txt = """
ENTRY %main.1 (p0: f32[4,4]) -> f32[4,4] {
  %p0 = f32[4,4]{1,0} parameter(0)
  %w.1 = (s32[], f32[4,4]{1,0}, /*index=2*/f32[8]{0}) while(%t), condition=%c, body=%b
  ROOT %r = f32[4,4]{1,0} add(%p0, %p0)
}
"""
    comps = parse_hlo(txt)
    ops = [i.op for i in comps["main.1"].instructions]
    assert "while" in ops and "add" in ops


def test_ring_formulas():
    from repro.analysis.hlo_cost import HloCostAnalyzer
    txt = """
ENTRY %main.1 (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p0), replica_groups=[1,8]<=[8], to_apply=%add
}
"""
    rep = HloCostAnalyzer(txt).entry_cost()
    expect = 2 * 1024 * 4 * 7 / 8
    assert abs(rep.collective_bytes["all-reduce"] - expect) < 1


def test_trip_count_extraction():
    from repro.analysis.hlo_cost import Computation, Instruction, _trip_count, parse_hlo
    txt = """
%cond.1 (arg: (s32[], f32[2])) -> pred[] {
  %arg = (s32[], f32[2]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(40)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
"""
    comps = parse_hlo(txt)
    assert _trip_count(comps["cond.1"]) == 40
