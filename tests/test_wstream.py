"""Quantized weight streaming (int8 wire format) end to end.

The q8 wire format streams each offloaded column shard as an int8
payload plus fp32 per-output-column scales: pin rings shrink to the
compressed bytes, transfer spans carry wire (not compute) bytes, the
policy layer prices the link in wire bytes (alpha shifts toward the
device), and the device share dequantizes inside the matmul
(docs/ANALYSIS.md, docs/SERVING.md).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import HeteGenEngine, ModulePlan
from repro.core.alpha import alpha_analytic, effective_link_speed
from repro.core.hw import PAPER_A10
from repro.core.param_manager import entry_slot_bytes, entry_wire_bytes
from repro.core.policy import LinearSpec, build_policy
from repro.kernels.q8_matmul import quantize_weights, quantize_weights_np
from repro.models import model as M
from repro.serving.backends import HeteGenBackend, enumerate_linears
from repro.telemetry import Tracer, measured_speeds, recalibrate_alpha


@pytest.fixture(scope="module")
def opt_setup():
    # the smoke reduction shrinks d_model to 64, where one 128-column
    # tile swallows every module and alpha quantizes to 0/1 — widen the
    # linears so a 0.5 split is real and the q8 wire format streams
    cfg = dataclasses.replace(
        reduced(get_config("opt-125m"), layers=2),
        name="opt-wstream", d_model=256, n_heads=4, head_dim=64, d_ff=512)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _mini_engine(rng, wstream, tracer=None, n=3, shape=(96, 256), a=0.5):
    names = [f"m{i}" for i in range(n)]
    W = {nm: rng.standard_normal(shape).astype(np.float32) for nm in names}
    plan = [ModulePlan(nm, "g", "hetegen", a) for nm in names]
    kw = dict(tracer=tracer, trace_phase="decode") if tracer else {}
    return W, names, HeteGenEngine(W, plan, wstream=wstream, **kw)


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound(rng):
    """Symmetric per-column int8: dequant error <= scale/2 per element."""
    w = rng.standard_normal((64, 256)).astype(np.float32) * 3.0
    q, scale = quantize_weights_np(w)
    assert q.dtype == np.int8 and scale.dtype == np.float32
    assert scale.shape == (256,)
    err = np.abs(w - q.astype(np.float32) * scale)
    assert np.all(err <= scale[None, :] * 0.5 + 1e-6)
    # symmetric max scaling never clips: |q| reaches 127 but not beyond
    assert np.abs(q).max() == 127


def test_np_quantizer_bit_identical_to_jax(rng):
    """The load-time host quantizer IS the jax wire format."""
    w = rng.standard_normal((48, 128)).astype(np.float32)
    qn, sn = quantize_weights_np(w)
    qj, sj = quantize_weights(jnp.asarray(w))
    np.testing.assert_array_equal(qn, np.asarray(qj))
    np.testing.assert_array_equal(sn, np.asarray(sj))


def test_linear_spec_wire_bytes():
    s_fp = LinearSpec("m", 96, 256, "g", 4)
    s_q8 = LinearSpec("m", 96, 256, "g", 4, wire="q8")
    assert s_fp.wire_bytes == s_fp.nbytes == 96 * 256 * 4
    assert s_q8.nbytes == s_fp.nbytes            # compute bytes unchanged
    assert s_q8.wire_bytes == 96 * 256 + 4 * 256  # int8 payload + scales
    assert s_q8.wire_bytes < s_q8.nbytes


def test_engine_rejects_unknown_wstream(rng):
    with pytest.raises(ValueError, match="wire format"):
        _mini_engine(rng, "int4")


# ---------------------------------------------------------------------------
# compressed rings + wire-byte telemetry
# ---------------------------------------------------------------------------

def test_pin_rings_sized_to_wire_bytes(rng):
    _, _, eng_fp = _mini_engine(rng, "fp")
    _, names, eng_q8 = _mini_engine(rng, "q8")
    try:
        entry = eng_q8.manager.weights[names[0]]
        assert isinstance(entry, tuple) and len(entry) == 2
        q, scale = entry
        assert q.dtype == np.int8 and scale.dtype == np.float32
        # ring slots hold the compressed staging footprint, two per group
        assert eng_q8.pinned_overhead_bytes() == 2 * entry_slot_bytes(entry)
        assert eng_q8.pinned_overhead_bytes() < eng_fp.pinned_overhead_bytes()
    finally:
        eng_fp.close()
        eng_q8.close()


def test_transfer_spans_carry_wire_bytes(rng):
    """pin/transfer spans report the bytes that actually moved (wire),
    with fp_bytes preserving the compute equivalent — and the streamed
    trace still recalibrates."""
    tr = Tracer()
    _, names, eng = _mini_engine(rng, "q8", tracer=tr)
    eng.warm_prefetch()
    x = jnp.asarray(rng.standard_normal((2, 96)).astype(np.float32))
    for nm in names:
        eng.linear(x, nm)
    eng.close()

    entry = eng.manager.weights[names[0]]
    wire = entry_wire_bytes(entry)
    fp = eng._fp_shard_bytes[names[0]]
    assert wire < fp
    spans = tr.spans()
    for track in ("pin", "transfer"):
        ss = [s for s in spans if s.track == track]
        assert ss
        for s in ss:
            assert s.attrs["bytes"] == wire
            assert s.attrs["fp_bytes"] == fp
    est = measured_speeds(spans, phase="decode")
    assert est.wire_ratio == pytest.approx(wire / fp, rel=1e-9)
    fit = recalibrate_alpha(spans, 0.5, phase="decode")
    assert 0.0 <= fit.alpha <= 1.0


# ---------------------------------------------------------------------------
# policy: compression shifts alpha toward the device
# ---------------------------------------------------------------------------

def test_effective_link_speed():
    assert effective_link_speed(8e9, 0.25) == pytest.approx(32e9)
    assert effective_link_speed(8e9, 1.0) == 8e9
    with pytest.raises(ValueError):
        effective_link_speed(8e9, 0.0)
    # the shifted law: r < 1 strictly raises the analytic alpha
    a_fp = alpha_analytic(2e9, 50e9, 8e9)
    a_q8 = alpha_analytic(2e9, 50e9, effective_link_speed(8e9, 0.26))
    assert a_q8 > a_fp


@pytest.mark.parametrize("bench", [False, True])
def test_policy_alpha_increases_under_compression(opt_setup, bench):
    cfg, _ = opt_setup
    fp = build_policy(enumerate_linears(cfg, wstream="fp"), PAPER_A10,
                      batch=2, use_alpha_benchmark=bench)
    q8 = build_policy(enumerate_linears(cfg, wstream="q8"), PAPER_A10,
                      batch=2, use_alpha_benchmark=bench)
    assert fp.wstream == "fp" and q8.wstream == "q8"
    assert q8.alpha > fp.alpha
    # never slower: the link got cheaper (equal only if tile quantization
    # lands both plans on the same split AND the host share dominates)
    assert q8.predicted_step_time <= fp.predicted_step_time


# ---------------------------------------------------------------------------
# accuracy contract (docs/SERVING.md)
# ---------------------------------------------------------------------------

def test_q8_linear_error_bound(rng):
    """Per-linear: |y_q8 - y_fp| <= (scale_j / 2) * sum_k |x_k| on the
    device (streamed) columns; host columns are fp in both."""
    W, names, eng_fp = _mini_engine(rng, "fp", n=1)
    plan = [ModulePlan(nm, "g", "hetegen", 0.5) for nm in names]
    eng_q8 = HeteGenEngine(W, plan, wstream="q8")
    try:
        x = rng.standard_normal((4, 96)).astype(np.float32)
        xj = jnp.asarray(x)
        y_fp = np.asarray(eng_fp.linear(xj, names[0]))
        y_q8 = np.asarray(eng_q8.linear(xj, names[0]))
        cols = eng_q8._dev_cols[names[0]]
        assert cols == 128                       # 0.5 of 256, tile-aligned
        _, scale = eng_q8.manager.weights[names[0]]
        bound = 0.5 * np.abs(x).sum(axis=1)[:, None] * scale[None, :]
        err = np.abs(y_q8[:, :cols] - y_fp[:, :cols])
        assert np.all(err <= bound + 1e-3)
        # host partition never quantizes: bit-identical tail
        np.testing.assert_array_equal(y_q8[:, cols:], y_fp[:, cols:])
    finally:
        eng_fp.close()
        eng_q8.close()


def test_q8_executors_token_identical(opt_setup, rng):
    """The q8 contract across executors: dense/paged x one-shot/continuous
    all produce the same greedy tokens (quantization is deterministic, so
    executor choice must not leak into outputs)."""
    from repro.serving.api import LLM

    cfg, params = opt_setup
    hb = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                        batch=2, alpha_override=0.5, wstream="q8")
    prompts = [list(rng.integers(0, cfg.vocab_size, 6)) for _ in range(2)]
    runs = {}
    try:
        # the decode partition really streams quantized entries
        assert any(isinstance(e, tuple)
                   for e in hb.engines["decode"].manager.weights.values())
        for paged in (False, True):
            with LLM(cfg, backend=hb, own_backend=False, wstream="q8",
                     paged=paged, max_slots=2, max_len=64) as llm:
                outs = llm.generate(prompts, max_new=5)
                runs[f"oneshot_paged={paged}"] = [o.tokens for o in outs]
                rids = [llm.submit(p, 5) for p in prompts]
                outs = llm.drain()
                runs[f"cont_paged={paged}"] = [outs[r].tokens for r in rids]
    finally:
        hb.close()
    want = runs.pop("oneshot_paged=False")
    assert all(len(t) == 5 for t in want)
    for k, got in runs.items():
        assert got == want, k


def test_wstream_validation(opt_setup):
    from repro.serving.api import LLM

    cfg, params = opt_setup
    with pytest.raises(ValueError, match="wire format"):
        HeteGenBackend(cfg, params, wstream="fp8")
    # q8 needs a streaming backend
    with pytest.raises(ValueError, match="streaming backend"):
        LLM(cfg, params, wstream="q8")
    hb = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                        batch=1, wstream="fp")
    try:
        with pytest.raises(ValueError, match="conflicts"):
            LLM(cfg, backend=hb, own_backend=False, wstream="q8")
        with LLM(cfg, backend=hb, own_backend=False, wstream="fp",
                 max_slots=1, max_len=32) as llm:
            assert llm.stats()["wstream"] == "fp"
    finally:
        hb.close()


# ---------------------------------------------------------------------------
# verify-phase recalibration (PR 8 follow-up)
# ---------------------------------------------------------------------------

def test_verify_phase_recalibration(opt_setup, rng):
    """Verify-phase spans re-tune the verify plan through the same drift
    hysteresis as decode — even when no decode spans exist at all."""
    cfg, params = opt_setup
    tr = Tracer()
    hb = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                        batch=2, use_alpha_benchmark=False,
                        tracer=tr, recalibrate=1e-9, recalibrate_every=1)
    try:
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (2, 4)).astype(np.int32))
        cache = hb.init_cache(2, 16)
        # 1st verify: builds the verify plan (no measurable spans yet)
        cache, _ = hb.verify({"tokens": toks}, cache)
        a0 = hb.policies["verify"].alpha
        assert hb.recalibrations == 0
        # 2nd verify: the 1st call's verify-tagged spans drive the re-fit
        hb.verify({"tokens": toks}, cache)
        assert hb.recalibrations >= 1
        assert hb.last_fit is not None
        assert hb.policies["verify"].alpha == pytest.approx(
            hb.last_fit.alpha)
        assert hb.policies["verify"].alpha != a0
    finally:
        hb.close()
