"""Gain-ranked residency promotion (Eq. 13)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.module_scheduler import ModuleInfo, dynamic_range, schedule

mods = st.lists(
    st.tuples(st.floats(1e3, 1e9), st.floats(1e-6, 1.0),
              st.integers(1, 8)),
    min_size=1, max_size=40)


@given(mods=mods, budget=st.floats(0, 2e9))
def test_budget_never_exceeded(mods, budget):
    infos = [ModuleInfo(f"m{i}", b, t, c) for i, (b, t, c) in enumerate(mods)]
    plan = schedule(infos, budget)
    assert plan.used_bytes <= budget + 1e-6
    assert set(plan.resident) | set(plan.offloaded) == \
        {m.name for m in infos}
    assert not (set(plan.resident) & set(plan.offloaded))


@given(mods=mods)
def test_greedy_prefers_higher_gain(mods):
    infos = [ModuleInfo(f"m{i}", b, t, c) for i, (b, t, c) in enumerate(mods)]
    # budget fits exactly the single highest-gain module
    best = max(infos, key=lambda m: m.gain)
    plan = schedule(infos, best.mem_bytes)
    assert best.name in plan.resident


@given(mods=mods, budget=st.floats(1e3, 2e9))
def test_time_saved_matches_residents(mods, budget):
    infos = [ModuleInfo(f"m{i}", b, t, c) for i, (b, t, c) in enumerate(mods)]
    plan = schedule(infos, budget)
    by_name = {m.name: m for m in infos}
    expect = sum(by_name[n].t_cpu * by_name[n].calls for n in plan.resident)
    assert abs(plan.time_saved - expect) < 1e-6 * max(expect, 1)


def test_reuse_scales_gain():
    """A module called 7x/step (zamba2's shared block) outranks an
    identical single-call module."""
    a = ModuleInfo("shared", 1e6, 0.01, calls=7)
    b = ModuleInfo("plain", 1e6, 0.01, calls=1)
    assert a.gain > b.gain
    plan = schedule([a, b], 1e6)
    assert plan.resident == ["shared"]


def test_dynamic_range():
    infos = [ModuleInfo(f"m{i}", 1e6, 0.01) for i in range(10)]
    r = dynamic_range(infos, overhead_bytes=5e5)
    assert 0 < r["min_fraction"] < r["max_fraction"] <= 1.0
