"""Discrete-event simulator invariants + paper-qualitative behavior."""
import pytest
pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.hw import PAPER_A10
from repro.core.sim import SimModule, run_strategy, simulate_step, Placement


def _opt_modules(layers=8, d=4096, f=16384):
    mods = []
    for l in range(layers):
        mods.append(SimModule(f"l{l}.qkv", "linear", d * 3 * d * 2, 3 * d,
                              "attn", 2 * d * 3 * d))
        mods.append(SimModule(f"l{l}.attn", "attn_core", 0, 0, "attn",
                              4 * d * 512, cache_bytes=2 * d * 512 * 2))
        mods.append(SimModule(f"l{l}.o", "linear", d * d * 2, d, "attn",
                              2 * d * d))
        mods.append(SimModule(f"l{l}.up", "linear", d * f * 2, f, "mlp",
                              2 * d * f))
        mods.append(SimModule(f"l{l}.down", "linear", f * d * 2, d, "mlp",
                              2 * d * f))
    return mods


STRATS = ["resident", "naive_offload", "sync_offload", "hetegen_basic",
          "hetegen_pinned", "hetegen"]


@pytest.mark.parametrize("strategy", STRATS)
def test_utilization_bounded(strategy):
    r = run_strategy(_opt_modules(), strategy, PAPER_A10)
    for s, u in r.utilization.items():
        assert 0.0 <= u <= 1.0 + 1e-9, (s, u)
    assert r.step_time > 0


def test_strategy_ordering_matches_paper():
    """resident < hetegen < fig5b < fig5a-style < sync < naive (Fig. 5/8)."""
    t = {s: run_strategy(_opt_modules(), s, PAPER_A10).step_time
         for s in STRATS}
    assert t["resident"] < t["hetegen"] < t["hetegen_pinned"]
    assert t["hetegen"] < t["hetegen_basic"]
    assert t["hetegen"] < t["sync_offload"] < t["naive_offload"]


def test_hetegen_streams_busy():
    """Table 2: CPU and I/O near-fully utilized, pin below I/O, device ~idle."""
    r = run_strategy(_opt_modules(48, 7168, 28672), "hetegen", PAPER_A10)
    u = r.utilization
    assert u["cpu"] > 0.9
    assert u["trans"] > 0.9
    assert 0.4 < u["pin"] < u["trans"] + 1e-9
    assert u["dev"] < 0.2


def test_module_scheduler_monotone_in_budget():
    """More accelerator memory never slows HeteGen down (Fig. 8 x-axis)."""
    mods = _opt_modules()
    total = sum(m.nbytes for m in mods)
    times = []
    for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        r = run_strategy(mods, "hetegen", PAPER_A10,
                         gpu_mem_budget=frac * total * 1.1)
        times.append(r.step_time)
    assert all(a >= b - 1e-9 for a, b in zip(times, times[1:]))


@given(alpha=st.floats(0.02, 0.9))
@settings(max_examples=20, deadline=None)
def test_simulated_optimum_near_formula_alpha(alpha):
    """The analytic alpha* minimizes simulated latency among probes
    (within quantization granularity) — the sim validates Eq. 9."""
    from repro.core import alpha as A
    mods = _opt_modules(4)
    hw = PAPER_A10
    a_star = A.alpha_analytic(hw.v_cpu(1), hw.v_gpu(1), hw.v_com())

    def time_at(a):
        placements = {m.name: Placement("hetegen", a) if m.kind == "linear"
                      else Placement("resident") for m in mods}
        return simulate_step(mods, placements, hw).step_time

    assert time_at(a_star) <= time_at(alpha) * 1.02 + 1e-9


def test_ablation_ordering():
    """Table 3: full HeteGen >= each ablation."""
    mods = _opt_modules(16)
    full = run_strategy(mods, "hetegen", PAPER_A10).step_time
    no_hybrid = run_strategy(mods, "hetegen_pinned", PAPER_A10).step_time
    no_async = run_strategy(mods, "hetegen", PAPER_A10,
                            async_manager=False).step_time
    no_bench = run_strategy(mods, "hetegen", PAPER_A10,
                            use_alpha_benchmark=False).step_time
    assert full <= no_hybrid + 1e-9
    assert full <= no_async + 1e-9
    assert full <= no_bench + 1e-9
