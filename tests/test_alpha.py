"""Property tests (hypothesis) for HeteGen's distribution law (Eq. 4-9)."""
import math

import numpy as np
import pytest
pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import alpha as A

speeds = st.floats(min_value=1e6, max_value=1e15, allow_nan=False,
                   allow_infinity=False)


@given(v_cpu=speeds, v_gpu=speeds, v_com=speeds)
def test_alpha_in_unit_interval(v_cpu, v_gpu, v_com):
    a = A.alpha_analytic(v_cpu, v_gpu, v_com)
    assert 0.0 <= a <= 1.0


@given(v_cpu=speeds, v_gpu=speeds, v_com=speeds)
def test_alpha_balances_eq4(v_cpu, v_gpu, v_com):
    """Plugging alpha* back into Eq. 4 balances host and device sides."""
    a = A.alpha_analytic(v_cpu, v_gpu, v_com)
    r = A.balance_residual(a, v_cpu, v_gpu, v_com)
    scale = 1.0 / v_cpu + 1.0 / v_gpu + 1.0 / v_com
    assert abs(r) <= 1e-9 * scale * 10


@given(v_cpu=speeds, v_gpu=speeds, v_com=speeds, v_com2=speeds)
def test_alpha_monotone_in_link_speed(v_cpu, v_gpu, v_com, v_com2):
    """Faster link -> more work on the device."""
    lo, hi = sorted((v_com, v_com2))
    assert A.alpha_analytic(v_cpu, v_gpu, lo) <= \
        A.alpha_analytic(v_cpu, v_gpu, hi) + 1e-12


@given(v_cpu=speeds, v_gpu=speeds, v_com=speeds)
def test_alpha_approx_upper_bounds_exact(v_cpu, v_gpu, v_com):
    """Eq. 6 ignores device compute time, so it never assigns less to the
    device than the exact law."""
    assert A.alpha_approx(v_cpu, v_com) >= \
        A.alpha_analytic(v_cpu, v_gpu, v_com) - 1e-12


@given(t_cpu=st.floats(1e-6, 1e3), t_pin=st.floats(1e-6, 1e3),
       t_trans=st.floats(1e-6, 1e3))
def test_hybrid_uses_max_of_pin_trans(t_cpu, t_pin, t_trans):
    a = A.alpha_hybrid(t_cpu, t_pin, t_trans)
    assert a == A.alpha_from_times(t_cpu, max(t_pin, t_trans))
    # hybrid never slower than pin+trans serialized (Fig. 5b -> 5c)
    a_serial = A.alpha_from_times(t_cpu, t_pin + t_trans)
    assert a >= a_serial - 1e-12


@given(a=st.floats(0, 1), n=st.integers(1, 1 << 16))
def test_quantize_alpha_tile_aligned(a, n):
    q = A.quantize_alpha(a, n, tile=128)
    cols = round(q * n)
    assert 0 <= cols <= n
    assert cols % 128 == 0 or cols == n
    # quantization error bounded by one tile
    assert abs(q - a) * n <= 128 + 1e-6


@given(v_cpu=speeds, v_gpu=speeds, v_com=speeds,
       n=st.sampled_from([1024, 4096, 28672]))
def test_decide_consistency(v_cpu, v_gpu, v_com, n):
    d = A.decide(n, n * 4096 * 2, v_cpu=v_cpu, v_gpu=v_gpu, v_com=v_com)
    assert d.device_cols + d.host_cols == n
    assert 0 <= d.alpha <= 1


def test_paper_rig_alpha_regime():
    """On the paper's A10 rig the law sends most decode weight to the CPU
    (alpha well under 0.5) — the qualitative claim behind Fig. 1/3."""
    from repro.core.hw import PAPER_A10
    a = A.alpha_analytic(PAPER_A10.v_cpu(1.0), PAPER_A10.v_gpu(1.0),
                         PAPER_A10.v_com())
    assert 0.05 < a < 0.35
