"""The request-level serving front door (docs/SERVING.md): LLM facade,
per-request SamplingParams, streaming, executor invariance, and the
phase-aware placement plans behind the LinearBackend seam."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.hw import PAPER_A10
from repro.models import model as M
from repro.serving.api import LLM, GenRequest
from repro.serving.backends import HeteGenBackend, ResidentBackend
from repro.serving.batcher import ContinuousBatcher
from repro.serving.sampling import (SamplingParams, greedy, pack_sampling,
                                    sample_rows)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def opt_setup():
    cfg = reduced(get_config("opt-6.7b"), layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


# ---------------------------------------------------------------------------
# row-vectorized sampling
# ---------------------------------------------------------------------------

def test_sample_rows_mixed_kinds_honored(rng):
    """Every row obeys its own params: greedy/topk-1/tiny-topp rows equal
    argmax while a hot temperature row actually explores, topk rows stay
    inside their top-k set, topp rows inside their nucleus."""
    logits = jnp.asarray(rng.standard_normal((5, 64)) * 2, jnp.float32)
    packed = pack_sampling([
        SamplingParams(),
        SamplingParams(kind="topk", top_k=1),
        SamplingParams(kind="topp", top_p=1e-6),
        SamplingParams(kind="topk", top_k=5, temperature=3.0),
        SamplingParams(kind="temperature", temperature=3.0),
    ])
    ref = np.asarray(greedy(logits))
    top5 = set(np.asarray(jax.lax.top_k(logits[3], 5)[1]).tolist())
    seen3, seen4 = set(), set()
    for i in range(200):
        keys = jnp.stack([jax.random.PRNGKey(1000 + 7 * i + r)
                          for r in range(5)])
        out = np.asarray(sample_rows(logits, keys, packed))
        assert out[0] == ref[0] and out[1] == ref[1] and out[2] == ref[2]
        assert out[3] in top5
        seen3.add(int(out[3]))
        seen4.add(int(out[4]))
    assert len(seen3) > 1          # stochastic rows explore...
    assert len(seen4) > len(seen3)  # ...and unrestricted explores more


def test_sample_rows_logprobs_from_same_sort(rng):
    """top_logprobs rides the sampler's existing descending sort: chosen
    logprob is the raw log-softmax at the sampled token, alternatives are
    the k highest-logit tokens, and greedy rows' chosen == top-1."""
    logits = jnp.asarray(rng.standard_normal((3, 32)) * 2, jnp.float32)
    packed = pack_sampling([SamplingParams(),
                            SamplingParams(kind="temperature",
                                           temperature=2.0),
                            SamplingParams(kind="topk", top_k=4)])
    keys = jnp.stack([jax.random.PRNGKey(r) for r in range(3)])
    toks, info = sample_rows(logits, keys, packed, top_logprobs=3)
    ref_lp = np.asarray(jax.nn.log_softmax(logits, axis=-1))
    for r in range(3):
        assert info["logprob"][r] == pytest.approx(
            ref_lp[r, int(toks[r])], abs=1e-5)
        want_top = np.argsort(-np.asarray(logits[r]))[:3]
        assert np.array_equal(np.asarray(info["top_tokens"][r]), want_top)
        assert np.allclose(np.asarray(info["top_logprobs"][r]),
                           ref_lp[r, want_top], atol=1e-5)
    assert int(toks[0]) == int(info["top_tokens"][0, 0])   # greedy row
    # plain call shape is unchanged
    assert sample_rows(logits, keys, packed).shape == (3,)


def test_facade_logprobs_in_request_output(setup, rng):
    """SamplingParams.logprobs threads batcher -> RequestState ->
    RequestOutput: one aligned entry per generated token, trimmed to the
    request's own k, and mixed logprob/no-logprob batches coexist."""
    cfg, params = setup
    p = [list(rng.integers(0, cfg.vocab_size, n)) for n in (6, 6)]
    with LLM(cfg, params, max_slots=2, max_len=32, seed=0) as llm:
        plain = llm.generate([p[0]], max_new=4)[0]
        r0 = llm.submit(p[0], 4, sampling=SamplingParams(logprobs=2))
        r1 = llm.submit(p[1], 4)                 # no logprobs requested
        outs = llm.drain()
    lp = outs[r0].logprobs
    assert outs[r0].tokens == plain.tokens       # recording changes nothing
    assert outs[r1].logprobs is None
    assert len(lp) == 4
    for e, t in zip(lp, outs[r0].tokens):
        assert e["token"] == t and len(e["top"]) == 2
        # greedy: the sampled token IS the top-1 alternative
        assert e["logprob"] == pytest.approx(max(e["top"].values()))
        assert e["logprob"] <= 0.0 + 1e-6
    # a rectangular generate() with logprobs still runs (via the batcher)
    with LLM(cfg, params, max_slots=2, max_len=32, seed=0) as llm:
        outs = llm.generate(p, max_new=3,
                            sampling=SamplingParams(logprobs=0))
        assert llm.last_executor == "batcher"
        assert all(len(o.logprobs) == 3 and not o.logprobs[0]["top"]
                   for o in outs)


def test_sample_rows_row_independent(rng):
    """A row's draw depends only on its own logits and key — the property
    that makes paged compaction safe under stochastic sampling."""
    logits = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    sp = [SamplingParams(kind="topp", top_p=0.8, temperature=1.5)] * 4
    keys = jnp.stack([jax.random.PRNGKey(r) for r in (9, 1, 2, 3)])
    a = sample_rows(logits, keys, pack_sampling(sp))
    # same row 0 moved into a different batch, surrounded by other rows
    shuffled = jnp.concatenate([logits[:1], logits[::-1][:2]])
    b = sample_rows(shuffled, keys[:3], pack_sampling(sp[:3]))
    assert int(a[0]) == int(b[0])


# ---------------------------------------------------------------------------
# per-request sampling in the batcher
# ---------------------------------------------------------------------------

def _run_batcher(cfg, params, reqs, *, max_slots=2, paged=False, seed=0):
    b = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                          own_backend=True, max_slots=max_slots,
                          max_len=48, paged=paged, page_size=8, seed=seed)
    rids = [b.submit(p, n, sampling=sp, rid=rid)
            for rid, (p, n, sp) in enumerate(reqs)]
    out = b.run_until_done()
    b.close()
    return [out[r] for r in rids]


def test_mixed_samplers_one_batch_scheduling_invariant(setup, rng):
    """Greedy and stochastic requests share one decode batch, and each
    request's tokens are what it would have generated alone — per-request
    params and PRNG streams are honored regardless of co-tenants."""
    cfg, params = setup
    p0 = list(rng.integers(0, cfg.vocab_size, 6))
    p1 = list(rng.integers(0, cfg.vocab_size, 6))
    sp1 = SamplingParams(kind="topp", top_p=0.95, temperature=2.0, seed=13)
    mixed = _run_batcher(cfg, params, [(p0, 5, SamplingParams()),
                                       (p1, 5, sp1)])
    alone0 = _run_batcher(cfg, params, [(p0, 5, SamplingParams())])
    # the stochastic request keeps rid 1 so its key derivation matches
    b = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                          own_backend=True, max_slots=2, max_len=48)
    rid = b.submit(p1, 5, sampling=sp1, rid=1)
    alone1 = b.run_until_done()[rid]
    b.close()
    assert mixed[0] == alone0[0]
    assert mixed[1] == alone1


def test_paged_dense_token_identical_stochastic(setup, rng):
    """The PR-2 claim upgraded: with request-owned PRNG streams, paged
    compaction (which renumbers rows) is invisible to stochastic
    samplers — paged == dense token-for-token, not just in
    distribution."""
    cfg, params = setup
    reqs = []
    sps = [SamplingParams(kind="topp", top_p=0.9, temperature=1.3, seed=3),
           SamplingParams(),
           SamplingParams(kind="temperature", temperature=0.8),  # unseeded
           SamplingParams(kind="topk", top_k=8, temperature=1.5, seed=4)]
    for n, sp in zip((5, 9, 3, 7), sps):
        reqs.append((list(rng.integers(0, cfg.vocab_size, n)), 6, sp))
    dense = _run_batcher(cfg, params, reqs, seed=0)
    paged = _run_batcher(cfg, params, reqs, paged=True, seed=0)
    assert dense == paged


# ---------------------------------------------------------------------------
# the LLM facade
# ---------------------------------------------------------------------------

def test_facade_executor_selection_and_identity(setup, rng):
    """Rectangular batches run one-shot, ragged/streamed work runs through
    the batcher — and the executors are token-identical for the same
    requests (greedy AND seeded stochastic)."""
    cfg, params = setup
    p = [list(rng.integers(0, cfg.vocab_size, 7)) for _ in range(3)]
    sps = [SamplingParams(),
           SamplingParams(kind="topp", top_p=0.9, temperature=1.5, seed=5),
           SamplingParams(kind="topk", top_k=4, temperature=2.0, seed=6)]
    with LLM(cfg, params, max_slots=2, max_len=64, seed=0) as llm:
        one = llm.generate(p, max_new=5, sampling=sps)
        assert llm.last_executor == "generator"
    with LLM(cfg, params, max_slots=2, max_len=64, seed=0) as llm:
        # same requests, staggered: forced through the batcher
        rids = [llm.submit(pi, 5, sampling=sp) for pi, sp in zip(p, sps)]
        outs = llm.drain()
        assert llm.last_executor == "batcher"
        for o, rid in zip(one, rids):
            assert o.tokens == outs[rid].tokens


def test_facade_ragged_goes_to_batcher(setup, rng):
    cfg, params = setup
    p = [list(rng.integers(0, cfg.vocab_size, n)) for n in (4, 9)]
    with LLM(cfg, params, max_slots=2, max_len=64) as llm:
        outs = llm.generate(p, max_new=4)
        assert llm.last_executor == "batcher"
        assert [len(o.tokens) for o in outs] == [4, 4]


def test_facade_streaming_iterator_and_callback(setup, rng):
    cfg, params = setup
    p = list(rng.integers(0, cfg.vocab_size, 6))
    with LLM(cfg, params, max_slots=2, max_len=32) as llm:
        ref = llm.generate([p], max_new=5)[0]
        streamed = list(llm.stream(p, max_new=5))
        got = []
        llm.submit(p, 5, on_token=got.append)
        llm.drain()
    assert streamed == ref.tokens
    assert got == ref.tokens


def test_facade_eos_and_finish_reason(setup, rng):
    cfg, params = setup
    p = list(rng.integers(0, cfg.vocab_size, 6))
    with LLM(cfg, params, max_slots=2, max_len=32) as llm:
        ref = llm.generate([p], max_new=5)[0]
        eos = ref.tokens[1]
        one = llm.generate([p], max_new=5, eos=eos)[0]
        assert one.finish_reason == "eos"
        assert one.tokens == ref.tokens[:ref.tokens.index(eos) + 1]
        # batcher path stops at the same place
        rid = llm.submit(p, 5, eos=eos)
        out = llm.drain()[rid]
        assert out.tokens == one.tokens
        assert out.finish_reason == "eos"


def test_facade_gen_request_objects(setup, rng):
    cfg, params = setup
    p = list(rng.integers(0, cfg.vocab_size, 5))
    with LLM(cfg, params, max_slots=2, max_len=32) as llm:
        outs = llm.generate([GenRequest(p, 4),
                             GenRequest(p, 6)])   # ragged budgets
        assert llm.last_executor == "batcher"
        assert [len(o.tokens) for o in outs] == [4, 6]


def test_facade_paged_offload(setup, rng):
    """The full stack through one door: HeteGen backend + paged KV +
    mixed samplers, identical to the resident dense facade."""
    cfg, params = setup
    p = [list(rng.integers(0, cfg.vocab_size, n)) for n in (5, 8, 3)]
    sps = [SamplingParams(),
           SamplingParams(kind="topp", top_p=0.9, seed=2),
           SamplingParams(kind="temperature", temperature=0.7)]
    with LLM(cfg, params, max_slots=2, max_len=32, seed=0) as ref_llm:
        rids = [ref_llm.submit(pi, 4, sampling=sp)
                for pi, sp in zip(p, sps)]
        ref = ref_llm.drain()
        ref_toks = [ref[r].tokens for r in rids]
    hb = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0, batch=2)
    with LLM(cfg, backend=hb, own_backend=True, max_slots=2, max_len=32,
             paged=True, page_size=8, seed=0) as llm:
        rids = [llm.submit(pi, 4, sampling=sp) for pi, sp in zip(p, sps)]
        outs = llm.drain()
        assert [outs[r].tokens for r in rids] == ref_toks
    assert hb.engines == {}        # facade closed the owned backend


# ---------------------------------------------------------------------------
# phase-aware placement plans
# ---------------------------------------------------------------------------

def test_phase_plans_prefill_alpha_exceeds_decode(opt_setup, rng):
    """Paper §4.1 on a link-bound hw model: prefill is compute-bound so
    its plan pushes the split toward the accelerator (alpha -> 1), while
    the decode plan keeps the host GEMM busy.  The backend holds BOTH and
    executes prefill/decode under different engine partitions."""
    cfg, params = opt_setup
    be = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                        batch=2, use_alpha_benchmark=False)
    assert set(be.policies) == {"decode"}
    cache = be.init_cache(2, 80)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)),
                         jnp.int32)
    cache, logits = be.prefill({"tokens": prompt}, cache)
    assert set(be.policies) == {"prefill", "decode"}
    a_pre = be.policies["prefill"].alpha
    a_dec = be.policies["decode"].alpha
    assert a_pre > a_dec
    # the policy prior IS the phase-aware law
    from repro.core.alpha import alpha_for_phase
    assert a_pre == pytest.approx(
        alpha_for_phase(PAPER_A10, 2, "prefill", tokens_per_seq=64))
    assert a_dec == pytest.approx(alpha_for_phase(PAPER_A10, 2, "decode"))
    assert be.policies["prefill"].phase == "prefill"
    assert be.policies["prefill"].tokens_per_seq == 64
    assert be.policies["decode"].tokens_per_seq == 1
    # the partitions are physically different: more device columns for
    # the compute-bound prefill plan (tile quantization can pin the
    # narrow attention linears to 0 columns at this smoke scale, so look
    # across the whole inventory)
    pre, dec = be.engines["prefill"], be.engines["decode"]
    assert any(pre._dev_cols[n] > dec._dev_cols.get(n, 0)
               for n in pre._dev_cols)
    be.close()


def test_phase_plan_hysteresis(opt_setup, rng):
    """Prompt-length jitter must not rebuild the prefill partition; a
    phase change in workload shape must."""
    cfg, params = opt_setup
    be = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                        batch=1, use_alpha_benchmark=False)
    cache = be.init_cache(1, 300)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 64)), jnp.int32)
    be.prefill({"tokens": toks}, cache)
    plan = be.policies["prefill"]
    # jitter inside the 2x hysteresis band: same plan object survives
    cache2 = be.init_cache(1, 300)
    be.prefill({"tokens": toks[:, :40]}, cache2)
    assert be.policies["prefill"] is plan
    # 4x the tokens: outside the band, plan rebuilt for higher intensity
    cache3 = be.init_cache(1, 300)
    big = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 256)), jnp.int32)
    be.prefill({"tokens": big}, cache3)
    assert be.policies["prefill"] is not plan
    assert be.policies["prefill"].alpha >= plan.alpha
    be.close()


def test_phase_plans_do_not_change_tokens(opt_setup, rng):
    """Plan swapping is a performance decision: offloaded generation with
    per-phase partitions matches the resident path token-for-token."""
    cfg, params = opt_setup
    p = [list(rng.integers(0, cfg.vocab_size, 9)) for _ in range(2)]
    with LLM(cfg, params, seed=0) as ref:
        want = [o.tokens for o in ref.generate(p, max_new=5)]
    be = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0, batch=2)
    with LLM(cfg, backend=be, own_backend=True, seed=0) as llm:
        got = [o.tokens for o in llm.generate(p, max_new=5)]
        assert set(be.policies) == {"prefill", "decode"}
    assert got == want


def test_facade_drain_leaves_live_streams_alone(setup, rng):
    """A drain() interleaved with a suspended stream() iterator must not
    evict or report the stream's request — the iterator owns it."""
    cfg, params = setup
    p = list(rng.integers(0, cfg.vocab_size, 5))
    with LLM(cfg, params, max_slots=2, max_len=32) as llm:
        ref = llm.generate([p], max_new=4)[0]
        it = llm.stream(p, max_new=4)
        first = next(it)
        drained = llm.drain()           # runs the stream's request to done
        assert drained == {}            # ...but does not report it
        rest = list(it)                 # iterator still delivers the rest
        assert [first] + rest == ref.tokens
        assert llm._batcher.requests == {}   # iterator evicted on finish
        # submission is eager: a drain before the first next() already
        # runs the request, and the iterator still delivers every token
        it2 = llm.stream(p, max_new=4)
        assert llm.drain() == {}
        assert list(it2) == ref.tokens


def test_facade_drain_reports_each_request_once(setup, rng):
    """A long-lived facade must not re-report (or retain) old work:
    every drain returns exactly the requests that finished since the
    last report."""
    cfg, params = setup
    p = list(rng.integers(0, cfg.vocab_size, 5))
    with LLM(cfg, params, max_slots=2, max_len=32) as llm:
        r1 = llm.submit(p, 3)
        assert set(llm.drain()) == {r1}
        r2 = llm.submit(p, 3)
        assert set(llm.drain()) == {r2}     # r1 not re-reported
        assert llm._batcher.requests == {}  # books stay bounded


def test_facade_stall_detection_on_page_exhaustion(setup, rng):
    """A queued request that wants more pages than the whole pool holds
    can never run: the facade raises instead of spinning forever."""
    cfg, params = setup
    small = list(rng.integers(0, cfg.vocab_size, 4))
    huge = list(rng.integers(0, cfg.vocab_size, 20))
    with LLM(cfg, params, paged=True, page_size=8, n_pages=4,
             max_slots=2, max_len=64) as llm:
        with pytest.raises(RuntimeError, match="stalled"):
            # ragged batch -> batcher; the huge request needs 7 pages,
            # the pool holds 3
            llm.generate([GenRequest(small, 2), GenRequest(huge, 30)])
    with LLM(cfg, params, paged=True, page_size=8, n_pages=4,
             max_slots=2, max_len=64) as llm:
        llm.submit(huge, 30)
        with pytest.raises(RuntimeError, match="stalled"):
            llm.drain()


def test_batcher_close_owns_backend(setup):
    cfg, params = setup
    hb = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0, batch=2)
    with ContinuousBatcher(cfg, backend=hb, own_backend=True,
                           max_slots=2, max_len=32) as b:
        b.submit([1, 2, 3], 2)
        b.run_until_done()
    assert hb.engines == {}        # context exit closed the owned backend
    # not-owned backends survive their batcher
    hb2 = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0, batch=2)
    with ContinuousBatcher(cfg, backend=hb2, max_slots=2, max_len=32) as b:
        b.submit([1, 2, 3], 2)
        b.run_until_done()
    assert hb2.engines != {}
    hb2.close()


# ---------------------------------------------------------------------------
# tokenizer-aware text IO
# ---------------------------------------------------------------------------

def test_byte_tokenizer_roundtrip():
    from repro.serving.tokenizer import ByteTokenizer
    tok = ByteTokenizer()
    for s in ("hello", "héllo wörld", "καλημέρα", "🙂 ok"):
        ids = tok.encode(s)
        assert all(0 <= t <= 255 for t in ids)
        assert tok.decode(ids) == s
    # out-of-byte-range ids decode, not crash (models sample freely)
    assert tok.decode([104, 105, 400]) == "hi" + tok.decode([255])
    assert tok.eos_id == 0
    assert ByteTokenizer(eos_id=None).eos_id is None


def test_stream_decoder_holds_split_characters():
    from repro.serving.tokenizer import ByteTokenizer, StreamDecoder
    tok = ByteTokenizer()
    dec = StreamDecoder(tok)
    out = [dec.push(b) for b in tok.encode("a€b")]   # € is 3 bytes
    assert out == ["a", "", "", "€", "b"]
    assert dec.flush() == ""
    # an incomplete tail surfaces on flush instead of vanishing
    dec2 = StreamDecoder(tok)
    parts = [dec2.push(b) for b in tok.encode("€")[:2]]
    assert parts == ["", ""]
    assert dec2.flush() != ""


def test_facade_text_io_and_stream_text(setup, rng):
    """Text in, text out, through both the blocking and streaming paths;
    token-level results stay the source of truth underneath."""
    from repro.serving.tokenizer import ByteTokenizer
    cfg, params = setup
    tok = ByteTokenizer(eos_id=None)
    with LLM(cfg, params, max_slots=2, max_len=64, tokenizer=tok) as llm:
        out = llm.generate("abcabcabc", max_new=8)[0]
        assert out.prompt == tok.encode("abcabcabc")
        assert out.text == tok.decode(out.tokens)
        assert out.finish_reason == "length"
        chunks = list(llm.stream_text("abcabcabc", max_new=8))
        assert "".join(chunks) == out.text      # same request, same text
    # no tokenizer: text prompts are rejected, token IO is unchanged
    with LLM(cfg, params, max_slots=1, max_len=64) as llm:
        with pytest.raises(ValueError, match="tokenizer"):
            llm.generate("abc", max_new=4)
        out = llm.generate([[1, 2, 3]], max_new=4)[0]
        assert out.text is None


def test_facade_finish_reason_from_scheduler(setup, rng):
    """The batcher records WHY it finished a request; the facade reports
    that verdict rather than re-deriving it from the token tail."""
    cfg, params = setup
    prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, 8)]
    with LLM(cfg, params, max_slots=1, max_len=64) as llm:
        probe = llm.generate([prompt], max_new=6)[0]
        assert probe.finish_reason == "length"
        # now stop on the token the model actually emits mid-stream
        eos = probe.tokens[2]
        rid = llm.submit(prompt, 6, eos=eos)
        out = llm.drain()[rid]
    assert out.finish_reason == "eos"
    assert out.tokens[-1] == eos
