"""Sharded async checkpointing: roundtrip, atomicity, GC, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _state(key=0):
    k = jax.random.PRNGKey(key)
    return {"params": {"w": jax.random.normal(k, (16, 8)),
                       "b": jnp.zeros((8,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st = _state()
    mgr.save(7, st)
    back = mgr.restore(7, jax.tree.map(jnp.zeros_like, st))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


def test_async_save_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    for s in (1, 2, 3):
        mgr.save(s, _state(s))
    mgr.wait()
    assert mgr.list_steps() == [1, 2, 3]
    back = mgr.restore_latest(jax.tree.map(jnp.zeros_like, _state()))
    np.testing.assert_array_equal(back["step"], _state(3)["step"])


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in range(5):
        mgr.save(s, _state(s))
    assert mgr.list_steps() == [3, 4]


def test_no_partial_checkpoint_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _state())
    # a stale tmp dir must not be listed
    os.makedirs(os.path.join(str(tmp_path), "step_000000099.tmp"))
    assert mgr.list_steps() == [1]


def test_elastic_restore_from_shard_slices(tmp_path):
    """Manifest index ranges reassemble a DIFFERENT slicing on restore."""
    import json
    import shutil
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    mgr.save(1, {"w": w})
    # split the saved single shard into two half-shards, as if written by
    # two hosts of a previous topology
    d = os.path.join(str(tmp_path), "step_000000001")
    man = json.load(open(os.path.join(d, "manifest.json")))
    data = np.load(os.path.join(d, man["leaves"][0]["shards"][0]["file"]))
    np.save(os.path.join(d, "leaf_00000_shard_000.npy"), data[:4])
    np.save(os.path.join(d, "leaf_00000_shard_001.npy"), data[4:])
    man["leaves"][0]["shards"] = [
        {"file": "leaf_00000_shard_000.npy", "index": [[0, 4], [0, 8]]},
        {"file": "leaf_00000_shard_001.npy", "index": [[4, 8], [0, 8]]},
    ]
    json.dump(man, open(os.path.join(d, "manifest.json"), "w"))
    back = mgr.restore(1, {"w": jnp.zeros((8, 8), jnp.float32)})
    np.testing.assert_array_equal(back["w"], w)
