"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override is exclusively for launch/dryrun.py, which sets it
before importing jax). Distribution tests spawn subprocesses instead."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_cfg():
    from repro.configs import get_config
    return get_config("tiny")


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    import jax
    from repro.models import model as M
    return M.init_params(tiny_cfg, jax.random.PRNGKey(0))
