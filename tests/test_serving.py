"""Serving: generator loop, continuous batcher, samplers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving.batcher import ContinuousBatcher
from repro.serving.engine import Generator
from repro.serving.sampling import (SamplerConfig, greedy, make_sampler,
                                    topk_sample, topp_sample)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_generate_deterministic(setup, rng):
    cfg, params = setup
    g = Generator(cfg, params)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    r1 = g.generate({"tokens": prompt}, 5)
    r2 = g.generate({"tokens": prompt}, 5)
    assert r1.tokens == r2.tokens
    assert len(r1.tokens[0]) == 5


def test_batcher_matches_generator(setup, rng):
    cfg, params = setup
    prompt = rng.integers(0, cfg.vocab_size, (3, 8))
    g = Generator(cfg, params)
    ref = g.generate({"tokens": jnp.asarray(prompt, jnp.int32)}, 6)
    b = ContinuousBatcher(cfg, params, max_slots=2, max_len=64)
    rids = [b.submit(list(prompt[i]), 6) for i in range(3)]
    outs = b.run_until_done()
    for i, rid in enumerate(rids):
        assert outs[rid] == ref.tokens[i], i


def test_batcher_staggered_join(setup, rng):
    """A request joining mid-flight decodes correctly (per-slot lens)."""
    cfg, params = setup
    prompts = rng.integers(0, cfg.vocab_size, (2, 8))
    g = Generator(cfg, params)
    ref0 = g.generate({"tokens": jnp.asarray(prompts[:1], jnp.int32)}, 8)
    ref1 = g.generate({"tokens": jnp.asarray(prompts[1:], jnp.int32)}, 4)
    b = ContinuousBatcher(cfg, params, max_slots=2, max_len=64)
    r0 = b.submit(list(prompts[0]), 8)
    b.step(); b.step(); b.step()
    r1 = b.submit(list(prompts[1]), 4)
    outs = b.run_until_done()
    assert outs[r0] == ref0.tokens[0]
    assert outs[r1] == ref1.tokens[0]


def test_samplers(rng):
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray(rng.standard_normal((4, 64)) * 3, jnp.float32)
    assert greedy(logits).shape == (4,)
    t1 = topk_sample(logits, key, k=1)
    np.testing.assert_array_equal(t1, greedy(logits))  # top-1 == greedy
    tp = topp_sample(logits, key, p=1e-6)
    np.testing.assert_array_equal(tp, greedy(logits))  # tiny p == greedy
    for kind in ("greedy", "temperature", "topk", "topp"):
        s = make_sampler(SamplerConfig(kind=kind))
        out = s(logits, key)
        assert out.shape == (4,) and out.dtype == jnp.int32
        assert (out >= 0).all() and (out < 64).all()
