"""Per-arch smoke tests on reduced configs: one forward/train step on CPU,
shape + finiteness checks, and prefill+decode teacher-forcing exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import model as M


def _batch_for(cfg, rng, B=2, S=24):
    batch = {}
    if cfg.embeds_input:
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
        batch.setdefault("tokens", jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward(arch, rng):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, rng)
    logits = M.forward_train(cfg, params, batch)
    B = 2
    assert logits.shape == (B, 24, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch, rng):
    from repro.train.loop import TrainConfig, make_train_step, init_state
    from repro.train.optimizer import OptimizerConfig
    cfg = reduced(get_config(arch))
    tcfg = TrainConfig(accum_steps=1, optimizer=OptimizerConfig(lr=1e-3))
    state = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    step, _ = make_train_step(cfg, tcfg)
    batch = _batch_for(cfg, rng, B=2, S=16)
    batch["labels"] = jnp.zeros((2, 16), jnp.int32) if "tokens" not in batch \
        else batch["tokens"]
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_teacher_forcing(arch, rng):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, S, S0 = 2, 24, 16
    batch = _batch_for(cfg, rng, B, S)
    full = M.forward_train(cfg, params, batch)
    cache = M.init_cache(cfg, B, S)
    pb = dict(batch)
    for key in ("tokens", "embeds"):
        if key in pb:
            pb[key] = pb[key][:, :S0]
    cache, logits = M.prefill(cfg, params, pb, cache)
    scale = float(jnp.abs(full).max()) + 1e-6
    assert float(jnp.abs(logits - full[:, S0 - 1]).max()) / scale < 3e-5
    if cfg.embeds_input:
        return
    toks = batch["tokens"]
    for t in range(S0, S):
        cache, logits = M.decode_step(cfg, params, toks[:, t], cache)
        err = float(jnp.abs(logits - full[:, t]).max()) / scale
        assert err < 3e-5, (arch, t, err)


def test_param_counts_match_published():
    expect = {
        "llama4-maverick-400b-a17b": (400e9, 0.10),
        "llama4-scout-17b-16e": (109e9, 0.05),
        "nemotron-4-340b": (340e9, 0.02),
        "gemma2-2b": (2.6e9, 0.05),
        "mistral-nemo-12b": (12.2e9, 0.02),
        "minicpm3-4b": (4.0e9, 0.05),
        "llava-next-mistral-7b": (7.2e9, 0.02),
        "whisper-small": (0.24e9, 0.15),
        "zamba2-1.2b": (1.2e9, 0.05),
        "mamba2-2.7b": (2.7e9, 0.03),
    }
    for arch, (n, tol) in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < tol, (arch, got, n)


def test_moe_active_params():
    cfg = get_config("llama4-scout-17b-16e")
    active = cfg.active_param_count()
    assert 15e9 < active < 19e9            # "17B active"
    mav = get_config("llama4-maverick-400b-a17b")
    assert mav.active_param_count() < 0.06 * mav.param_count()


def test_long_context_eligibility():
    assert get_config("mamba2-2.7b").sub_quadratic
    assert get_config("zamba2-1.2b").sub_quadratic
    for a in ASSIGNED_ARCHS:
        if a not in ("mamba2-2.7b", "zamba2-1.2b"):
            assert not get_config(a).sub_quadratic, a
