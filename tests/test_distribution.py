"""Distribution: dry-run on a tiny mesh in a subprocess (the 512-device
override must not leak into this test process), spec derivation rules."""
import json
import os
import subprocess
import sys

import pytest


def test_specs_param_rules():
    import jax
    from repro.configs import get_config, reduced
    from repro.distributed.shardings import ShardingRules
    from repro.distributed import specs as SP
    from repro.models import model as M

    cfg = get_config("mistral-nemo-12b")
    rules = ShardingRules(
        table=ShardingRules().table,
        mesh_axes=("data", "model"),
        mesh_shape={"data": 16, "model": 16})
    pshape = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    pspec = SP.param_specs(cfg, rules, pshape)
    flat = {jax.tree_util.keystr(kp): v for kp, v in
            jax.tree_util.tree_flatten_with_path(pspec)[0]}
    wq = [v for k, v in flat.items() if k.endswith("['wq']")][0]
    assert wq[-1] == "model"                       # column-parallel
    wo = [v for k, v in flat.items() if k.endswith("['wo']")][0]
    assert wo[-2] == "model"                       # row-parallel
    emb = flat["['embed']"]
    assert emb[0] == "model"                       # vocab-sharded


def test_fsdp_2d_sharding():
    import jax
    from repro.configs import get_config
    from repro.distributed.shardings import ShardingRules
    from repro.distributed import specs as SP
    from repro.models import model as M

    cfg = get_config("nemotron-4-340b")
    assert cfg.fsdp
    rules = ShardingRules(
        table=ShardingRules().table, mesh_axes=("data", "model"),
        mesh_shape={"data": 16, "model": 16})
    pshape = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    pspec = SP.param_specs(cfg, rules, pshape)
    flat = {jax.tree_util.keystr(kp): v for kp, v in
            jax.tree_util.tree_flatten_with_path(pspec)[0]}
    w_in = [v for k, v in flat.items() if k.endswith("['w_in']")][0]
    assert w_in[-2] == "data" and w_in[-1] == "model"   # 2D sharded


def test_rules_divisibility_guard():
    from repro.distributed.shardings import ShardingRules
    rules = ShardingRules(
        table=ShardingRules().table, mesh_axes=("data", "model"),
        mesh_shape={"data": 16, "model": 16})
    # 8 kv heads cannot shard 16 ways -> replicated
    spec = rules.spec_for_shape((2, 128, 8, 64),
                                "batch", None, "kv_heads", None)
    assert spec[2] is None
    # batch 2 can't take data 16 either
    assert spec[0] is None


def test_rules_conflict_resolution():
    from repro.distributed.shardings import ShardingRules
    rules = ShardingRules(
        table={**ShardingRules().table, "seq": ("model",)},
        mesh_axes=("data", "model"),
        mesh_shape={"data": 16, "model": 16})
    spec = rules.spec_for_shape((32, 4096, 64, 128),
                                "batch", "seq", "heads", None)
    assert spec[2] == "model" and spec[1] is None  # heads win over seq


@pytest.mark.slow
def test_tiny_mesh_dryrun_subprocess(tmp_path):
    """Full dryrun machinery on a 2x2 mesh with a reduced config."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, json
from repro.configs import get_config, reduced, register
from repro.launch.mesh import make_mesh
from repro.launch import dryrun as DR
cfg = reduced(get_config("mistral-nemo-12b"))
register(cfg)
mesh = make_mesh((2, 2), ("data", "model"))
fn, inputs, in_sh, out_sh, donate, meta = DR.build_cell(cfg, "decode_32k",
                                                        mesh)
from repro.models import model as M
pshape = jax.eval_shape(lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))
import repro.configs.shapes as SH
ins = SH.input_specs(cfg, "decode_32k", batch_override=4)
from repro.distributed import specs as SP
from repro.distributed.shardings import ShardingRules
rules = ShardingRules.for_mesh(mesh)
cspec = SP.named(mesh, SP.cache_specs(cfg, rules, ins["cache"]))
tspec = SP.named(mesh, SP.batch_specs(cfg, rules, ins["token"]))
pspec = SP.named(mesh, SP.param_specs(cfg, rules, pshape))
from repro.serving.engine import make_serve_step
step = make_serve_step(cfg, rules)
c = jax.jit(step, in_shardings=(pspec, tspec, cspec),
            out_shardings=(cspec, tspec)).lower(
    pshape, ins["token"], ins["cache"]).compile()
print("MEM", c.memory_analysis().temp_size_in_bytes)
from repro.analysis.hlo_cost import HloCostAnalyzer
rep = HloCostAnalyzer(c.as_text(), max_bytes_per_elem=2).entry_cost()
assert rep.flops > 0
print("TINY_DRYRUN_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "TINY_DRYRUN_OK" in r.stdout, r.stdout[-500:] + r.stderr[-2000:]
