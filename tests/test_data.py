"""Data pipeline: determinism, packing, prefetch, learnability."""
import numpy as np

from repro.data.pipeline import (ByteTokenizer, PackedLMDataset, Prefetcher,
                                 synthetic_corpus)


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "HeteGen: héllo ✓"
    assert tok.decode(tok.encode(s)) == s


def test_corpus_deterministic():
    a = synthetic_corpus(8, vocab=100, seed=3)
    b = synthetic_corpus(8, vocab=100, seed=3)
    assert all((x == y).all() for x, y in zip(a, b))
    assert all((0 <= d).all() and (d < 100).all() for d in a)


def test_packing_labels_shifted():
    docs = synthetic_corpus(16, vocab=50, seed=0)
    ds = PackedLMDataset(docs, batch=4, seq=32)
    b = next(iter(ds))
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetcher_yields_everything():
    items = [{"i": np.asarray(i)} for i in range(10)]
    out = list(Prefetcher(iter(items), depth=3))
    assert [int(x["i"]) for x in out] == list(range(10))


def test_motif_structure_is_learnable():
    """Within-motif bigrams repeat: conditional entropy well below uniform."""
    docs = synthetic_corpus(64, vocab=200, seed=1, motif_len=8, n_motifs=8)
    stream = np.concatenate(docs)
    pairs = {}
    for a, b in zip(stream[:-1], stream[1:]):
        pairs.setdefault(int(a), []).append(int(b))
    # most tokens have a dominant successor
    dom = [max(np.bincount(v).max() / len(v) for _ in [0])
           for v in pairs.values() if len(v) > 10]
    assert np.mean(dom) > 0.5
