"""Fault tolerance: stragglers, retry, preemption, elastic topology, and
kill/restore/resume-identical training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.fault_tolerance import (ElasticTopology,
                                               PreemptionHandler,
                                               StragglerDetector, retry)


def test_straggler_detection():
    sd = StragglerDetector()
    for _ in range(5):
        for h in range(8):
            sd.update(f"h{h}", 1.0 + (2.5 if h == 3 else 0.0))
    assert sd.stragglers() == ["h3"]
    assert sd.fleet_summary()["stragglers"] == 1


def test_straggler_needs_warmup():
    sd = StragglerDetector(warmup=3)
    sd.update("a", 1.0); sd.update("b", 9.0)
    assert sd.stragglers() == []           # single sample: no verdict


def test_retry_recovers():
    calls = {"n": 0}
    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return 42
    assert retry(flaky, attempts=3) == 42


def test_retry_exhausts():
    with pytest.raises(RuntimeError):
        retry(lambda: (_ for _ in ()).throw(RuntimeError("x")).__next__(),
              attempts=2)


def test_preemption_flag():
    h = PreemptionHandler(install=False)
    assert not h.triggered
    h.trigger()
    assert h.triggered
    h.reset()
    assert not h.triggered


@pytest.mark.parametrize("n,expect", [(8, (2, 4)), (6, (3, 2)), (4, (1, 4)),
                                      (3, (3, 1))])
def test_elastic_topology(n, expect):
    et = ElasticTopology(model_parallel=4)
    c = et.choose(n)
    assert c.shape == expect
    assert c.devices_used == expect[0] * expect[1] <= n


def test_kill_restore_resume_identical(tmp_path, rng):
    """Train 6 steps; separately train 3, 'crash', restore, train 3 more:
    final params identical (deterministic data + state restore)."""
    from repro.configs import get_config
    from repro.train.loop import TrainConfig, Trainer
    from repro.train.optimizer import OptimizerConfig

    cfg = get_config("tiny")
    tcfg = TrainConfig(accum_steps=1,
                       optimizer=OptimizerConfig(lr=1e-2), warmup=2)

    def batches():
        r = np.random.default_rng(7)
        while True:
            t = r.integers(0, cfg.vocab_size, (4, 32)).astype(np.int32)
            yield {"tokens": jnp.asarray(t[:, :-1]),
                   "labels": jnp.asarray(t[:, 1:])}

    t_all = Trainer(cfg, tcfg, checkpoint_dir=str(tmp_path / "a"),
                    checkpoint_every=3, async_checkpoint=False)
    gen = batches()
    t_all.run(gen, 6)

    t1 = Trainer(cfg, tcfg, checkpoint_dir=str(tmp_path / "b"),
                 checkpoint_every=3, async_checkpoint=False)
    gen2 = batches()
    t1.run(gen2, 3)
    del t1                                  # "crash"
    t2 = Trainer(cfg, tcfg, checkpoint_dir=str(tmp_path / "b"),
                 checkpoint_every=3, async_checkpoint=False)
    assert t2.step == 3                     # resumed from the checkpoint
    t2.run(gen2, 3)                         # gen2 continues at batch 4

    # compare final params (exactly: same inputs, same state path)
    for a, b in zip(jax.tree.leaves(t_all.state["params"]),
                    jax.tree.leaves(t2.state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_preemption_checkpoints_immediately(tmp_path):
    from repro.configs import get_config
    from repro.train.loop import TrainConfig, Trainer
    from repro.train.optimizer import OptimizerConfig
    cfg = get_config("tiny")
    tcfg = TrainConfig(accum_steps=1, optimizer=OptimizerConfig(lr=1e-3))
    tr = Trainer(cfg, tcfg, checkpoint_dir=str(tmp_path),
                 checkpoint_every=1000, async_checkpoint=False)

    def batches():
        while True:
            yield {"tokens": jnp.zeros((2, 16), jnp.int32),
                   "labels": jnp.zeros((2, 16), jnp.int32)}

    tr.preemption.trigger()
    tr.run(batches(), 5)
    assert tr.ckpt.list_steps() == [1]      # stopped + saved at step 1
