"""Heterogeneous speculative decoding (docs/SERVING.md): CPU-side
drafting, batched verification over the paged KV cache, and rejection
sampling that leaves the output distribution untouched.

The contract under test: greedy speculative decoding is *token-identical*
to the non-speculative baseline across dense/paged caches, chunked
admission, and mid-speculation preemption; stochastic acceptance keeps
the emitted marginal exactly the request's filtered sampling
distribution; and a draft-less row inside a verify step draws
bitwise-identically to a plain decode step (so speculation on one tenant
can never perturb another).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving.api import LLM
from repro.serving.backends import ResidentBackend
from repro.serving.batcher import ContinuousBatcher
from repro.serving.sampling import (SamplingParams, pack_sampling,
                                    request_key, sample_rows, step_key)
from repro.serving.speculative import (AdaptiveK, ModelDrafter, NgramDrafter,
                                       SpecConfig, SpecStats, accept_row,
                                       filtered_probs)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, submits, *, spec=None, max_slots=2, max_len=64,
         **kw):
    """Run (rid, prompt, max_new, sampling) submits to completion;
    returns ({rid: tokens}, batcher-stats-or-None)."""
    b = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                          own_backend=True, max_slots=max_slots,
                          max_len=max_len, spec=spec, **kw)
    for rid, p, n, sp in submits:
        b.submit(p, n, sampling=sp, rid=rid)
    out = {rid: list(t) for rid, t in b.run_until_done().items()}
    stats = b.spec_stats if spec is not None else None
    b.close()
    return out, stats


def _repetitive(rng, vocab, length, period=4):
    motif = [int(t) for t in rng.integers(1, vocab, period)]
    return (motif * length)[:length]


# ---------------------------------------------------------------------------
# drafters as pure functions
# ---------------------------------------------------------------------------

def test_ngram_drafter_lookup():
    d = NgramDrafter(max_ngram=3)
    # newest trigram [2,3,4] recurs: propose its continuation
    assert d.propose(0, [1, 2, 3, 4, 9, 1, 2, 3, 4], 3) == [9, 1, 2]
    # k caps the continuation
    assert d.propose(0, [1, 2, 3, 4, 9, 1, 2, 3, 4], 1) == [9]
    # nothing recurs: no proposal (falls back to plain decode)
    assert d.propose(0, [1, 2, 3, 4, 5, 6], 4) == []
    assert d.propose(0, [], 4) == []
    assert d.propose(0, [1, 2], 0) == []


def test_ngram_drafter_prefers_longest_then_most_recent():
    d = NgramDrafter(max_ngram=2)
    # bigram [1,2] occurs twice earlier; the most recent match (followed
    # by 8) must win over the older one (followed by 7)
    assert d.propose(0, [1, 2, 7, 1, 2, 8, 1, 2], 1) == [8]
    # longest n wins: unigram [5] matches, but bigram [2,5] also matches
    # with a different continuation
    toks = [2, 5, 6, 5, 9, 2, 5]
    assert d.propose(0, toks, 1) == [6]         # via bigram [2,5]
    d1 = NgramDrafter(max_ngram=1)
    assert d1.propose(0, toks, 1) == [9]        # unigram sees newest 5
    with pytest.raises(ValueError):
        NgramDrafter(max_ngram=0)


def test_adaptive_k_controller():
    ak = AdaptiveK(4, k_min=2, k_max=6)
    assert ak.k_for(0) == 4
    ak.update(0, 4, 4)                  # full acceptance: grow
    assert ak.k_for(0) == 5
    ak.update(0, 5, 5)
    ak.update(0, 6, 6)                  # capped at k_max
    assert ak.k_for(0) == 6
    ak.update(0, 6, 2)                  # < half survived: shrink
    assert ak.k_for(0) == 5
    ak.update(0, 5, 3)                  # middling: hold
    assert ak.k_for(0) == 5
    for _ in range(10):
        ak.update(0, 5, 0)              # floored at k_min
    assert ak.k_for(0) == 2
    ak.update(1, 0, 0)                  # draft-less step: no-op
    assert ak.k_for(1) == 4
    ak.release(0)
    assert ak.k_for(0) == 4


def test_spec_config_validation():
    with pytest.raises(ValueError):
        SpecConfig(drafter=NgramDrafter(), k=0)
    with pytest.raises(ValueError):
        SpecConfig(drafter=NgramDrafter(), k=2, k_min=3, k_max=2)
    st = SpecStats()
    st.record(4, 2)
    st.record(0, 0)                     # draft-less steps don't count
    assert st.as_dict() == {"steps": 1, "drafted": 4, "accepted": 2,
                            "rolled_back": 2, "acceptance_rate": 0.5}


# ---------------------------------------------------------------------------
# the host mirror of the device sampler's filter
# ---------------------------------------------------------------------------

def test_filtered_probs_supports_exactly_the_sampler(rng):
    """filtered_probs' support must equal the set of tokens sample_rows
    can emit: many seeded device draws all land inside the support, and
    every strictly-positive mode keeps more than the argmax."""
    logits = np.asarray(rng.standard_normal(64) * 2, np.float32)
    for params in (SamplingParams(kind="topk", top_k=5, temperature=2.0),
                   SamplingParams(kind="topp", top_p=0.7, temperature=2.0),
                   SamplingParams(kind="temperature", temperature=3.0)):
        p = filtered_probs(logits, params)
        assert p.shape == (64,)
        assert abs(p.sum() - 1.0) < 1e-5
        assert p[int(np.argmax(logits))] > 0          # argmax always kept
        if params.kind == "topk":
            assert (p > 0).sum() <= params.top_k
        n = 128
        keys = jnp.stack([jax.random.PRNGKey(10_000 + i) for i in range(n)])
        draws = np.asarray(sample_rows(
            jnp.tile(jnp.asarray(logits)[None], (n, 1)), keys,
            pack_sampling([params] * n)))
        assert set(draws.tolist()) <= set(np.flatnonzero(p > 0).tolist())


def test_accept_row_marginal_matches_filtered_probs(rng):
    """The rejection-sampling marginal: over many request keys, the first
    token accept_row emits is distributed as filtered_probs — whether the
    draft was the mode (mostly accepted) or a tail token (mostly
    rejected and resampled)."""
    logits = np.asarray(rng.standard_normal(16), np.float32)
    params = SamplingParams(kind="temperature", temperature=3.0)
    p = filtered_probs(logits, params)
    rows = np.stack([logits, logits])           # bonus row is irrelevant
    for draft in (int(np.argmax(p)), int(np.argmin(p))):
        counts = np.zeros(16)
        n = 600
        for i in range(n):
            key = request_key(jax.random.PRNGKey(3), i, params)
            out = accept_row(rows, [draft], params, key, 0)
            counts[out[0]] += 1
        tv = 0.5 * np.abs(counts / n - p).sum()
        assert tv < 0.11, (draft, tv)


def test_accept_row_greedy_is_argmax_chain(rng):
    rows = np.asarray(rng.standard_normal((4, 32)), np.float32)
    arg = [int(np.argmax(r)) for r in rows]
    key = request_key(jax.random.PRNGKey(0), 0, SamplingParams())
    # all drafts match: every argmax plus the bonus argmax
    assert accept_row(rows, arg[:3], SamplingParams(), key, 0) == arg
    # first mismatch cuts the run and emits the correction
    wrong = [arg[0], (arg[1] + 1) % 32, arg[2]]
    assert accept_row(rows, wrong, SamplingParams(), key, 0) == arg[:2]


# ---------------------------------------------------------------------------
# greedy identity: dense / paged / chunked admission
# ---------------------------------------------------------------------------

def _greedy_submits(rng, cfg, n=3, plen=12, max_new=10):
    subs = []
    for rid in range(n):
        subs.append((rid, _repetitive(rng, cfg.vocab_size, plen, 3 + rid),
                     max_new, SamplingParams()))
    return subs


def test_spec_greedy_token_identical_dense(setup, rng):
    cfg, params = setup
    subs = _greedy_submits(rng, cfg)
    base, _ = _run(cfg, params, subs)
    spec = SpecConfig(drafter=NgramDrafter(), k=4)
    out, stats = _run(cfg, params, subs, spec=spec)
    assert out == base
    assert stats.drafted > 0 and stats.accepted > 0


def test_spec_greedy_token_identical_paged(setup, rng):
    cfg, params = setup
    subs = _greedy_submits(rng, cfg)
    base, _ = _run(cfg, params, subs)
    spec = SpecConfig(drafter=NgramDrafter(), k=4)
    out, stats = _run(cfg, params, subs, spec=spec, paged=True, page_size=8)
    assert out == base
    assert stats.accepted > 0


def test_spec_greedy_token_identical_chunked_admission(setup, rng):
    """A long prompt admitted in chunks, then speculated over: the
    chunked-prefill scheduler path and the verify path compose without
    perturbing tokens."""
    cfg, params = setup
    subs = [(0, _repetitive(rng, cfg.vocab_size, 30, 3), 10,
             SamplingParams()),
            (1, _repetitive(rng, cfg.vocab_size, 8, 4), 10,
             SamplingParams())]
    base, _ = _run(cfg, params, subs, max_len=64)
    spec = SpecConfig(drafter=NgramDrafter(), k=4)
    out, stats = _run(cfg, params, subs, spec=spec, paged=True, page_size=8,
                      chunk_tokens=8, max_len=64)
    assert out == base
    assert stats.accepted > 0


def test_spec_adaptive_k_identical_and_bounded(setup, rng):
    cfg, params = setup
    subs = _greedy_submits(rng, cfg, n=2)
    base, _ = _run(cfg, params, subs)
    spec = SpecConfig(drafter=NgramDrafter(), k=2, adaptive=True,
                      k_min=1, k_max=5)
    out, stats = _run(cfg, params, subs, spec=spec)
    assert out == base                  # adaptation never changes tokens
    assert stats.drafted > 0


# ---------------------------------------------------------------------------
# preemption mid-speculation
# ---------------------------------------------------------------------------

def test_spec_preempt_resume_token_identical(setup, rng):
    """A page pool too small for both tenants forces preempt/resume in
    the middle of speculative runs; deterministic re-drafting on resume
    keeps every request token-identical to the unpressured baseline."""
    cfg, params = setup
    subs = _greedy_submits(rng, cfg, n=3, plen=10, max_new=12)
    base, _ = _run(cfg, params, subs, max_slots=3, max_len=64)
    spec = SpecConfig(drafter=NgramDrafter(), k=4)
    b = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                          own_backend=True, max_slots=3, max_len=64,
                          paged=True, page_size=8, n_pages=9,
                          spec=spec)
    for rid, p, n, sp in subs:
        b.submit(p, n, sampling=sp, rid=rid)
    out = {rid: list(t) for rid, t in b.run_until_done().items()}
    preemptions = b.scheduler.preemptions
    b.close()
    assert out == base
    assert preemptions > 0              # the squeeze actually happened


# ---------------------------------------------------------------------------
# speculation on one tenant cannot perturb another
# ---------------------------------------------------------------------------

class _OnlyRid:
    """Wrap a drafter so only one request ever gets drafts: the other
    rides the verify batch as a draft-less row."""

    def __init__(self, inner, rid):
        self.inner, self.rid = inner, rid

    def propose(self, rid, tokens, k):
        return self.inner.propose(rid, tokens, k) if rid == self.rid else []

    def release(self, rid):
        self.inner.release(rid)

    def close(self):
        self.inner.close()


def test_spec_draftless_row_bitwise_stochastic(setup, rng):
    """A stochastic tenant that never drafts shares verify steps with a
    speculating neighbor; its bonus draw rides sample_rows with the plain
    step key, so its tokens are bitwise the baseline's."""
    cfg, params = setup
    sto = SamplingParams(kind="temperature", temperature=2.0)
    subs = [(0, _repetitive(rng, cfg.vocab_size, 12, 3), 10,
             SamplingParams()),
            (1, [int(t) for t in rng.integers(1, cfg.vocab_size, 9)], 10,
             sto)]
    base, _ = _run(cfg, params, subs)
    spec = SpecConfig(drafter=_OnlyRid(NgramDrafter(), 0), k=4)
    for kw in ({}, {"paged": True, "page_size": 8}):
        out, stats = _run(cfg, params, subs, spec=spec, **kw)
        assert out[1] == base[1]        # stochastic tenant: bitwise
        assert out[0] == base[0]        # greedy tenant: argmax chain
        assert stats.accepted > 0


# ---------------------------------------------------------------------------
# rejection / rollback under a hot sampler
# ---------------------------------------------------------------------------

class _ConstDrafter:
    """Always proposes the same run — drafting quality is irrelevant when
    the test targets the rejection/rollback machinery itself."""

    def __init__(self, run):
        self.run = list(run)

    def propose(self, rid, tokens, k):
        return self.run[:k]

    def release(self, rid):
        pass

    def close(self):
        pass


def test_spec_rollback_truncates_and_finishes(setup, rng):
    """At a temperature hot enough to reject almost every draft, the KV
    rollback path (dense len reset, paged truncate) runs and every
    request still finishes with exactly its budget."""
    cfg, params = setup
    hot = SamplingParams(kind="temperature", temperature=25.0)
    subs = [(rid, _repetitive(rng, cfg.vocab_size, 12, 3), 8, hot)
            for rid in range(2)]
    spec = SpecConfig(drafter=_ConstDrafter([1, 2, 3]), k=3)
    for kw in ({}, {"paged": True, "page_size": 8}):
        out, stats = _run(cfg, params, subs, spec=spec, **kw)
        assert all(len(t) == 8 for t in out.values())
        assert stats.rolled_back > 0
        assert stats.drafted == stats.accepted + stats.rolled_back


# ---------------------------------------------------------------------------
# the model drafter
# ---------------------------------------------------------------------------

def test_model_drafter_self_draft_identity(setup, rng):
    """Drafting with the target model itself: every greedy draft is the
    target's own argmax, so acceptance is total and output identical."""
    cfg, params = setup
    subs = _greedy_submits(rng, cfg, n=2, plen=8, max_new=8)
    base, _ = _run(cfg, params, subs)
    drafter = ModelDrafter(cfg, params, max_len=64)
    spec = SpecConfig(drafter=drafter, k=3)
    out, stats = _run(cfg, params, subs, spec=spec, paged=True, page_size=8)
    assert out == base
    assert stats.drafted > 0
    assert stats.acceptance_rate == 1.0


def test_model_drafter_reconciles_after_rejection(setup, rng):
    """Rejected speculation leaves the drafter's private cache ahead of
    the request's real history; the LCP reconciliation re-feeds only the
    divergent tail and keeps proposing."""
    cfg, params = setup
    hot = SamplingParams(kind="temperature", temperature=25.0)
    subs = [(0, _repetitive(rng, cfg.vocab_size, 10, 3), 8, hot)]
    drafter = ModelDrafter(cfg, params, max_len=64)
    spec = SpecConfig(drafter=drafter, k=3)
    out, stats = _run(cfg, params, subs, spec=spec)
    assert len(out[0]) == 8
    assert stats.rolled_back > 0        # rejections actually happened
    assert not drafter._fed             # released on finish


# ---------------------------------------------------------------------------
# the facade: stats, finish_reason, eos mid-run
# ---------------------------------------------------------------------------

def test_facade_spec_stats_and_acceptance(setup, rng):
    cfg, params = setup
    prompts = [_repetitive(rng, cfg.vocab_size, 12, 3) for _ in range(2)]
    spec = SpecConfig(drafter=NgramDrafter(), k=4)
    with LLM(cfg, params, max_slots=2, max_len=64, paged=True,
             page_size=8, spec=spec) as llm:
        outs = llm.generate(prompts, max_new=10)
        assert llm.last_executor == "batcher"   # spec never runs one-shot
        st = llm.stats()["spec"]
    assert st["drafted"] > 0 and st["accepted"] > 0
    assert st["acceptance_rate"] > 0
    assert st["drafted"] == st["accepted"] + st["rolled_back"]
    assert set(st["per_request"]) == {o.rid for o in outs}
    assert all(o.finish_reason == "length" for o in outs)


def test_facade_spec_eos_mid_draft(setup, rng):
    """An eos token emitted in the middle of an accepted draft run cuts
    the output there and reports finish_reason='eos'."""
    cfg, params = setup
    prompt = _repetitive(rng, cfg.vocab_size, 12, 3)
    with LLM(cfg, params, max_slots=1, max_len=64) as llm:
        base = llm.generate([prompt], max_new=10)[0].tokens
    assert len(base) == 10
    eos = base[5]                       # force a stop mid-stream
    spec = SpecConfig(drafter=NgramDrafter(), k=4)
    with LLM(cfg, params, max_slots=1, max_len=64, spec=spec) as llm:
        out = llm.generate([prompt], max_new=10, eos=eos)[0]
    assert out.finish_reason == "eos"
    assert out.tokens == base[:base.index(eos) + 1]
