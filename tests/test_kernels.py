"""Per-kernel validation: interpret=True vs the pure-jnp oracles in
kernels/ref.py, swept over shapes and dtypes."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

ops.set_mode("interpret")


def _rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 384),
                                   (128, 256, 512)])
@pytest.mark.parametrize("act", [None, "relu", "relu2", "gelu", "silu"])
def test_matmul_shapes_acts(rng, m, k, n, act):
    x, w = _rand(rng, (m, k)), _rand(rng, (k, n))
    b = _rand(rng, (n,))
    got = ops.matmul(x, w, b, activation=act)
    want = ref.matmul(x, w, b, activation=act)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(rng, dtype):
    x = _rand(rng, (128, 128)).astype(dtype)
    w = _rand(rng, (128, 128)).astype(dtype)
    got = ops.matmul(x, w)
    want = ref.matmul(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_gated_matmul(rng):
    x, wg, wu = _rand(rng, (128, 256)), _rand(rng, (256, 256)), \
        _rand(rng, (256, 256))
    got = ops.gated_matmul(x, wg, wu)
    # silu amplifies blocked-K accumulation differences at large |gate|
    np.testing.assert_allclose(got, ref.gated_matmul(x, wg, wu),
                               rtol=2e-3, atol=2e-3)


def test_q8_matmul(rng):
    x, w = _rand(rng, (128, 256)), _rand(rng, (256, 384))
    q, s = ops.quantize_weights(w)
    got = ops.q8_matmul(x, q, s)
    want = ref.q8_matmul(x, q, s)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    # and dequantized result approximates the fp matmul within quant error
    full = np.asarray(x) @ np.asarray(w)
    assert np.abs(np.asarray(got) - full).max() / np.abs(full).max() < 0.05


@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None), (False, None, None),
    (True, 64, None), (True, None, 20.0)])
def test_flash_attention(rng, causal, window, softcap):
    b, hq, hkv, s, d = 2, 4, 2, 256, 64
    q = _rand(rng, (b, hq, s, d))
    k = _rand(rng, (b, hkv, s, d))
    v = _rand(rng, (b, hkv, s, d))
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=64, block_kv=64)
    want = ref.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
def test_flash_attention_gqa_ratios(rng, hq, hkv):
    b, s, d = 1, 128, 32
    q = _rand(rng, (b, hq, s, d))
    k = _rand(rng, (b, hkv, s, d))
    v = _rand(rng, (b, hkv, s, d))
    got = ops.flash_attention(q, k, v, block_q=64, block_kv=64)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_decode_attention(rng):
    b, hq, hkv, s, d = 3, 4, 2, 256, 64
    q = _rand(rng, (b, hq, d))
    k = _rand(rng, (b, hkv, s, d))
    v = _rand(rng, (b, hkv, s, d))
    kv_len = jnp.asarray([17, 100, 256], jnp.int32)
    got = ops.decode_attention(q, k, v, kv_len, block_kv=64)
    want = ref.decode_attention(q, k, v, kv_len)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_flash_last_row(rng):
    """decode(q_last) == flash(full)[last] when kv_len == s."""
    b, hq, hkv, s, d = 1, 4, 2, 128, 32
    q = _rand(rng, (b, hq, s, d))
    k = _rand(rng, (b, hkv, s, d))
    v = _rand(rng, (b, hkv, s, d))
    full = ref.flash_attention(q, k, v, causal=True)
    got = ops.decode_attention(q[:, :, -1], k, v,
                               jnp.asarray([s], jnp.int32), block_kv=64)
    np.testing.assert_allclose(got, full[:, :, -1], rtol=2e-5, atol=2e-5)


def _paged_pool(rng, k, v, page_size):
    """Scatter dense (B, Hkv, S, D) K/V into a permuted page pool +
    block tables (page 0 left as trash)."""
    b, hkv, s, d = k.shape
    nb = s // page_size
    n_pages = 1 + b * nb
    perm = rng.permutation(np.arange(1, n_pages))
    bt = np.zeros((b, nb), np.int32)
    kp = np.zeros((n_pages, hkv, page_size, d), np.asarray(k).dtype)
    vp = np.zeros_like(kp)
    for i in range(b):
        for j in range(nb):
            pid = int(perm[i * nb + j])
            bt[i, j] = pid
            kp[pid] = np.asarray(k[i, :, j * page_size:(j + 1) * page_size])
            vp[pid] = np.asarray(v[i, :, j * page_size:(j + 1) * page_size])
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt)


@pytest.mark.parametrize("hq,hkv", [(4, 2), (8, 1), (4, 4)])
def test_paged_decode_attention(rng, hq, hkv):
    """Paged kernel == paged oracle == dense decode oracle: gathering K/V
    through a permuted block table changes nothing but the layout."""
    b, s, d, ps = 3, 256, 64, 16
    q = _rand(rng, (b, hq, d))
    k = _rand(rng, (b, hkv, s, d))
    v = _rand(rng, (b, hkv, s, d))
    kv_len = jnp.asarray([17, 100, 256], jnp.int32)
    kp, vp, bt = _paged_pool(rng, k, v, ps)
    want_dense = ref.decode_attention(q, k, v, kv_len)
    want = ref.paged_decode_attention(q, kp, vp, bt, kv_len)
    got = ops.paged_decode_attention(q, kp, vp, bt, kv_len)
    np.testing.assert_allclose(want, want_dense, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_paged_decode_attention_softcap(rng):
    b, hq, hkv, s, d, ps = 2, 4, 2, 128, 32, 16
    q = _rand(rng, (b, hq, d))
    k = _rand(rng, (b, hkv, s, d))
    v = _rand(rng, (b, hkv, s, d))
    kv_len = jnp.asarray([50, 128], jnp.int32)
    kp, vp, bt = _paged_pool(rng, k, v, ps)
    got = ops.paged_decode_attention(q, kp, vp, bt, kv_len, softcap=20.0)
    want = ref.paged_decode_attention(q, kp, vp, bt, kv_len, softcap=20.0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_paged_decode_attention_q8(rng):
    """int8 pages with per-(page, head, token) scales dequantize in the
    kernel body exactly as the q8 oracle does after gathering."""
    b, hq, hkv, s, d, ps = 2, 4, 2, 128, 32, 16
    n_pages = 1 + b * (s // ps)
    q = _rand(rng, (b, hq, d))
    k8 = jnp.asarray(rng.integers(-127, 127, (n_pages, hkv, ps, d)),
                     jnp.int8)
    v8 = jnp.asarray(rng.integers(-127, 127, (n_pages, hkv, ps, d)),
                     jnp.int8)
    ks = jnp.abs(_rand(rng, (n_pages, hkv, ps))) * 0.01
    vs = jnp.abs(_rand(rng, (n_pages, hkv, ps))) * 0.01
    bt = jnp.asarray(rng.permutation(np.arange(1, n_pages)).reshape(b, -1),
                     jnp.int32)
    kv_len = jnp.asarray([37, 128], jnp.int32)
    got = ops.paged_decode_attention(q, k8, v8, bt, kv_len,
                                     k_scale=ks, v_scale=vs)
    want = ref.paged_decode_attention(q, k8, v8, bt, kv_len,
                                      k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("hq,hkv", [(4, 2), (8, 1), (4, 4)])
def test_paged_prefill_attention(rng, hq, hkv):
    """Whole-shot paged prefill (kv_offset 0) == paged oracle == dense
    causal flash attention: block-table indirection changes layout only."""
    b, s, d, ps = 2, 128, 64, 16
    q = _rand(rng, (b, hq, s, d))
    k = _rand(rng, (b, hkv, s, d))
    v = _rand(rng, (b, hkv, s, d))
    kp, vp, bt = _paged_pool(rng, k, v, ps)
    offs = jnp.zeros((b,), jnp.int32)
    want_dense = ref.flash_attention(q, k, v, causal=True)
    want = ref.paged_prefill_attention(q, kp, vp, bt, offs)
    got = ops.paged_prefill_attention(q, kp, vp, bt, offs, block_q=32)
    np.testing.assert_allclose(want, want_dense, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_paged_prefill_attention_chunk_offsets(rng):
    """A chunk starting mid-sequence (per-row kv_offset, non-dividing
    length) attends to every previously-written page plus its own causal
    triangle — matching rows [off, off+s) of dense full-sequence flash."""
    b, hq, hkv, t, d, ps, s = 2, 4, 2, 160, 32, 16, 37
    q_full = _rand(rng, (b, hq, t, d))
    k = _rand(rng, (b, hkv, t, d))
    v = _rand(rng, (b, hkv, t, d))
    kp, vp, bt = _paged_pool(rng, k, v, ps)
    offs = jnp.asarray([40, 103], jnp.int32)     # page-unaligned second row
    q = jnp.stack([q_full[i, :, int(o):int(o) + s]
                   for i, o in enumerate(offs)])
    full = ref.flash_attention(q_full, k, v, causal=True)
    want_rows = jnp.stack([full[i, :, int(o):int(o) + s]
                           for i, o in enumerate(offs)])
    want = ref.paged_prefill_attention(q, kp, vp, bt, offs)
    got = ops.paged_prefill_attention(q, kp, vp, bt, offs, block_q=32)
    np.testing.assert_allclose(want, want_rows, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_paged_prefill_attention_trash_tail(rng):
    """Pages past the chunk's last position hold garbage (the trash page
    and never-written pool rows) — the causal mask must exclude them, so
    corrupting them cannot change the output."""
    b, hq, hkv, t, d, ps, s = 1, 4, 2, 128, 32, 16, 21
    q = _rand(rng, (b, hq, s, d))
    k = _rand(rng, (b, hkv, t, d))
    v = _rand(rng, (b, hkv, t, d))
    kp, vp, bt = _paged_pool(rng, k, v, ps)
    offs = jnp.asarray([30], jnp.int32)
    base = ops.paged_prefill_attention(q, kp, vp, bt, offs, block_q=32)
    # poison everything past kv_len = off + s
    end_page = -(-int(offs[0] + s) // ps)
    poison_ids = np.asarray(bt)[0, end_page:]
    kp2 = np.array(kp)
    vp2 = np.array(vp)
    kp2[poison_ids] = np.nan
    vp2[poison_ids] = np.nan
    got = ops.paged_prefill_attention(q, jnp.asarray(kp2), jnp.asarray(vp2),
                                      bt, offs, block_q=32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


@pytest.mark.parametrize("window,softcap", [(24, None), (None, 20.0)])
def test_paged_prefill_attention_window_softcap(rng, window, softcap):
    b, hq, hkv, t, d, ps, s = 2, 4, 2, 128, 32, 16, 32
    q = _rand(rng, (b, hq, s, d))
    k = _rand(rng, (b, hkv, t, d))
    v = _rand(rng, (b, hkv, t, d))
    kp, vp, bt = _paged_pool(rng, k, v, ps)
    offs = jnp.asarray([0, 77], jnp.int32)
    got = ops.paged_prefill_attention(q, kp, vp, bt, offs, window=window,
                                      softcap=softcap, block_q=32)
    want = ref.paged_prefill_attention(q, kp, vp, bt, offs, window=window,
                                       softcap=softcap)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_paged_prefill_attention_q8(rng):
    """int8 pages with per-(page, head, token) scales dequantize in the
    kernel body exactly as the q8 oracle does after gathering."""
    b, hq, hkv, t, d, ps, s = 2, 4, 2, 128, 32, 16, 19
    n_pages = 1 + b * (t // ps)
    q = _rand(rng, (b, hq, s, d))
    k8 = jnp.asarray(rng.integers(-127, 127, (n_pages, hkv, ps, d)),
                     jnp.int8)
    v8 = jnp.asarray(rng.integers(-127, 127, (n_pages, hkv, ps, d)),
                     jnp.int8)
    ks = jnp.abs(_rand(rng, (n_pages, hkv, ps))) * 0.01
    vs = jnp.abs(_rand(rng, (n_pages, hkv, ps))) * 0.01
    bt = jnp.asarray(rng.permutation(np.arange(1, n_pages)).reshape(b, -1),
                     jnp.int32)
    offs = jnp.asarray([16, 55], jnp.int32)
    got = ops.paged_prefill_attention(q, k8, v8, bt, offs,
                                      k_scale=ks, v_scale=vs, block_q=32)
    want = ref.paged_prefill_attention(q, k8, v8, bt, offs,
                                       k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [(4, 128), (2, 33, 128), (3, 5, 7, 256)])
@pytest.mark.parametrize("plus_one", [False, True])
def test_rmsnorm(rng, shape, plus_one):
    x = _rand(rng, shape)
    s = _rand(rng, (shape[-1],))
    got = ops.rmsnorm(x, s, plus_one=plus_one)
    want = ref.rmsnorm(x, s, plus_one=plus_one)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ssd_chunk_vs_oracle_and_recurrent(rng):
    from repro.models.ssm import ssd_recurrent
    b, l, h, p, n, ch = 2, 64, 3, 16, 8, 16
    x = _rand(rng, (b, l, h, p))
    dt = jnp.abs(_rand(rng, (b, l, h))) * 0.5
    a = -jnp.abs(_rand(rng, (h,))) * 0.5
    bm = _rand(rng, (b, l, h, n))
    cm = _rand(rng, (b, l, h, n))
    y_k, sc_k, cum_k = ops.ssd_chunk(x, dt, a, bm, cm, chunk=ch)
    y_r, sc_r, cum_r = ref.ssd_chunk(x, dt, a, bm, cm, chunk=ch)
    np.testing.assert_allclose(y_k, y_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(sc_k, sc_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(cum_k, cum_r, rtol=2e-5, atol=2e-5)
