"""repro.telemetry: tracer, metrics registry, Chrome export, overlap
math, and trace-driven alpha recalibration (docs/OBSERVABILITY.md)."""
import json
import threading

import numpy as np
import pytest

from repro.core.engine import StreamStats
from repro.telemetry import (MetricsRegistry, NULL_TRACER, OverlapReport,
                             Span, Tracer, as_tracer, compute_overlap,
                             measured_speeds, recalibrate_alpha,
                             to_chrome_trace, validate_chrome_trace,
                             write_chrome_trace)
from repro.telemetry.overlap import (intersect_unions, total,
                                     union_intervals)
from repro.telemetry.tracer import _NULL_SPAN


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_records_interval_and_attrs():
    tr = Tracer()
    with tr.span("work", track="cpu_gemm", bytes=1024):
        pass
    (s,) = tr.spans()
    assert s.name == "work" and s.track == "cpu_gemm"
    assert s.attrs == {"bytes": 1024}
    assert s.t1 >= s.t0 and s.dur == s.t1 - s.t0


def test_span_late_attr_binding():
    """A step span can learn its phase after the work ran."""
    tr = Tracer()
    with tr.span("step1", track="step") as sp:
        sp.set(phase="decode")
    (s,) = tr.spans()
    assert s.attrs == {"phase": "decode"}


def test_event_and_track_defaults():
    tr = Tracer()
    tr.set_track("sched")
    tr.event("preempt", rid=3)             # thread-default track
    tr.event("admit", track="other")       # explicit wins
    evs = tr.events_list()
    assert [(e.name, e.track) for e in evs] == \
        [("preempt", "sched"), ("admit", "other")]
    assert evs[0].attrs == {"rid": 3}


def test_mark_scopes_snapshot():
    tr = Tracer()
    with tr.span("old", track="t"):
        pass
    m = tr.mark()
    with tr.span("new", track="t"):
        pass
    assert [s.name for s in tr.spans(since=m)] == ["new"]
    assert [s.name for s in tr.spans(track="t")] == ["old", "new"]


def test_ring_wrap_drops_oldest():
    tr = Tracer(capacity=4)
    for i in range(10):
        with tr.span(f"s{i}", track="t"):
            pass
    spans = tr.spans()
    assert [s.name for s in spans] == ["s6", "s7", "s8", "s9"]
    assert tr.dropped() == 6
    tr.clear()
    assert tr.spans() == [] and tr.dropped() == 0


def test_threads_get_own_buffers():
    tr = Tracer()

    def work(i):
        with tr.span(f"w{i}", track=f"trk{i}"):
            pass

    ths = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    spans = tr.spans()
    assert sorted(s.name for s in spans) == ["w0", "w1", "w2", "w3"]
    assert sorted(s.track for s in spans) == \
        ["trk0", "trk1", "trk2", "trk3"]


def test_disabled_tracer_is_free_and_inert():
    tr = Tracer(enabled=False)
    assert not tr and not NULL_TRACER
    # the no-op span is one shared object: no per-call allocation
    assert tr.span("x", track="t") is _NULL_SPAN
    assert NULL_TRACER.span("y") is _NULL_SPAN
    with tr.span("x", track="t") as sp:
        sp.set(phase="decode")          # no-op, no error
    tr.event("e", track="t")
    assert tr.spans() == [] and tr.events_list() == []


def test_as_tracer_normalizes():
    tr = Tracer()
    assert as_tracer(tr) is tr
    assert as_tracer(False) is NULL_TRACER
    assert as_tracer(None) is NULL_TRACER
    built = as_tracer(True)
    assert isinstance(built, Tracer) and built.enabled


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_instruments():
    m = MetricsRegistry()
    m.counter("steps").inc()
    m.counter("steps").inc(2)
    m.gauge("slots").set(3)
    m.gauge("slots").set(1)
    h = m.histogram("lat", edges=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = m.snapshot()
    assert snap["steps"] == 3.0
    assert snap["slots"] == 1.0
    assert snap["lat"]["count"] == 3
    assert snap["lat"]["buckets"] == [1, 1, 1]
    assert snap["lat"]["min"] == 0.05 and snap["lat"]["max"] == 5.0
    assert snap["lat"]["mean"] == pytest.approx(5.55 / 3)


def test_metrics_misuse_raises():
    m = MetricsRegistry()
    m.counter("x")
    with pytest.raises(TypeError):
        m.gauge("x")
    with pytest.raises(ValueError):
        m.counter("y").inc(-1)
    with pytest.raises(ValueError):
        m.histogram("h", edges=(1.0, 1.0))


def test_absorb_maps_legacy_stats_keys():
    """Every numeric leaf of a legacy stats() dict appears in the
    snapshot under its dotted path — the supersession contract."""
    stats = {
        "executor": "batcher",               # identity: skipped
        "tokens_per_s": 12.5,
        "phase_alpha": {"decode": 0.2, "prefill": 0.9},
        "resident_bytes": 1 << 20,
        "retunes": 3,
        "stream": StreamStats(cpu=1.0, pin=0.25, trans=0.5, dev=2.0,
                              wall=4.0),
        "scheduler": {"policy": "fcfs", "preemptions": 1, "waiting": 0},
        "paged": {"page_size": 16, "pool_pages": 64, "mapped_pages": 8},
    }
    m = MetricsRegistry()
    m.absorb(stats)
    snap = m.snapshot()
    assert "executor" not in snap and "scheduler.policy" not in snap
    assert snap["tokens_per_s"] == 12.5
    assert snap["phase_alpha.decode"] == 0.2
    assert snap["phase_alpha.prefill"] == 0.9
    assert snap["resident_bytes"] == float(1 << 20)
    assert snap["retunes"] == 3.0
    assert snap["stream.cpu_s"] == 1.0 and snap["stream.pin_s"] == 0.25
    assert snap["stream.trans_s"] == 0.5 and snap["stream.dev_s"] == 2.0
    assert snap["stream.wall_s"] == 4.0
    assert snap["scheduler.preemptions"] == 1.0
    assert snap["paged.mapped_pages"] == 8.0
    # re-absorbing is idempotent (point-in-time gauges)
    m.absorb(stats)
    assert m.snapshot() == snap


# ---------------------------------------------------------------------------
# StreamStats (satellite: __add__ / utilization edge cases)
# ---------------------------------------------------------------------------

def test_stream_stats_add_sums_busy_maxes_wall():
    a = StreamStats(cpu=1.0, pin=0.5, trans=0.25, dev=2.0, wall=3.0)
    b = StreamStats(cpu=0.5, pin=0.5, trans=0.75, dev=1.0, wall=2.0)
    c = a + b
    assert (c.cpu, c.pin, c.trans, c.dev) == (1.5, 1.0, 1.0, 3.0)
    assert c.wall == 3.0                    # shared timeline: max, not sum
    z = StreamStats() + StreamStats()
    assert (z.cpu, z.pin, z.trans, z.dev, z.wall) == (0, 0, 0, 0, 0)


def test_stream_stats_utilization_zero_wall():
    """A never-run engine must not divide by zero."""
    u = StreamStats().utilization()
    assert u == {"cpu": 0.0, "pin": 0.0, "trans": 0.0, "dev": 0.0}
    u2 = StreamStats(cpu=1.0, dev=3.0, wall=4.0).utilization()
    assert u2["cpu"] == pytest.approx(0.25)
    assert u2["dev"] == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# overlap math
# ---------------------------------------------------------------------------

def _sp(name, track, t0, t1, **attrs):
    return Span(name, track, t0, t1, attrs or None)


def test_interval_primitives():
    assert union_intervals([(0, 1), (0.5, 2), (3, 4), (4, 4)]) == \
        [(0, 2), (3, 4)]
    assert intersect_unions([(0, 2), (3, 5)], [(1, 4)]) == \
        [(1, 2), (3, 4)]
    assert total([(0, 2), (3, 4)]) == 3.0


def test_overlap_perfectly_hidden():
    """I/O entirely under compute -> fraction 1.0."""
    spans = [_sp("t", "transfer", 1.0, 2.0),
             _sp("p", "pin", 1.2, 1.8),
             _sp("d", "device", 0.0, 4.0)]
    rep = compute_overlap(spans)
    assert rep.io_hidden_frac == pytest.approx(1.0)
    assert rep.overall.critical_path == "device"


def test_overlap_forced_serial_is_zero():
    """Streams running back-to-back (no concurrency) -> fraction ~0."""
    spans = [_sp("p", "pin", 0.0, 1.0),
             _sp("t", "transfer", 1.0, 2.0),
             _sp("c", "cpu_gemm", 2.0, 3.0),
             _sp("d", "device", 3.0, 4.0)]
    rep = compute_overlap(spans)
    assert rep.io_hidden_frac == pytest.approx(0.0)


def test_overlap_partial_and_bounds():
    # io [0,2], compute [1,3]: hidden 1 of 2 io seconds
    spans = [_sp("t", "transfer", 0.0, 2.0),
             _sp("d", "device", 1.0, 3.0)]
    rep = compute_overlap(spans)
    assert rep.io_hidden_frac == pytest.approx(0.5)
    assert 0.0 <= rep.io_hidden_frac <= 1.0
    assert rep.overall.busy == {"transfer": 2.0, "device": 2.0}
    util = rep.overall.utilization()
    assert util["transfer"] == pytest.approx(2.0 / 3.0)


def test_overlap_no_io_reports_one():
    rep = compute_overlap([_sp("d", "device", 0.0, 1.0)])
    assert rep.io_hidden_frac == 1.0        # nothing needed hiding
    empty = compute_overlap([])
    assert empty.overall.wall == 0.0 and empty.steps == []


def test_overlap_per_step_windows():
    spans = [_sp("step1", "step", 0.0, 2.0, phase="decode"),
             _sp("step2", "step", 2.0, 4.0, phase="verify"),
             _sp("t", "transfer", 0.0, 1.0),
             _sp("d", "device", 0.5, 3.5)]
    rep = compute_overlap(spans)
    assert [w.label for w in rep.steps] == ["step1", "step2"]
    assert [w.phase for w in rep.steps] == ["decode", "verify"]
    # step1 sees io [0,1] with compute [0.5,1] over it
    assert rep.steps[0].io_hidden_frac == pytest.approx(0.5)
    # step2 has no io at all
    assert rep.steps[1].io_hidden_frac == 1.0
    text = rep.render()
    assert "io hidden" in text and "step1" in text and "decode" in text


# ---------------------------------------------------------------------------
# chrome export
# ---------------------------------------------------------------------------

def test_chrome_export_schema_and_validation(tmp_path):
    tr = Tracer()
    with tr.span("a", track="pin", bytes=64):
        pass
    with tr.span("b", track="device"):
        pass
    tr.event("admit", track="sched", rid=1)
    path = tmp_path / "trace.json"
    doc = write_chrome_trace(str(path), tr)
    assert validate_chrome_trace(doc) == []
    on_disk = json.loads(path.read_text())
    assert validate_chrome_trace(on_disk) == []
    phs = [e["ph"] for e in on_disk["traceEvents"]]
    assert phs.count("X") == 2 and phs.count("i") == 1
    names = {e["args"]["name"] for e in on_disk["traceEvents"]
             if e["ph"] == "M"}
    assert {"pin", "device", "sched"} <= names
    xs = [e for e in on_disk["traceEvents"] if e["ph"] == "X"]
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)


def test_chrome_validator_catches_violations():
    doc = to_chrome_trace([_sp("a", "t", 1.0, 2.0),
                           _sp("b", "t", 1.5, 2.5)])   # same-track overlap
    probs = validate_chrome_trace(doc)
    assert any("overlaps" in p for p in probs)
    # distinct tracks may overlap freely
    ok = to_chrome_trace([_sp("a", "t1", 1.0, 2.0),
                          _sp("b", "t2", 1.5, 2.5)])
    assert validate_chrome_trace(ok) == []
    assert validate_chrome_trace({}) == \
        ["traceEvents missing or not a list"]
    bad = {"traceEvents": [{"ph": "Z", "pid": 0, "tid": 0, "name": "x"}]}
    assert any("unknown ph" in p for p in validate_chrome_trace(bad))


# ---------------------------------------------------------------------------
# trace-driven alpha recalibration
# ---------------------------------------------------------------------------

def _speed_spans(v_cpu, v_pin, v_com, n=8, nbytes=1 << 20):
    """Synthetic engine spans with exact per-stream speeds."""
    spans = []
    t = 0.0
    for i in range(n):
        for track, v in (("cpu_gemm", v_cpu), ("pin", v_pin),
                         ("transfer", v_com)):
            spans.append(_sp(f"m{i}", track, t, t + nbytes / v,
                             bytes=nbytes, phase="decode"))
            t += nbytes / v + 1e-3
    return spans


def test_measured_speeds_exact():
    spans = _speed_spans(2e9, 8e9, 4e9, n=4)
    est = measured_speeds(spans, phase="decode")
    assert est.v_cpu == pytest.approx(2e9, rel=1e-9)
    assert est.v_pin == pytest.approx(8e9, rel=1e-9)
    assert est.v_com == pytest.approx(4e9, rel=1e-9)
    assert est.n_spans == 12
    assert est.cpu_bytes == 4 << 20


def test_measured_speeds_missing_stream_raises():
    spans = [_sp("m", "cpu_gemm", 0.0, 1.0, bytes=1024)]
    with pytest.raises(ValueError, match="pin"):
        measured_speeds(spans)
    # byte-less spans don't count either
    spans += [_sp("m", "pin", 0.0, 1.0), _sp("m", "transfer", 0.0, 1.0)]
    with pytest.raises(ValueError):
        measured_speeds(spans)


def test_recalibrate_matches_direct_refine_alpha():
    """The trace-driven fit must reproduce refine_alpha on the same
    synthesized callables — identical probes, identical root."""
    from repro.core.alpha_benchmark import refine_alpha

    # crossing (1-a)/v_cpu = a/v_com sits at 0.5 — inside refine_alpha's
    # probe window around alpha0 (the solver refines locally, +/- gamma)
    v_cpu, v_pin, v_com = 2e9, 12e9, 2e9
    spans = _speed_spans(v_cpu, v_pin, v_com)
    alpha0 = 0.52
    fit = recalibrate_alpha(spans, alpha0, phase="decode")

    est = measured_speeds(spans, phase="decode")
    B = float(est.cpu_bytes + max(est.pin_bytes, est.trans_bytes))
    ref = refine_alpha(lambda a: (1 - a) * B / est.v_cpu,
                       lambda a: max(a * B / est.v_pin,
                                     a * B / est.v_com),
                       alpha0)
    assert fit.alpha == pytest.approx(ref.alpha, abs=1e-9)
    assert fit.predicted_time == pytest.approx(ref.predicted_time,
                                               rel=1e-9)
    # the analytic crossing for these speeds: (1-a)/v_cpu = a/v_com
    a_star = (1 / v_cpu) / (1 / v_cpu + 1 / v_com)
    assert fit.alpha == pytest.approx(a_star, abs=0.02)


def test_recalibrate_scale_invariant_in_bytes():
    spans = _speed_spans(2e9, 10e9, 5e9)
    f1 = recalibrate_alpha(spans, 0.4)
    f2 = recalibrate_alpha(spans, 0.4, bytes_per_step=123456789.0)
    assert f1.alpha == pytest.approx(f2.alpha, abs=1e-9)


# ---------------------------------------------------------------------------
# live engine + backend integration
# ---------------------------------------------------------------------------

def test_engine_emits_stream_spans(rng):
    """A traced hetegen linear produces byte-carrying spans on all four
    stream tracks, and those spans recalibrate."""
    import jax.numpy as jnp

    from repro.core import HeteGenEngine, ModulePlan

    names = [f"m{i}" for i in range(4)]
    W = {n: rng.standard_normal((96, 256)).astype(np.float32)
         for n in names}
    plan = [ModulePlan(n, "g", "hetegen", 0.5) for n in names]
    tr = Tracer()
    eng = HeteGenEngine(W, plan, tracer=tr, trace_phase="decode")
    eng.warm_prefetch()
    x = jnp.asarray(rng.standard_normal((2, 96)).astype(np.float32))
    for n in names:
        eng.linear(x, n)
    eng.close()

    spans = tr.spans()
    by_track = {t: [s for s in spans if s.track == t]
                for t in ("pin", "transfer", "cpu_gemm", "device")}
    for t, ss in by_track.items():
        assert ss, f"no spans on {t}"
    for t in ("pin", "transfer", "cpu_gemm"):
        assert all((s.attrs or {}).get("bytes", 0) > 0
                   for s in by_track[t]), t
        assert all((s.attrs or {}).get("phase") == "decode"
                   for s in by_track[t]), t
    # the trace is exportable and internally consistent
    assert validate_chrome_trace(to_chrome_trace(spans)) == []
    # and dense spans feed the recalibrator
    fit = recalibrate_alpha(spans, 0.5, phase="decode")
    assert 0.0 <= fit.alpha <= 1.0


def test_stream_span_links(rng):
    """pin -> transfer -> device spans of one module/step share a seq
    attr, so the trace shows which pin fed which transfer."""
    import jax.numpy as jnp

    from repro.core import HeteGenEngine, ModulePlan

    names = [f"m{i}" for i in range(3)]
    W = {n: rng.standard_normal((96, 256)).astype(np.float32)
         for n in names}
    plan = [ModulePlan(n, "g", "hetegen", 0.5) for n in names]
    tr = Tracer()
    eng = HeteGenEngine(W, plan, tracer=tr, trace_phase="decode")
    eng.warm_prefetch()
    x = jnp.asarray(rng.standard_normal((2, 96)).astype(np.float32))
    n_steps = 3
    for _ in range(n_steps):
        for n in names:
            eng.linear(x, n)
    eng.close()

    spans = tr.spans()
    for n in names:
        linked = {}
        for track in ("pin", "transfer", "device"):
            seqs = [(s.attrs or {}).get("seq") for s in spans
                    if s.track == track
                    and (s.attrs or {}).get("module", s.name) == n]
            assert all(q is not None for q in seqs), (n, track)
            linked[track] = seqs
        # every step's transfer/device span names the pin that fed it:
        # the same seq appears once per stream, in the same order
        assert linked["transfer"] == linked["device"]
        assert linked["transfer"] == list(range(n_steps))
        # pins are distinct and cover every transfer (the tail may hold
        # one extra: the wrap-around prefetch of a step that never ran)
        assert len(set(linked["pin"])) == len(linked["pin"])
        assert set(linked["transfer"]) <= set(linked["pin"])


def test_traced_batcher_token_identical(rng):
    """Tracing must be observation only: same tokens with and without."""
    import jax

    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving.batcher import ContinuousBatcher

    cfg = get_config("tiny")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (5, 8)]

    ref = ContinuousBatcher(cfg, params, max_slots=2, max_len=64)
    ref_ids = [ref.submit(p, 6) for p in prompts]
    ref_out = ref.run_until_done()

    tr = Tracer()
    traced = ContinuousBatcher(cfg, params, max_slots=2, max_len=64,
                               tracer=tr)
    tr_ids = [traced.submit(p, 6) for p in prompts]
    tr_out = traced.run_until_done()

    for a, b in zip(ref_ids, tr_ids):
        assert ref_out[a] == tr_out[b]
    # the traced run recorded its steps and phases
    steps = tr.spans(track="step")
    assert steps and all((s.attrs or {}).get("phase") for s in steps)
    assert tr.spans(track="phase")
    assert tr.spans(track="sample")
    assert validate_chrome_trace(
        to_chrome_trace(tr.spans(), tr.events_list())) == []
    # serve.* metrics counted every token once
    snap = traced.metrics.snapshot()
    assert snap["serve.tokens"] == float(sum(len(o)
                                             for o in tr_out.values()))
    assert snap["serve.steps"] == len(steps)


def test_llm_facade_trace_and_metrics(rng):
    """LLM(trace=True): scheduler events, metrics() superset of stats(),
    overlap report bounded."""
    import jax

    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving.api import LLM

    cfg = get_config("tiny")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [list(rng.integers(0, cfg.vocab_size, 6)) for _ in range(3)]
    with LLM(cfg, params, max_slots=2, max_len=64, trace=True) as llm:
        for p in prompts:
            llm.submit(p, 5)
        outs = llm.drain()
        assert all(len(o.tokens) == 5 for o in outs.values())
        rep = llm.overlap_report()
        assert isinstance(rep, OverlapReport)
        assert 0.0 <= rep.io_hidden_frac <= 1.0
        snap = llm.metrics()
        st = llm.stats()
    # scheduler admissions/finishes were recorded as instant events
    admits = [e for e in llm.tracer.events_list(track="sched")
              if e.name == "admit"]
    finishes = [e for e in llm.tracer.events_list(track="sched")
                if e.name == "finish"]
    assert len(admits) == 3 and len(finishes) == 3
    # metrics() carries the legacy stats() numeric leaves, namespaced
    assert snap["scheduler.preemptions"] == \
        float(st["scheduler"]["preemptions"])
    assert snap["serve.tokens"] == 15.0
    assert snap["tokens_per_s"] == pytest.approx(st["tokens_per_s"])
