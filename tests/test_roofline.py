"""Roofline machinery: record enrichment, floors, memory model sanity."""
import json

import pytest

from repro.analysis.roofline import enrich, ideal_seconds, model_flops


def _fake_record(arch="mistral-nemo-12b", shape="decode_32k"):
    return {
        "arch": arch, "shape": shape, "mesh": "single", "status": "ok",
        "mesh_shape": {"data": 16, "model": 16},
        "hlo": {"flops_per_device": 2e10, "bytes_per_device": 7e9,
                "collective_bytes": {"all-reduce": 2e7},
                "collective_wire_bytes_total": 2e7, "collective_count": 5},
        "memory": {}, "xla_cost": {},
    }


def test_enrich_terms_and_dominant():
    e = enrich(_fake_record())
    assert set(e["terms"]) == {"compute_s", "memory_s", "collective_s"}
    assert e["dominant"] == "memory_s"
    assert 0 < e["roofline_fraction"] <= 1.5


def test_model_flops_shapes():
    f_train = model_flops("mistral-nemo-12b", "train_4k")
    f_prefill = model_flops("mistral-nemo-12b", "prefill_32k")
    f_decode = model_flops("mistral-nemo-12b", "decode_32k")
    assert f_train == pytest.approx(6 * 12.25e9 * 256 * 4096, rel=0.05)
    assert f_prefill == pytest.approx(2 * 12.25e9 * 32 * 32768, rel=0.05)
    assert f_decode == pytest.approx(2 * 12.25e9 * 128, rel=0.05)


def test_moe_uses_active_params():
    dense = model_flops("mistral-nemo-12b", "decode_32k") / 12.25e9
    moe = model_flops("llama4-scout-17b-16e", "decode_32k") / 17.2e9
    assert moe == pytest.approx(dense, rel=0.1)


def test_ideal_floor_decode_memory_bound():
    i = ideal_seconds("mistral-nemo-12b", "decode_32k", 256)
    assert i["memory"] > i["compute"]          # decode is HBM-bound
    # params 24.5GB + cache ~2.7GB/chip-equivalent: floor in ~ms range
    assert 1e-3 < i["floor"] < 20e-3


def test_int8_kv_halves_cache_floor():
    import dataclasses
    from repro.configs import get_config
    from repro.models.config import kv_cache_bytes
    cfg = get_config("mistral-nemo-12b")
    cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
    assert kv_cache_bytes(cfg8, 128, 32768) == \
        kv_cache_bytes(cfg, 128, 32768) // 2
