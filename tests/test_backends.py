"""The backend seam: one layer-math core, pluggable linear backends.

ResidentBackend (jitted device matmuls) and HeteGenBackend (offloaded,
alpha-split) execute the SAME shared layer functions; these tests pin the
contract: identical generations across backends, batched offload decode,
continuous batching over offloaded weights, and batch-aware policies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.hw import PAPER_A10
from repro.models import model as M
from repro.serving.backends import (HeteGenBackend, ResidentBackend,
                                    ScanResidentBackend)
from repro.serving.batcher import ContinuousBatcher
from repro.serving.engine import Generator
from repro.serving.offload_runtime import OffloadGenerator


@pytest.fixture(scope="module")
def opt_setup():
    cfg = reduced(get_config("opt-6.7b"), layers=3)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def test_resident_backend_matches_scan_path(opt_setup, rng):
    """The backend-parameterized forward == the scan-stacked trunk."""
    cfg, params = opt_setup
    prompt = rng.integers(0, cfg.vocab_size, (2, 9)).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompt)}
    ref = Generator(cfg, params).generate(batch, 6)
    res = Generator(cfg, backend=ResidentBackend(cfg, params)).generate(
        batch, 6)
    assert res.tokens == ref.tokens


@pytest.mark.parametrize("batch", [1, 4])
def test_batched_offload_matches_resident(opt_setup, rng, batch):
    """Batched offload decode (the HeteGen backend at batch > 1) is
    token-exact vs the resident jitted path, with the placement plan built
    for the real batch size."""
    cfg, params = opt_setup
    prompt = rng.integers(0, cfg.vocab_size, (batch, 7)).astype(np.int32)
    ref = Generator(cfg, params).generate({"tokens": jnp.asarray(prompt)}, 5)
    off = OffloadGenerator(cfg, params, hw=PAPER_A10, budget_bytes=0,
                           batch=batch)
    res = off.generate(prompt, 5)
    assert res["tokens"].tolist() == ref.tokens
    assert off.policy.batch == batch
    assert res["batch"] == batch
    off.close()


def test_offload_auto_retunes_to_real_batch(opt_setup, rng):
    """generate() with a batch different from the constructed plan retunes
    build_policy to the observed batch size."""
    cfg, params = opt_setup
    off = OffloadGenerator(cfg, params, hw=PAPER_A10, budget_bytes=0)
    assert off.policy.batch == 1
    prompt = rng.integers(0, cfg.vocab_size, (4, 6)).astype(np.int32)
    res = off.generate(prompt, 3)
    assert off.policy.batch == 4
    ref = Generator(cfg, params).generate({"tokens": jnp.asarray(prompt)}, 3)
    assert res["tokens"].tolist() == ref.tokens
    off.close()


def test_alpha_shifts_with_batch(opt_setup):
    """Paper §4.1: larger decode batches raise arithmetic intensity, derate
    the host GEMM, and push more of the split onto the accelerator."""
    from repro.core.alpha import alpha_for_batch

    cfg, params = opt_setup
    be = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                        use_alpha_benchmark=False)
    a1 = be.retune(1).alpha
    a64 = be.retune(64).alpha
    assert a64 > a1
    # the policy prior IS the batch-aware law
    assert a1 == pytest.approx(alpha_for_batch(PAPER_A10, 1))
    assert a64 == pytest.approx(alpha_for_batch(PAPER_A10, 64))
    be.close()


def test_batcher_over_hetegen_backend(opt_setup, rng):
    """Slot-based continuous batching over offloaded weights: identical
    generations to the resident backend for a mixed-length request set."""
    cfg, params = opt_setup
    slots = 3
    prompts = [list(rng.integers(0, cfg.vocab_size, n))
               for n in (5, 9, 3, 7)]
    max_news = [6, 4, 5, 3]

    ref_b = ContinuousBatcher(cfg, params, max_slots=slots, max_len=64)
    ref_ids = [ref_b.submit(p, m) for p, m in zip(prompts, max_news)]
    ref_out = ref_b.run_until_done()

    hb = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                        batch=slots)
    off_b = ContinuousBatcher(cfg, backend=hb, max_slots=slots, max_len=64)
    off_ids = [off_b.submit(p, m) for p, m in zip(prompts, max_news)]
    off_out = off_b.run_until_done()

    assert hb.policy.batch == slots
    for r, o in zip(ref_ids, off_ids):
        assert ref_out[r] == off_out[o], (r, o)
    hb.close()


def test_batcher_over_resident_backend_staggered(opt_setup, rng):
    """The jitted ResidentBackend drives the batcher too, including
    mid-flight joins (per-slot len vector through the shared layer math)."""
    cfg, params = opt_setup
    prompts = rng.integers(0, cfg.vocab_size, (2, 6))
    g = Generator(cfg, params)
    ref0 = g.generate({"tokens": jnp.asarray(prompts[:1], jnp.int32)}, 6)
    ref1 = g.generate({"tokens": jnp.asarray(prompts[1:], jnp.int32)}, 4)
    b = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                          max_slots=2, max_len=64)
    r0 = b.submit(list(prompts[0]), 6)
    b.step(); b.step()
    r1 = b.submit(list(prompts[1]), 4)
    outs = b.run_until_done()
    assert outs[r0] == ref0.tokens[0]
    assert outs[r1] == ref1.tokens[0]


def test_backend_rejects_unsupported_family():
    cfg = reduced(get_config("mamba2-2.7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        ResidentBackend(cfg, params)
