"""Paper Table 2 — stream utilization inside a heterogeneous linear module
(OPT-13B): CPU 97.8%, I/O 96.9%, Pin 72.4%, GPU 0.1% in the paper.
Simulated on the A10 rig + really measured on this host's threaded engine.
"""


def run():
    from benchmarks.common import opt_decode_modules
    from repro.core.hw import PAPER_A10
    from repro.core.sim import run_strategy

    r = run_strategy(opt_decode_modules("opt-13b"), "hetegen", PAPER_A10)
    u = r.utilization
    # our module list is finer-grained than the paper's (per-projection
    # linears + device-resident attention cores create small link idles
    # the paper's single-module measurement does not see)
    assert u["cpu"] > 0.9 and u["trans"] > 0.75
    assert u["pin"] < u["trans"]
    rows = [(f"table2.sim.{k}_util_pct", v * 100) for k, v in u.items()]
    rows.append(("table2.paper.cpu_util_pct", 97.8))
    rows.append(("table2.paper.io_util_pct", 96.9))
    rows.append(("table2.paper.pin_util_pct", 72.4))
    return rows
