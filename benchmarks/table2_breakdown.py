"""Paper Table 2 — stream utilization inside a heterogeneous linear module
(OPT-13B): CPU 97.8%, I/O 96.9%, Pin 72.4%, GPU 0.1% in the paper.
Simulated on the A10 rig + really measured on this host's threaded engine,
with a trace-derived cross-check: the same utilizations recomputed from
the zero-sync tracer's span timeline (docs/OBSERVABILITY.md), which also
yields the numbers the totals cannot — the I/O-hidden fraction and the
critical-path stream.

The traced breakdown runs twice, fp vs q8 weight streaming
(docs/ANALYSIS.md appendix): the q8 run's pin/transfer spans carry the
int8+scale wire bytes, so its wire ratio lands near 1/4, its measured
wire GB/s is the compressed link rate, and its trace-recalibrated alpha
sits above the fp run's.
"""


def run():
    from benchmarks.common import opt_decode_modules
    from repro.core.hw import PAPER_A10
    from repro.core.sim import run_strategy

    r = run_strategy(opt_decode_modules("opt-13b"), "hetegen", PAPER_A10)
    u = r.utilization
    # our module list is finer-grained than the paper's (per-projection
    # linears + device-resident attention cores create small link idles
    # the paper's single-module measurement does not see)
    assert u["cpu"] > 0.9 and u["trans"] > 0.75
    assert u["pin"] < u["trans"]
    rows = [(f"table2.sim.{k}_util_pct", v * 100) for k, v in u.items()]
    rows.append(("table2.paper.cpu_util_pct", 97.8))
    rows.append(("table2.paper.io_util_pct", 96.9))
    rows.append(("table2.paper.pin_util_pct", 72.4))
    fits = {}
    for ws in ("fp", "q8"):
        wrows, fits[ws] = _traced_engine_breakdown(ws)
        rows += wrows
    # the compressed wire makes the measured link look faster, so the
    # trace-refit split leans toward the device (ANALYSIS.md) — only >=
    # here: refine_alpha probes a bounded window around alpha0, and on a
    # host where both optima sit below the window both fits clamp to its
    # edge (the strict planned-alpha ordering is pinned in
    # tests/test_wstream.py and the fig8 sweep instead)
    assert fits["q8"] >= fits["fp"], fits
    return rows


def _traced_engine_breakdown(wstream: str = "fp"):
    """Really-measured utilization from the traced engine timeline: run
    split hetegen linears under a Tracer and recompute the Table-2 view
    from spans — per-stream utilization, the measured I/O-hidden
    fraction, which stream the trace says is critical, and (q8) the wire
    ratio + wire GB/s the transfer stream actually carried."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import HeteGenEngine, ModulePlan
    from repro.telemetry import (Tracer, compute_overlap, measured_speeds,
                                 recalibrate_alpha)

    rng = np.random.default_rng(0)
    names = [f"m{i}" for i in range(8)]
    W = {n: rng.standard_normal((256, 512)).astype(np.float32)
         for n in names}
    plan = [ModulePlan(n, "g", "hetegen", 0.5) for n in names]
    tr = Tracer()
    eng = HeteGenEngine(W, plan, tracer=tr, trace_phase="decode",
                        wstream=wstream)
    eng.warm_prefetch()
    x = jnp.asarray(rng.standard_normal((4, 256)).astype(np.float32))
    for _ in range(4):                    # steps: ring wrap + prefetch
        for n in names:
            eng.linear(x, n)
    eng.close()

    rep = compute_overlap(tr.spans())
    o = rep.overall
    assert 0.0 <= o.io_hidden_frac <= 1.0
    util = o.utilization()
    tag = f"table2.trace.{wstream}"
    rows = [(f"{tag}.{trk}_util_pct", util[trk] * 100)
            for trk in ("cpu_gemm", "pin", "transfer", "device")
            if trk in util]
    rows += [(f"{tag}.io_hidden_frac", o.io_hidden_frac),
             (f"{tag}.critical_path", o.critical_path)]
    # what the spans say actually crossed the link (wire bytes/s)
    est = measured_speeds(tr.spans(), phase="decode")
    rows += [(f"{tag}.wire_gb_s", est.v_com / 1e9),
             (f"{tag}.wire_ratio", est.wire_ratio)]
    if wstream == "q8":
        assert est.wire_ratio < 0.6, est.wire_ratio
    # the same spans drive the alpha recalibrator — report what the
    # measured stream speeds say the split should have been
    fit = recalibrate_alpha(tr.spans(), 0.5, phase="decode")
    rows.append((f"{tag}.recalibrated_alpha", fit.alpha))
    return rows, fit.alpha
