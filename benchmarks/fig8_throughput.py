"""Paper Fig. 8 — decode throughput vs GPU-memory budget for OPT-6.7B /
13B / 30B under four systems (simulated on the paper's A10 rig):

    accelerate-like   naive offload, no pinning, no overlap, no CPU GEMM
    deepspeed-like    naive offload (single memory point)
    flexgen-like      pinned streaming overlapped with compute, attention
                      on CPU (sync pinning blocks, per the paper's Fig 5b)
    hetegen           hybrid heterogeneous parallelism, full scheduler

Key claims checked: HeteGen >= flexgen-like at every matched budget; the
peak advantage exceeds 3x (paper: 'up to 317%'); HeteGen's dynamic range
of feasible GPU-memory operating points is the widest.

Also sweeps *batched* offload decode (the FlexGen insight: offloading
systems win on aggregate throughput via large effective batches): at full
offload, aggregate tok/s grows with the decode batch while the batch-aware
planner shifts alpha toward the accelerator as host GEMMs become
compute-bound.

Finally, a real (not simulated) mixed-sampler request sweep through the
:class:`repro.serving.api.LLM` facade: staggered requests carrying
per-request SamplingParams over resident and HeteGen-offloaded backends,
reporting aggregate tok/s and the backend's per-phase alphas — plus a
speculative-decoding sweep (drafter x k over the offload backend):
acceptance rate, tok/s, and scheduler-step reduction vs the non-spec
baseline, with greedy token-identity asserted on every cell.
"""


def run():
    from benchmarks.common import opt_decode_modules, weight_bytes
    from repro.core.hw import PAPER_A10
    from repro.core.sim import run_strategy

    rows = []
    for arch in ("opt-6.7b", "opt-13b", "opt-30b"):
        mods = opt_decode_modules(arch)
        total = weight_bytes(mods)
        best_ratio = 0.0
        for frac in (0.0, 0.1, 0.25, 0.5, 0.75, 1.0):
            budget = frac * total
            tput = {}
            for strat in ("naive_offload", "sync_offload", "hetegen"):
                r = run_strategy(mods, strat, PAPER_A10,
                                 gpu_mem_budget=budget)
                tput[strat] = r.tokens_per_s
                rows.append((f"fig8.{arch}.mem{int(frac*100):03d}."
                             f"{strat}_tok_s", r.tokens_per_s))
            assert tput["hetegen"] >= tput["sync_offload"] - 1e-9
            best_ratio = max(best_ratio,
                             tput["hetegen"] / max(tput["sync_offload"],
                                                   1e-12))
        rows.append((f"fig8.{arch}.max_speedup_vs_flexgen_like", best_ratio))

    # batched offload decode: aggregate throughput vs batch, full offload
    for arch in ("opt-6.7b", "opt-13b"):
        agg1 = None
        for batch in (1, 4, 16, 32):
            mods = opt_decode_modules(arch, batch=batch)
            r = run_strategy(mods, "hetegen", PAPER_A10, batch=batch,
                             gpu_mem_budget=0.0)
            agg = r.throughput(batch)
            rows.append((f"fig8.{arch}.batch{batch:03d}.hetegen_agg_tok_s",
                         agg))
            if agg1 is None:
                agg1 = agg
        # batching pays: aggregate throughput at batch 32 >> batch 1
        rows.append((f"fig8.{arch}.batch_speedup_32x", agg / agg1))
        assert agg > 2.0 * agg1

    rows += _facade_mixed_sampler_sweep()
    rows += _policy_latency_sweep()
    rows += _chunked_interference_sweep()
    rows += _speculative_sweep()
    rows += _traced_serving_sweep()
    rows += _wstream_sweep()
    return rows


def _wstream_sweep():
    """fp vs q8 weight streaming (docs/ANALYSIS.md appendix), two ways.

    Simulated (the paper's A10 rig, OPT-13B, full offload): stamping the
    int8+scale wire bytes on every linear makes the link look ~4x faster,
    so the planner's alpha shifts toward the device and simulated decode
    throughput rises.

    Really measured (opt-125m through the LLM facade, traced): the same
    fp-vs-q8 pair served end to end, reporting the planned decode alpha,
    the *wire* GB/s the transfer stream actually sustained, aggregate
    tok/s, and the trace's I/O-hidden fraction.  Wall tok/s on this tiny
    CPU-hosted rig undersells the win (host overhead dominates); the
    honest measured signal is the transfer stream's byte count, which the
    CI q8 smoke pins at <= 0.6x of fp."""
    import time

    import jax
    import numpy as np

    from benchmarks.common import opt_decode_modules
    from repro.configs import get_config
    from repro.core.hw import PAPER_A10
    from repro.core.sim import make_placements, simulate_step
    from repro.models import model as M
    from repro.serving.api import LLM
    from repro.serving.backends import HeteGenBackend, enumerate_linears
    from repro.telemetry import measured_speeds

    rows = []
    sim = {}
    for ws in ("fp", "q8"):
        mods = opt_decode_modules("opt-13b", wstream=ws)
        pl = make_placements(mods, "hetegen", PAPER_A10, gpu_mem_budget=0.0)
        a = max((p.alpha for p in pl.values() if p.mode == "hetegen"),
                default=0.0)
        r = simulate_step(mods, pl, PAPER_A10, pinned=True,
                          hybrid_comm=True, async_manager=True)
        sim[ws] = (a, r.tokens_per_s)
        rows += [(f"fig8.wstream.sim.{ws}_alpha", a),
                 (f"fig8.wstream.sim.{ws}_tok_s", r.tokens_per_s)]
    # compression never hurts the planned split or the simulated rate
    assert sim["q8"][0] >= sim["fp"][0] - 1e-9, sim
    assert sim["q8"][1] >= sim["fp"][1] - 1e-9, sim
    rows.append(("fig8.wstream.sim.q8_speedup",
                 sim["q8"][1] / max(sim["fp"][1], 1e-12)))

    cfg = get_config("opt-125m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    total = sum(s.nbytes for s in enumerate_linears(cfg))
    planned = {}
    for ws in ("fp", "q8"):
        be = HeteGenBackend(cfg, params, hw=PAPER_A10, batch=2,
                            budget_bytes=0.25 * total, wstream=ws)
        rng = np.random.default_rng(0)
        prompts = [list(rng.integers(0, cfg.vocab_size, 8))
                   for _ in range(2)]
        with LLM(cfg, backend=be, own_backend=True, max_slots=2,
                 max_len=32, trace=True) as llm:
            t0 = time.perf_counter()
            for p in prompts:
                llm.submit(p, 4)
            outs = llm.drain()
            dt = max(time.perf_counter() - t0, 1e-9)
            rep = llm.overlap_report()
            spans = llm.tracer.spans()
            planned[ws] = be.policies["decode"].alpha
        est = measured_speeds(spans, phase="decode")
        toks = sum(len(o.tokens) for o in outs.values())
        rows += [(f"fig8.wstream.{ws}_decode_alpha", planned[ws]),
                 (f"fig8.wstream.{ws}_wire_gb_s", est.v_com / 1e9),
                 (f"fig8.wstream.{ws}_wire_ratio", est.wire_ratio),
                 (f"fig8.wstream.{ws}_tok_s", toks / dt),
                 (f"fig8.wstream.{ws}_io_hidden_frac",
                  rep.overall.io_hidden_frac)]
    # the planned split shifts toward the device under the compressed wire
    assert planned["q8"] > planned["fp"], planned
    return rows


def _traced_serving_sweep():
    """Trace-derived serving rows (docs/OBSERVABILITY.md): serve a real
    request mix through ``LLM(trace=True)`` over the offload backend and
    report what the span timeline — not wall-clock bookkeeping — says:
    per-phase wall split, per-step latency percentiles, the measured
    I/O-hidden fraction, and the critical-path stream."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.hw import PAPER_A10
    from repro.models import model as M
    from repro.serving.api import LLM
    from repro.serving.backends import HeteGenBackend

    cfg = get_config("tiny")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    be = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0, batch=3)
    with LLM(cfg, backend=be, own_backend=True, max_slots=3,
             max_len=64, trace=True) as llm:
        for i in range(5):
            n = int(rng.integers(4, 12))
            llm.submit(list(rng.integers(0, cfg.vocab_size, n)), max_new=8)
        llm.drain()
        rep = llm.overlap_report()
        snap = llm.metrics()

    o = rep.overall
    assert 0.0 <= o.io_hidden_frac <= 1.0
    rows = [("fig8.trace.io_hidden_frac", o.io_hidden_frac),
            ("fig8.trace.critical_path", o.critical_path),
            ("fig8.trace.steps", len(rep.steps)),
            ("fig8.trace.serve_tokens", snap["serve.tokens"]),
            ("fig8.trace.step_mean_ms", snap["serve.step_s"]["mean"] * 1e3)]
    # wall split by step phase — where serving time actually went
    by_phase = {}
    for w in rep.steps:
        by_phase[w.phase or "idle"] = \
            by_phase.get(w.phase or "idle", 0.0) + w.wall
    span_wall = max(sum(by_phase.values()), 1e-12)
    for ph, wall in sorted(by_phase.items()):
        rows.append((f"fig8.trace.phase.{ph}_wall_frac", wall / span_wall))
    # per-step decode latency from the trace itself (not the histogram)
    decode_walls = sorted(w.wall for w in rep.steps if w.phase == "decode")
    if decode_walls:
        rows.append(("fig8.trace.decode_step_p50_ms",
                     decode_walls[len(decode_walls) // 2] * 1e3))
    return rows


def _facade_mixed_sampler_sweep():
    """Real request-level serving through the LLM facade: staggered
    requests with mixed per-request samplers, resident vs offloaded."""
    import time

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.hw import PAPER_A10
    from repro.models import model as M
    from repro.serving.api import LLM
    from repro.serving.backends import HeteGenBackend
    from repro.serving.sampling import SamplingParams

    cfg = get_config("tiny")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    samplers = [SamplingParams(),
                SamplingParams(kind="topp", top_p=0.9, seed=1),
                SamplingParams(kind="topk", top_k=16, temperature=0.9,
                               seed=2)]

    def sweep(llm: LLM) -> float:
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for i in range(6):
            n = int(rng.integers(4, 12))
            llm.submit(list(rng.integers(0, cfg.vocab_size, n)),
                       max_new=8, sampling=samplers[i % len(samplers)])
            llm.step()
        outs = llm.drain()
        dt = max(time.perf_counter() - t0, 1e-9)
        return sum(len(o.tokens) for o in outs.values()) / dt

    rows = []
    with LLM(cfg, params, max_slots=3, max_len=64) as llm:
        rows.append(("fig8.facade.mixed_sampler.resident_tok_s",
                     sweep(llm)))
    be = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0, batch=3)
    with LLM(cfg, backend=be, own_backend=True, max_slots=3,
             max_len=64) as llm:
        rows.append(("fig8.facade.mixed_sampler.hetegen_tok_s",
                     sweep(llm)))
        alphas = {ph: p.alpha for ph, p in be.policies.items()}
        rows.append(("fig8.facade.hetegen_decode_alpha",
                     alphas["decode"]))
        rows.append(("fig8.facade.hetegen_prefill_alpha",
                     alphas["prefill"]))
    return rows


def _policy_latency_sweep():
    """Scheduler-policy latency, measured for real: a late high-priority
    request lands on a busy, page-tight paged batcher.  Under ``fcfs`` it
    waits for a tenant to finish; under ``priority`` it preempts one
    (optimistic paging + swap resume) and completes in a fraction of the
    steps — the FlexGen point that policy, not kernels, sets tail
    latency."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving.backends import ResidentBackend
    from repro.serving.batcher import ContinuousBatcher

    cfg = get_config("tiny")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, 6)) for _ in range(3)]

    def hipri_latency(policy: str) -> int:
        b = ContinuousBatcher(cfg, backend=ResidentBackend(cfg, params),
                              own_backend=True, max_slots=2, max_len=48,
                              paged=True, page_size=8, n_pages=7,
                              policy=policy)
        for p in prompts[:2]:
            b.submit(p, 24)
        for _ in range(3):
            b.step()
        hi = b.submit(prompts[2], 4, priority=5)
        steps = 0
        while not b.requests[hi].done:
            b.step()
            steps += 1
        b.run_until_done()
        b.close()
        return steps

    rows = []
    lat = {pol: hipri_latency(pol) for pol in ("fcfs", "priority")}
    for pol, steps in lat.items():
        rows.append((f"fig8.sched.{pol}.hipri_latency_steps", steps))
    rows.append(("fig8.sched.priority_latency_speedup",
                 lat["fcfs"] / max(lat["priority"], 1)))
    # the claim the scheduler seam exists for: policy moves tail latency
    assert lat["priority"] < lat["fcfs"]
    return rows


def _chunked_interference_sweep():
    """Decode latency under prefill interference, measured for real: a
    decode tenant shares the batcher with a stream of long-prompt
    admissions.  Whole-shot admission prefills the full prompt inside the
    tenant's step — its per-token latency absorbs the entire prompt;
    chunked admission (``chunk_tokens``) spreads the same work across
    steps, bounding each tenant token by one chunk of prefill.

    Two views of the same run: wall-clock p50/worst per tenant token
    (real, but the tiny CPU model's per-step host overhead compresses the
    ratio), and the deterministic *stall bound* — the most prompt tokens
    prefilled inside any single tenant step — which is exactly what
    chunking divides by the chunking factor (384/32 = 12x here)."""
    import time

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving.backends import ResidentBackend
    from repro.serving.batcher import ContinuousBatcher

    cfg = get_config("tiny")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    backend = ResidentBackend(cfg, params)   # shared: jit caches persist
    rng = np.random.default_rng(0)
    tenant_prompt = list(rng.integers(0, cfg.vocab_size, 6))
    longs = [list(rng.integers(0, cfg.vocab_size, 384)) for _ in range(3)]
    chunk_tokens = 32

    def interfered_run(chunk, measure=True):
        b = ContinuousBatcher(cfg, backend=backend, own_backend=False,
                              max_slots=2, max_len=448, paged=True,
                              chunk_tokens=chunk)
        # count prompt tokens each backend prefill call processes
        per_call = []
        orig_prefill = backend.prefill
        backend.prefill = lambda batch, cache: (
            per_call.append(int(np.prod(batch["tokens"].shape))),
            orig_prefill(batch, cache))[1]
        tenant = b.submit(tenant_prompt, 60)
        b.step()
        for p in longs:
            b.submit(p, 1)               # max_new=1: admissions dominate
        lats, stall_tokens = [], 0
        while (b.queue or len(b.scheduler.resident()) > 1) \
                and not b.requests[tenant].done:
            before = len(b.requests[tenant].generated)
            calls_before = len(per_call)
            t0 = time.perf_counter()
            b.step()
            dt = (time.perf_counter() - t0) * 1e3
            if len(b.requests[tenant].generated) == before + 1:
                lats.append(dt)
                stall_tokens = max(stall_tokens,
                                   sum(per_call[calls_before:]))
        b.run_until_done()
        b.close()
        backend.prefill = orig_prefill
        if not (measure and lats):
            return 0.0, 0.0, 0
        return float(np.median(lats)), float(np.max(lats)), stall_tokens

    for chunk in (None, chunk_tokens):   # warm the per-shape jit caches
        interfered_run(chunk, measure=False)
    whole_p50, whole_max, whole_stall = interfered_run(None)
    chunk_p50, chunk_max, chunk_stall = interfered_run(chunk_tokens)
    backend.close()
    rows = [("fig8.chunked_prefill.wholeshot_decode_p50_ms", whole_p50),
            ("fig8.chunked_prefill.chunk32_decode_p50_ms", chunk_p50),
            ("fig8.chunked_prefill.wholeshot_decode_worst_ms", whole_max),
            ("fig8.chunked_prefill.chunk32_decode_worst_ms", chunk_max),
            ("fig8.chunked_prefill.worst_token_speedup",
             whole_max / max(chunk_max, 1e-9)),
            # prompt tokens prefilled inside the tenant's worst step
            ("fig8.chunked_prefill.wholeshot_stall_tokens", whole_stall),
            ("fig8.chunked_prefill.chunk32_stall_tokens", chunk_stall),
            ("fig8.chunked_prefill.stall_reduction_factor",
             whole_stall / max(chunk_stall, 1))]
    # the tentpole claim: chunked admission bounds the prefill work a
    # tenant step can absorb by the chunking factor, and the tenant's
    # worst-token wall latency drops with it
    assert chunk_max < whole_max
    assert whole_stall >= (len(longs[0]) // chunk_tokens) * chunk_stall
    return rows


def _speculative_sweep():
    """Heterogeneous speculative decoding over the offload path, measured
    for real: drafter x k against the non-speculative baseline, greedy,
    repetitive prompts (the prompt-lookup drafter's favorable case —
    code/JSON-like text).

    The claim under test is HeteGen-specific: in the offload regime every
    decode step streams every offloaded weight over the link, so accepted
    drafts collapse k link-bound steps into one verify step.  The honest
    proxy here is **scheduler steps** (= weight streams); wall tok/s is
    reported but the tiny CPU-hosted model undersells the win (its
    per-step host overhead is the denominator a real PCIe link dwarfs).
    Greedy identity vs the baseline is asserted on every cell."""
    import time

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.hw import PAPER_A10
    from repro.models import model as M
    from repro.serving.backends import HeteGenBackend
    from repro.serving.batcher import ContinuousBatcher
    from repro.serving.speculative import (ModelDrafter, NgramDrafter,
                                           SpecConfig)

    cfg = get_config("tiny")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [([int(t) for t in rng.integers(1, cfg.vocab_size, 4)]
                * 8)[:16] for _ in range(2)]
    max_new = 24

    def serve(spec):
        hb = HeteGenBackend(cfg, params, hw=PAPER_A10, budget_bytes=0,
                            batch=2)
        b = ContinuousBatcher(cfg, backend=hb, max_slots=2, max_len=64,
                              paged=True, page_size=8, spec=spec)
        rids = [b.submit(p, max_new) for p in prompts]
        t0 = time.perf_counter()
        steps = 0
        while b.queue or b.scheduler.resident():
            b.step()
            steps += 1
        dt = max(time.perf_counter() - t0, 1e-9)
        toks = sum(len(b.requests[r].generated) for r in rids)
        out = [list(b.requests[r].generated) for r in rids]
        acc = b.spec_stats.acceptance_rate if spec is not None else 0.0
        hb.close()
        b.close()
        if spec is not None:
            spec.drafter.close()
        return out, toks / dt, steps, acc

    base_out, base_tps, base_steps, _ = serve(None)
    rows = [("fig8.spec.baseline_tok_s", base_tps),
            ("fig8.spec.baseline_steps", base_steps)]
    drafters = (("ngram", lambda: NgramDrafter()),
                ("model", lambda: ModelDrafter(cfg, params, max_len=64)))
    for name, mk in drafters:
        for k in (2, 4):
            out, tps, steps, acc = serve(SpecConfig(drafter=mk(), k=k))
            assert out == base_out, f"{name} k={k} changed tokens"
            assert steps < base_steps, (name, k, steps, base_steps)
            rows += [(f"fig8.spec.{name}_k{k}_tok_s", tps),
                     (f"fig8.spec.{name}_k{k}_acceptance", acc),
                     (f"fig8.spec.{name}_k{k}_steps", steps),
                     (f"fig8.spec.{name}_k{k}_step_reduction",
                      base_steps / steps)]
    return rows
