"""Real threaded-engine measurement on this host: per-stream busy seconds
for a HeteGen-offloaded OPT-125M decode (mechanism demo; the container is
CPU-only so absolute numbers are not A10 numbers)."""


def run():
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.core.hw import PAPER_A10
    from repro.models import model as M
    from repro.serving.offload_runtime import OffloadGenerator

    cfg = get_config("opt-125m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (1, 16)).astype(np.int32)
    off = OffloadGenerator(cfg, params, hw=PAPER_A10, budget_bytes=0)
    res = off.generate(prompt, 8)
    st = res["stream_stats"]
    rows = [
        ("engine.opt125m.decode_tok_s", res["tokens_per_s"]),
        ("engine.opt125m.alpha", res["alpha"]),
        ("engine.opt125m.cpu_busy_s", st.cpu),
        ("engine.opt125m.pin_busy_s", st.pin),
        ("engine.opt125m.trans_busy_s", st.trans),
        ("engine.opt125m.dev_busy_s", st.dev),
        ("engine.opt125m.pinned_overhead_MB",
         res["pinned_overhead_bytes"] / 1e6),
    ]
    off.close()
    return rows
