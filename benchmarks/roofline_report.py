"""Roofline summary from the dry-run records (one row per single-pod cell:
the three terms + dominant bound)."""


def run():
    import os
    from repro.analysis.roofline import enrich, load_records

    out = []
    d = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")
    if not os.path.isdir(d):
        return [("roofline.records_found", 0)]
    recs = [r for r in load_records(d) if r.get("mesh") == "single"]
    n_ok = 0
    for r in recs:
        e = enrich(r)
        if e is None:
            continue
        n_ok += 1
        key = f"roofline.{e['arch']}.{e['shape']}"
        out.append((f"{key}.bound_ms", e["bound_s"] * 1e3))
        out.append((f"{key}.dominant", e["dominant"]))
        out.append((f"{key}.useful_flops_ratio",
                    round(e["useful_flops_ratio"], 3)))
    out.insert(0, ("roofline.records_found", n_ok))
    return out
