"""Paper Fig. 1 — processing speeds of GPU / CPU / I/O on an OPT-30B MLP
linear, expressed as parameter bytes per second (the paper's convention:
'parameter size divided by processing time').

Reported for the paper's A10+Xeon rig (hardware model) AND measured on
this host's CPU (real wall-clock GEMV) for calibration.
"""


def run():
    import numpy as np
    from repro.core.alpha_benchmark import (measure_host_linear,
                                            measure_staging_copy)
    from repro.core.hw import PAPER_A10, TPU_V5E

    d, f = 7168, 28672                      # OPT-30B MLP first linear
    nbytes = d * f * 2
    rows = []
    for hw in (PAPER_A10, TPU_V5E):
        rows.append((f"fig1.{hw.name}.accel_Bps", hw.v_gpu(1.0)))
        rows.append((f"fig1.{hw.name}.cpu_Bps", hw.v_cpu(1.0)))
        rows.append((f"fig1.{hw.name}.link_Bps", hw.v_com()))
    t_cpu = measure_host_linear(d, f, batch=1, dtype=np.float32)
    t_pin = measure_staging_copy(nbytes)
    rows.append(("fig1.this_host.cpu_Bps", d * f * 4 / t_cpu))
    rows.append(("fig1.this_host.staging_Bps", nbytes / t_pin))
    return rows
