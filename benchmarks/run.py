"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows: for timing rows the middle
column is microseconds; for derived metrics (throughputs, utilizations,
fractions) it is empty and the value goes to the third column.
"""
import sys
import time

MODULES = [
    "benchmarks.fig1_speeds",
    "benchmarks.fig2_memory",
    "benchmarks.fig8_throughput",
    "benchmarks.table2_breakdown",
    "benchmarks.table3_ablation",
    "benchmarks.bench_engine",
    "benchmarks.bench_kernels",
    "benchmarks.roofline_report",
]


def main() -> None:
    import importlib
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(mod_name)
            rows = mod.run()
            for name, value in rows:
                if name.endswith("_us"):
                    print(f"{name},{value:.2f},")
                elif isinstance(value, str):
                    print(f"{name},,{value}")
                else:
                    print(f"{name},,{value:.6g}")
            dt = (time.perf_counter() - t0) * 1e6
            print(f"{mod_name}.total,{dt:.0f},")
        except Exception as e:                                # noqa: BLE001
            failures += 1
            print(f"{mod_name}.FAILED,,{type(e).__name__}: {e}",
                  file=sys.stdout)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
