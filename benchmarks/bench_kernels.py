"""Kernel-level microbenchmarks: ref (XLA-compiled) wall time per call +
theoretical bytes/flops per kernel shape (the Pallas kernels themselves
are TPU-target; interpret mode is not a timing proxy)."""


def run():
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ref

    rng = np.random.default_rng(0)
    rows = []

    def timeit(name, fn, *args, flops=None):
        jfn = jax.jit(fn)
        jfn(*args)[0].block_until_ready() if isinstance(jfn(*args), tuple) \
            else jax.block_until_ready(jfn(*args))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(jfn(*args))
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append((f"kernels.{name}_us", us))
        if flops:
            rows.append((f"kernels.{name}_gflops_s", flops / us / 1e3))

    m = k = n = 512
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    timeit("matmul_512", lambda a, b: ref.matmul(a, b), x, w,
           flops=2 * m * k * n)

    q = jnp.asarray(rng.standard_normal((1, 8, 512, 64)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 8, 512, 64)), jnp.float32)
    timeit("flash_512", lambda a, b: ref.flash_attention(a, b, b), q, kk,
           flops=4 * 8 * 512 * 512 * 64)

    xs = jnp.asarray(rng.standard_normal((2, 256, 4, 16)), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.standard_normal((2, 256, 4)), jnp.float32))
    a = -jnp.ones((4,), jnp.float32) * 0.5
    bm = jnp.asarray(rng.standard_normal((2, 256, 4, 8)), jnp.float32)
    timeit("ssd_chunk_256", lambda *t: ref.ssd_chunk(*t, chunk=64)[0],
           xs, dt, a, bm, bm)

    # paged vs dense decode attention at the same total KV: the XLA-level
    # cost of reading the cache through a block table (the gather the
    # Pallas kernel's index maps avoid on TPU) vs a contiguous cache
    b, hq, hkv, s, d, ps = 4, 32, 8, 1024, 128, 64
    nb = s // ps
    q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    vd = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    lens = jnp.full((b,), s, jnp.int32)
    flops = 4 * b * hq * s * d
    timeit("decode_dense_1024", lambda *t: ref.decode_attention(*t),
           q, kd, vd, lens, flops=flops)
    n_pages = 1 + b * nb
    bt = jnp.asarray(
        rng.permutation(np.arange(1, n_pages)).reshape(b, nb), jnp.int32)
    kp = jnp.asarray(rng.standard_normal((n_pages, hkv, ps, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, hkv, ps, d)), jnp.float32)
    timeit("decode_paged_1024",
           lambda *t: ref.paged_decode_attention(*t),
           q, kp, vp, bt, lens, flops=flops)

    # paged prefill: the ref oracle IS the gather fallback the Pallas
    # kernel deleted (materialize every mapped page densely, then attend).
    # Whole-shot at kv_offset 0 computes exactly dense causal flash, so
    # the row pair prices the per-layer page gather the kernel's
    # scalar-prefetch index maps avoid on TPU; the chunk row adds the
    # mid-prompt shape chunked admission runs every step.
    qf = jnp.asarray(rng.standard_normal((b, hq, s, d)), jnp.float32)
    pf_flops = 2 * b * hq * s * s * d   # causal: half the rectangle, x4/2
    timeit("prefill_dense_1024",
           lambda *t: ref.flash_attention(*t, causal=True),
           qf, kd, vd, flops=pf_flops)
    timeit("prefill_paged_gather_1024",
           lambda *t: ref.paged_prefill_attention(*t),
           qf, kp, vp, bt, jnp.zeros((b,), jnp.int32), flops=pf_flops)
    cs = 128                            # chunk_tokens of a mid-prompt chunk
    qc = qf[:, :, -cs:]
    offs = jnp.full((b,), s - cs, jnp.int32)
    timeit("prefill_chunk_paged_gather_128",
           lambda *t: ref.paged_prefill_attention(*t),
           qc, kp, vp, bt, offs, flops=4 * b * hq * cs * s * d)
    return rows
