"""Kernel-level microbenchmarks: ref (XLA-compiled) wall time per call +
theoretical bytes/flops per kernel shape (the Pallas kernels themselves
are TPU-target; interpret mode is not a timing proxy)."""
from repro.benchmarks_shim import *  # noqa


def run():
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ref

    rng = np.random.default_rng(0)
    rows = []

    def timeit(name, fn, *args, flops=None):
        jfn = jax.jit(fn)
        jfn(*args)[0].block_until_ready() if isinstance(jfn(*args), tuple) \
            else jax.block_until_ready(jfn(*args))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(jfn(*args))
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append((f"kernels.{name}_us", us))
        if flops:
            rows.append((f"kernels.{name}_gflops_s", flops / us / 1e3))

    m = k = n = 512
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    timeit("matmul_512", lambda a, b: ref.matmul(a, b), x, w,
           flops=2 * m * k * n)

    q = jnp.asarray(rng.standard_normal((1, 8, 512, 64)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 8, 512, 64)), jnp.float32)
    timeit("flash_512", lambda a, b: ref.flash_attention(a, b, b), q, kk,
           flops=4 * 8 * 512 * 512 * 64)

    xs = jnp.asarray(rng.standard_normal((2, 256, 4, 16)), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.standard_normal((2, 256, 4)), jnp.float32))
    a = -jnp.ones((4,), jnp.float32) * 0.5
    bm = jnp.asarray(rng.standard_normal((2, 256, 4, 8)), jnp.float32)
    timeit("ssd_chunk_256", lambda *t: ref.ssd_chunk(*t, chunk=64)[0],
           xs, dt, a, bm, bm)
    return rows
