"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import time
from typing import List

from repro.configs import get_config
from repro.core.sim import SimModule


def opt_decode_modules(arch: str, prefill_len: int = 512,
                       batch: int = 1,
                       wstream: str = "fp") -> List[SimModule]:
    """Per-decode-step module list for an OPT config (the paper's models).

    Linear weights in fp16 (the paper's deployment dtype); attention core
    touches the KV cache for ``prefill_len`` tokens.  ``wstream="q8"``
    stamps the int8+scale wire bytes on every linear so the simulator
    prices pin/DMA at the compressed size (docs/ANALYSIS.md appendix).
    """
    cfg = get_config(arch)
    d, f = cfg.d_model, cfg.d_ff
    hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    by = 2                                      # fp16 weights at deployment

    def linear(name, n_in, n_out, group, flops):
        wire = n_in * n_out + 4 * n_out if wstream == "q8" else None
        return SimModule(name, "linear", n_in * n_out * by, n_out, group,
                         flops, wire_bytes=wire)

    mods: List[SimModule] = []
    for l in range(cfg.n_layers):
        mods += [
            linear(f"l{l}.wq", d, hq * hd, "attn", 2 * batch * d * hq * hd),
            linear(f"l{l}.wk", d, hkv * hd, "attn",
                   2 * batch * d * hkv * hd),
            linear(f"l{l}.wv", d, hkv * hd, "attn",
                   2 * batch * d * hkv * hd),
            SimModule(f"l{l}.attn", "attn_core", 0, 0, "attn",
                      4 * batch * d * prefill_len,
                      cache_bytes=2 * batch * hkv * hd * prefill_len * by),
            linear(f"l{l}.wo", hq * hd, d, "attn", 2 * batch * hq * hd * d),
            linear(f"l{l}.w_in", d, f, "mlp", 2 * batch * d * f),
            linear(f"l{l}.w_down", f, d, "mlp_down", 2 * batch * f * d),
        ]
    return mods


def weight_bytes(mods) -> int:
    return sum(m.nbytes for m in mods if m.kind == "linear")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0

    @property
    def us(self):
        return self.s * 1e6
