"""Paper Fig. 2 — OPT-30B memory breakdown (batch 1, seq 512): linears
dominate (>97%), motivating linear-only offload."""


def run():
    from repro.configs import get_config
    from repro.models.config import kv_cache_bytes

    cfg = get_config("opt-30b")
    by = 2
    d, f, hd, hq = cfg.d_model, cfg.d_ff, cfg.hd, cfg.n_heads
    lin_attn = cfg.n_layers * (3 * d * hq * hd + hq * hd * d) * by
    lin_mlp = cfg.n_layers * (d * f + f * d) * by
    emb = cfg.vocab_size * d * by + cfg.max_seq * d * by
    norms = cfg.n_layers * 4 * d * by + 2 * d * by
    kv = kv_cache_bytes(cfg, batch=1, seq=512)
    total = lin_attn + lin_mlp + emb + norms + kv
    frac_lin = (lin_attn + lin_mlp) / total
    assert frac_lin > 0.9, frac_lin
    return [
        ("fig2.linear_attn_GB", lin_attn / 1e9),
        ("fig2.linear_mlp_GB", lin_mlp / 1e9),
        ("fig2.embedding_GB", emb / 1e9),
        ("fig2.norms_GB", norms / 1e9),
        ("fig2.kv_cache_GB", kv / 1e9),
        ("fig2.linear_fraction", frac_lin),
    ]
