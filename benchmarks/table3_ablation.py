"""Paper Table 3 — ablations, relative decode throughput (paper: all=100%,
no-hybrid 77.7%, no-async-manager 94.9%, no-alpha-benchmark 92.8%,
no-module-scheduler 32.1%)."""


def run():
    from benchmarks.common import opt_decode_modules, weight_bytes
    from repro.core.hw import PAPER_A10
    from repro.core.sim import run_strategy

    mods = opt_decode_modules("opt-13b")
    budget = 0.6 * weight_bytes(mods)        # ample-memory regime
    full = run_strategy(mods, "hetegen", PAPER_A10,
                        gpu_mem_budget=budget).tokens_per_s
    rows = [("table3.all_pct", 100.0)]
    variants = {
        "no_hybrid_parallelism": dict(strategy="hetegen_pinned"),
        "no_async_param_manager": dict(strategy="hetegen",
                                       async_manager=False),
        "no_alpha_benchmark": dict(strategy="hetegen",
                                   use_alpha_benchmark=False),
        "no_module_scheduler": dict(strategy="hetegen",
                                    use_module_scheduler=False),
    }
    for name, kw in variants.items():
        strat = kw.pop("strategy")
        t = run_strategy(mods, strat, PAPER_A10, gpu_mem_budget=budget,
                         **kw).tokens_per_s
        pct = 100.0 * t / full
        assert pct <= 100.0 + 1e-6, name
        rows.append((f"table3.{name}_pct", pct))
    return rows
