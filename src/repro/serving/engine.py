"""Jitted generation engine (the production serving path).

``Generator`` compiles prefill/decode once per (batch, prompt_len) shape and
runs the autoregressive loop with a donated cache.  This is the path the
multi-pod dry-run lowers (``serve_step``); the paper's *offload* runtime —
eager, layer-streaming, HeteGen-scheduled — lives in
:mod:`repro.serving.offload_runtime` and shares the same layer math.

Request-level serving (per-request sampling, streaming, continuous
batching) fronts this class through :class:`repro.serving.api.LLM`, which
uses it as the one-shot executor for rectangular batches
(docs/SERVING.md).
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.distributed.shardings import NO_RULES, ShardingRules
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.sampling import (SamplerConfig, SamplingParams,
                                    make_sampler, pack_sampling, request_key,
                                    sample_rows, step_key)


@dataclasses.dataclass
class GenerateResult:
    tokens: list                        # (B, n_new) python ints
    prefill_s: float
    decode_s: float
    tokens_per_s: float


class Generator:
    """Batched autoregressive generation with a jitted serve_step.

    By default runs the scan-stacked resident path (``M.prefill`` /
    ``M.decode_step`` over the stacked params).  Passing ``backend`` (a
    :class:`repro.serving.backends.LinearBackend` driver — ResidentBackend,
    HeteGenBackend, ...) instead routes every step through the shared
    backend-parameterized layer math.
    """

    def __init__(self, cfg: ModelConfig, params: Optional[Dict] = None, *,
                 rules: ShardingRules = NO_RULES,
                 sampler: SamplerConfig = SamplerConfig(),
                 backend=None):
        self.cfg = cfg
        self.params = params
        self.rules = rules
        self.backend = backend
        self.sample = make_sampler(sampler)
        if backend is None and params is None:
            raise ValueError("Generator needs params or a backend")
        if backend is not None and rules is not NO_RULES:
            raise ValueError(
                "sharding rules are owned by the backend; construct the "
                "backend with its own sharding instead of passing rules")

        # The params-based path is kept separate from the backend driver on
        # purpose: sampling stays inside the jitted decode step, so the
        # autoregressive loop moves (B,) token ids instead of a (B, vocab)
        # logits transfer per step.  Backend drivers sample outside (their
        # logits are already on the host side of the seam).
        if backend is None:
            def _prefill(params, batch, cache):
                cache, logits = M.prefill(cfg, params, batch, cache, rules)
                return cache, logits

            def _decode(params, token, cache, key):
                cache, logits = M.decode_step(cfg, params, token, cache,
                                              rules)
                nxt = self.sample(logits, key)
                return cache, nxt

            def _decode_logits(params, token, cache):
                return M.decode_step(cfg, params, token, cache, rules)

            def _decode_greedy(params, token, cache):
                cache, logits = M.decode_step(cfg, params, token, cache,
                                              rules)
                return cache, jnp.argmax(logits, axis=-1).astype(jnp.int32)

            self._prefill = jax.jit(_prefill)
            self._decode = jax.jit(_decode, donate_argnums=(2,))
            # logits-returning variant for request-level sampling: per-row
            # params/keys live outside the jit, so the loop moves a
            # (B, vocab) row per step instead of (B,) ids
            self._decode_logits = jax.jit(_decode_logits,
                                          donate_argnums=(2,))
            # all-greedy request batches keep the fused loop regardless of
            # the constructor's sampler (greedy rows consume no entropy)
            self._decode_greedy = jax.jit(_decode_greedy,
                                          donate_argnums=(2,))

    # ------------------------------------------------------------------
    def generate(self, batch: Dict, max_new_tokens: int,
                 *, max_len: Optional[int] = None,
                 seed: int = 0,
                 sampling: Optional[List[SamplingParams]] = None,
                 request_keys: Optional[List[jax.Array]] = None
                 ) -> GenerateResult:
        """Generate ``max_new_tokens`` per row.

        ``sampling`` switches to request-level sampling: one
        :class:`SamplingParams` per row, drawn under per-request PRNG
        streams (``request_keys``, derived from ``seed`` and the row
        index when omitted) — the same streams the continuous batcher
        consumes, so one-shot and batched execution of the same requests
        are token-identical.  Without it, the constructor's whole-batch
        sampler runs (jitted into the decode step on the scan path).
        """
        cfg = self.cfg
        if "tokens" in batch:
            b, s = batch["tokens"].shape
        else:
            b, s = batch["embeds"].shape[:2]
        total = max_len or (s + max_new_tokens)
        be = self.backend
        if be is not None and hasattr(be, "retune"):
            be.retune(b)       # plan follows the real decode batch
        cache = M.init_cache(cfg, b, total) if be is None \
            else be.init_cache(b, total)

        packed = None
        all_greedy = False
        if sampling is not None:
            if len(sampling) != b:
                raise ValueError(f"{len(sampling)} SamplingParams for "
                                 f"batch {b}")
            all_greedy = all(p.kind == "greedy" for p in sampling)
            if all_greedy:
                # greedy rows consume no entropy: keep the fused jitted
                # loop ((B,) ids per step) instead of shipping (B, vocab)
                # logits out for the row-vectorized sampler
                sampling = None
            else:
                packed = pack_sampling(sampling)
                if request_keys is None:
                    # lint: allow[prng-discipline] one-shot base key; the
                    # very next line derives request-owned keys from it
                    base = jax.random.PRNGKey(seed)
                    request_keys = [request_key(base, i, sp)
                                    for i, sp in enumerate(sampling)]

                def row_keys(step: int) -> jax.Array:
                    return jnp.stack([step_key(k, step)
                                      for k in request_keys])

        t0 = time.perf_counter()
        if be is None:
            cache, logits = self._prefill(self.params, batch, cache)
        else:
            cache, logits = be.prefill(batch, cache)
        # lint: allow[prng-discipline] legacy greedy/sample_fn path of the
        # one-shot generator; the batched path above is request-keyed
        key = jax.random.PRNGKey(seed)
        if packed is not None:
            tok = sample_rows(logits, row_keys(0), packed)
        elif all_greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            tok = self.sample(logits, key)
        jax.block_until_ready(tok)
        t1 = time.perf_counter()

        out = [tok]
        for i in range(max_new_tokens - 1):
            key = jax.random.fold_in(key, i)
            if packed is not None:
                if be is None:
                    cache, logits = self._decode_logits(self.params, tok,
                                                        cache)
                else:
                    cache, logits = be.decode(tok, cache)
                tok = sample_rows(logits, row_keys(i + 1), packed)
            elif be is None:
                if all_greedy:
                    cache, tok = self._decode_greedy(self.params, tok,
                                                     cache)
                else:
                    cache, tok = self._decode(self.params, tok, cache, key)
            else:
                cache, logits = be.decode(tok, cache)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32) \
                    if all_greedy else self.sample(logits, key)
            out.append(tok)
        jax.block_until_ready(out[-1])
        t2 = time.perf_counter()

        toks = jnp.stack(out, axis=1)
        dec = max(t2 - t1, 1e-9)
        return GenerateResult(
            tokens=jax.device_get(toks).tolist(),
            prefill_s=t1 - t0,
            decode_s=dec,
            tokens_per_s=b * max(max_new_tokens - 1, 1) / dec,
        )


# ---------------------------------------------------------------------------
# serve_step / train-free entry points used by the dry-run
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig, rules: ShardingRules = NO_RULES):
    """One decode step: (params, token (B,), cache) -> (cache, next (B,)).

    Greedy sampling inside the step (argmax over the sharded vocab) keeps
    the autoregressive loop device-side.
    """

    def serve_step(params, token, cache):
        cache, logits = M.decode_step(cfg, params, token, cache, rules)
        return cache, jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return serve_step


def make_prefill_step(cfg: ModelConfig, rules: ShardingRules = NO_RULES):
    def prefill_step(params, batch, cache):
        cache, logits = M.prefill(cfg, params, batch, cache, rules)
        return cache, jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return prefill_step
