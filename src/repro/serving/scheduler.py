"""Scheduling as an API: admission/preemption/resume policies over requests.

PR 1-3 grew the serving stack an executor at a time, but scheduling stayed
implicit: the batcher admitted FCFS, reserved every page a request could
ever want at admission, and nothing could be evicted.  FlexGen's lesson
(PAPERS.md) is that *policy* — who runs, who waits, who gets evicted —
dominates offloaded throughput long before kernels do, so this module
makes it a first-class seam:

  * :class:`RequestState` — one request's full scheduling state: prompt,
    budget, sampling stream, priority, generated tokens, status
    (waiting / running / preempted / finished), and — when preempted with
    ``preempt_mode="swap"`` — its host-saved KV pages.
  * :class:`SchedulerPolicy` — the pluggable decision surface: admission
    order, sacrifice order, and which running victims an incoming request
    may preempt.  Three implementations ship: :class:`FCFSPolicy`,
    :class:`PriorityPolicy`, :class:`FairSharePolicy` (registry:
    :func:`get_policy`).
  * :class:`Scheduler` — owns the request queues, the slot table, and all
    page *accounting* (`PagedKVCache` alloc/free), and emits a per-step
    :class:`StepPlan`.  The :class:`repro.serving.batcher.ContinuousBatcher`
    shrinks to a pure executor: it applies the plan (save / restore /
    prefill), runs the decode step, and reports tokens back.

Optimistic paging (ROADMAP paged follow-up): with ``optimistic=True``
(the default for paged serving) admission maps only the pages the prompt
needs *now* — ``prompt + 1`` positions instead of ``prompt + max_new`` —
and every step grows each running slot by exactly the next decode
position.  The pool therefore admits far more concurrent requests than
worst-case reservation would, and *page pressure* becomes a scheduling
event rather than an admission error: when ``alloc`` raises
:class:`PagesExhausted`, the policy picks victims, their pages are
released, and they re-enter the admission queue.

Preemption is loss-free and token-exact in both modes:

  * ``preempt_mode="swap"`` (paged default) — the victim's mapped pages
    are gathered to host memory (the natural direction for a HeteGen
    deployment: host RAM is the big pool) and scattered back into freshly
    mapped pages on resume.  KV bits are preserved exactly, so the resumed
    request continues bit-identically.
  * ``preempt_mode="recompute"`` (dense default) — the victim keeps only
    its token ids; resume re-prefills ``prompt + generated`` in one pass.
    Teacher-forced prefill reproduces the decode-path KV and logits
    exactly on this backend (tests/test_scheduler.py), and sampling draws
    from request-owned PRNG streams keyed by generated-token count
    (PR 3), so resumed requests are token-identical either way.

Starvation/thrash guards: a growth victim may be the growing request
itself (it simply waits for co-tenants to release pages), but when a
request is *alone* and still cannot grow, no future step can help — the
scheduler raises instead of flapping.  ``FairSharePolicy`` only allows
preemption after a victim has generated ``quantum`` tokens since its last
(re)admission, so every preemption cycle makes at least ``quantum``
tokens of progress.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Protocol, Union, runtime_checkable

import jax
import numpy as np

from repro.serving.kv_cache import PagedKVCache, PagesExhausted
from repro.serving.sampling import SamplingParams
from repro.telemetry.tracer import NULL_TRACER, Tracer

WAITING = "waiting"
RUNNING = "running"
PREFILLING = "prefilling"
PREEMPTED = "preempted"
FINISHED = "finished"


@dataclasses.dataclass
class RequestState:
    """One request's complete scheduling state (the queue's unit)."""

    rid: int
    prompt: List[int]
    max_new: int
    eos: Optional[int] = None
    sampling: SamplingParams = SamplingParams()
    key: Optional[jax.Array] = None      # request-owned PRNG stream (PR 3)
    priority: int = 0                    # larger = more important
    arrival: int = 0                     # monotonic submission index
    generated: List[int] = dataclasses.field(default_factory=list)
    logprobs: Optional[List[Dict]] = None  # per-token, when requested
    status: str = WAITING
    finish_reason: Optional[str] = None  # "eos" | "length" once finished
    slot: Optional[int] = None
    preemptions: int = 0                 # times this request was evicted
    resumed_at: int = 0                  # len(generated) at last admission
    wait_steps: int = 0                  # steps spent waiting/preempted
    # swap-mode preemption state: which pages to save (recorded at the
    # planning step, before they return to the free list) and the host
    # copy the executor gathers before anything overwrites them
    swap_block_ids: Optional[List[int]] = None
    saved_len: int = 0
    saved_kv: Optional[Dict[str, np.ndarray]] = None
    # chunked-prefill state (status == PREFILLING): tokens of
    # prompt + generated already written to KV, and the end the current
    # plan's chunk must reach (set by Scheduler.plan, consumed by the
    # executor which advances the cursor after prefilling)
    prefill_cursor: int = 0
    prefill_target: int = 0
    # prefix-dedupe state: cumulative hashes of the prompt's full pages
    # (computed at submit) and how many tokens were forked from a shared
    # prefix at admission instead of prefilled
    prefix_hashes: Optional[List[bytes]] = None
    forked_len: int = 0

    @property
    def done(self) -> bool:
        return self.status == FINISHED

    @property
    def kv_len(self) -> int:
        """KV positions materialized while running: the prompt plus every
        generated token except the newest (still the pending input)."""
        return len(self.prompt) + len(self.generated) - 1

    @property
    def slice_served(self) -> int:
        """Tokens generated since the last (re)admission."""
        return len(self.generated) - self.resumed_at


@dataclasses.dataclass
class StepPlan:
    """What the executor must do before this step's decode.

    ``preempt`` entries still carry their old ``slot`` so the executor can
    save their KV (swap mode) and clear the slot's length — their pages
    and slots are already released in the scheduler's accounting.
    ``start`` entries are already assigned a slot with pages mapped; the
    executor restores saved KV (``saved_kv`` set) or prefills
    ``prompt + generated`` (fresh admissions and recompute resumes — for
    a fresh request ``generated`` is empty, so the two are one code
    path).

    ``prefill`` entries are chunked admissions (status ``prefilling``):
    the executor prefills tokens ``[prefill_cursor, prefill_target)``
    into the slot's already-mapped pages and advances the cursor; on the
    final chunk (target == prompt + generated) it samples the first
    token and flips the request to ``running`` so the slot joins that
    same step's decode."""

    preempt: List[RequestState] = dataclasses.field(default_factory=list)
    start: List[RequestState] = dataclasses.field(default_factory=list)
    prefill: List[RequestState] = dataclasses.field(default_factory=list)


@runtime_checkable
class SchedulerPolicy(Protocol):
    """The pluggable scheduling surface.

    All three methods are pure functions of request state — policies hold
    no queues and mutate nothing, which is what lets the scheduler replay
    them every step against whatever the current queues are.
    """

    name: str

    def admit_order(self, pending: List[RequestState]
                    ) -> List[RequestState]:
        """Order the admission queue (waiting + preempted), most
        deserving first.  Admission is head-of-line: when the head cannot
        be placed, nothing behind it jumps the queue."""
        ...

    def preempt_order(self, running: List[RequestState]
                      ) -> List[RequestState]:
        """Sacrifice order over the running set, first victim first."""
        ...

    def may_preempt(self, incoming: RequestState,
                    victim: RequestState) -> bool:
        """May ``incoming`` (a pending request) evict ``victim`` to get
        admitted?  Page *growth* of already-running requests does not
        consult this — growth always may preempt (the alternative is a
        wedged step); this gate exists so admission cannot churn."""
        ...


class FCFSPolicy:
    """Arrival order; admission never preempts.  Page growth sacrifices
    the newest-arrived running request first (it has the least sunk
    work), exactly vLLM's recompute-preemption default."""

    name = "fcfs"

    def admit_order(self, pending):
        return sorted(pending, key=lambda s: s.arrival)

    def preempt_order(self, running):
        return sorted(running, key=lambda s: -s.arrival)

    def may_preempt(self, incoming, victim):
        return False


class PriorityPolicy:
    """Strict priorities: higher ``priority`` admits first and may evict
    any strictly lower-priority running request (strictness is the
    anti-thrash guarantee — equal priorities never preempt each other).
    Ties break FCFS."""

    name = "priority"

    def admit_order(self, pending):
        return sorted(pending, key=lambda s: (-s.priority, s.arrival))

    def preempt_order(self, running):
        return sorted(running, key=lambda s: (s.priority, -s.arrival))

    def may_preempt(self, incoming, victim):
        return incoming.priority > victim.priority


class FairSharePolicy:
    """Round-robin over service: least-served requests admit first, the
    most-served running request is sacrificed first, and a running
    request becomes evictable once it has generated ``quantum`` tokens
    since its last (re)admission.  Starvation bound: with any waiting
    request, no slot holder runs more than ``quantum`` tokens before
    yielding, so a waiter starts within ``quantum`` steps of reaching the
    head of the queue — and every preemption cycle ships at least
    ``quantum`` tokens, so slicing can never live-lock."""

    name = "fair_share"

    def __init__(self, quantum: int = 8):
        self.quantum = max(int(quantum), 1)

    def admit_order(self, pending):
        return sorted(pending, key=lambda s: (len(s.generated), s.arrival))

    def preempt_order(self, running):
        return sorted(running,
                      key=lambda s: (-len(s.generated), -s.arrival))

    def may_preempt(self, incoming, victim):
        return victim.slice_served >= self.quantum \
            and len(incoming.generated) < len(victim.generated) \
            + self.quantum

    def __repr__(self):
        return f"FairSharePolicy(quantum={self.quantum})"


POLICIES = {
    "fcfs": FCFSPolicy,
    "priority": PriorityPolicy,
    "fair_share": FairSharePolicy,
}


def get_policy(policy: Union[str, SchedulerPolicy, None]) -> SchedulerPolicy:
    """Resolve a policy name (registry) or pass a policy object through."""
    if policy is None:
        return FCFSPolicy()
    if isinstance(policy, str):
        try:
            return POLICIES[policy]()
        except KeyError:
            raise ValueError(f"unknown scheduler policy {policy!r}; "
                             f"known: {sorted(POLICIES)}") from None
    return policy


def _prefix_hashes(prompt: List[int], page_size: int) -> List[bytes]:
    """Cumulative digests of the prompt's *full* pages: entry j covers
    tokens [0, (j+1)*page_size).  Chained, so equal j-th entries imply the
    whole prefix matches — one comparison finds the longest shared
    page-aligned prefix at admission."""
    out: List[bytes] = []
    h = hashlib.sha256()
    for j in range(len(prompt) // page_size):
        page = prompt[j * page_size:(j + 1) * page_size]
        # lint: allow[hot-path-sync] hashes a host list of prompt ints at
        # admission (prefix dedupe); no device array is ever involved
        h.update(np.asarray(page, np.int64).tobytes())
        out.append(h.digest())
    return out


class Scheduler:
    """Owns who runs: queues, the slot table, and page accounting.

    The executor calls :meth:`plan` once per step and applies the
    returned :class:`StepPlan` (saves, then restores/prefills) before
    decoding; everything device-side stays in the executor, everything
    decision-side lives here.  ``kv`` is the page *allocator* — this
    class calls ``alloc``/``free``/``mapped_pages`` (host metadata only)
    and flips :attr:`tables_dirty` so the executor knows to re-export the
    device block tables."""

    def __init__(self, policy: Union[str, SchedulerPolicy, None],
                 max_slots: int, max_len: int, *,
                 kv: Optional[PagedKVCache] = None,
                 optimistic: bool = True,
                 preempt_mode: Optional[str] = None,
                 chunk_tokens: Optional[int] = None,
                 prefix_dedupe: Optional[bool] = None,
                 tracer: Tracer = NULL_TRACER):
        self.policy = get_policy(policy)
        # scheduling decisions land as instant events on the "sched"
        # track (docs/OBSERVABILITY.md) — admit/resume/preempt/finish
        self.tracer = tracer
        self.max_slots = max_slots
        self.max_len = max_len
        self.kv = kv
        self.optimistic = bool(optimistic) and kv is not None
        if preempt_mode is None:
            preempt_mode = "swap" if kv is not None else "recompute"
        if preempt_mode not in ("swap", "recompute"):
            raise ValueError(f"unknown preempt_mode {preempt_mode!r}")
        if preempt_mode == "swap" and kv is None:
            raise ValueError("preempt_mode='swap' needs a paged cache")
        self.preempt_mode = preempt_mode
        if chunk_tokens is not None and chunk_tokens < 1:
            raise ValueError(f"chunk_tokens must be >= 1, got {chunk_tokens}")
        self.chunk_tokens = chunk_tokens
        # prefix dedupe needs page-aliasing: default on for paged serving
        self.prefix_dedupe = (kv is not None if prefix_dedupe is None
                              else bool(prefix_dedupe) and kv is not None)
        self.requests: Dict[int, RequestState] = {}
        self.waiting: List[RequestState] = []
        self.preempted: List[RequestState] = []
        self.slot_req: List[Optional[RequestState]] = [None] * max_slots
        self.preemptions = 0           # total eviction events
        self.chunks_planned = 0        # chunked-prefill chunks emitted
        self.dedupe_hits = 0           # admissions that forked a prefix
        self.dedupe_tokens = 0         # prompt tokens never re-prefilled
        self.tables_dirty = False      # block tables changed since export
        self._arrivals = 0

    # -- queue views ----------------------------------------------------
    @property
    def pending(self) -> List[RequestState]:
        """Everything that wants a slot: never-run plus preempted."""
        return self.waiting + self.preempted

    def running(self) -> List[RequestState]:
        """Slots decoding this step (excludes mid-prefill slots)."""
        return [st for st in self.slot_req
                if st is not None and st.status == RUNNING]

    def prefilling(self) -> List[RequestState]:
        """Slots mid-chunked-prefill: they hold pages but do not decode."""
        return [st for st in self.slot_req
                if st is not None and st.status == PREFILLING]

    def resident(self) -> List[RequestState]:
        """Every slot holder — running plus prefilling."""
        return [st for st in self.slot_req if st is not None]

    def active_mask(self) -> np.ndarray:
        return np.asarray([st is not None and st.status == RUNNING
                           for st in self.slot_req], bool)

    # -- intake / completion -------------------------------------------
    def submit(self, st: RequestState) -> None:
        if st.rid in self.requests:
            raise ValueError(f"duplicate request id {st.rid}")
        st.arrival = self._arrivals
        self._arrivals += 1
        st.status = WAITING
        if st.sampling.logprobs is not None and st.logprobs is None:
            st.logprobs = []
        if self.prefix_dedupe and st.prefix_hashes is None:
            st.prefix_hashes = _prefix_hashes(st.prompt, self.kv.page_size)
        self.requests[st.rid] = st
        self.waiting.append(st)

    def finish(self, st: RequestState) -> None:
        """Retire a finished request: release its slot and pages."""
        st.status = FINISHED
        self.tracer.event("finish", track="sched", rid=st.rid,
                          reason=st.finish_reason,
                          generated=len(st.generated))
        if st.slot is not None:
            if self.kv is not None:
                self.kv.free(st.slot)
                self.tables_dirty = True
            self.slot_req[st.slot] = None

    # -- the per-step plan ---------------------------------------------
    def plan(self, advances: Optional[Dict[int, int]] = None) -> StepPlan:
        """Decide this step's preemptions, admissions, and page growth.

        All accounting (slots, pages) is committed here; the executor
        then performs the device work in plan order (saves before
        restores/prefills, so swapped KV is read before its old pages
        can be rewritten).

        ``advances`` maps request ids to this step's KV advance in
        positions (default 1, the plain decode step).  Speculative
        decoding passes ``k_eff + 1`` per drafted request so optimistic
        growth reserves the whole draft run up front; rejection later
        *shrinks* the slot back (``PagedKVCache.truncate``), so a spec
        step can never hold rejected pages across steps."""
        out = StepPlan()
        if self.optimistic:
            # growth first: running requests reserve their next decode
            # position, most-protected first so pressure lands on the
            # requests the policy would sacrifice anyway
            for st in reversed(self.policy.preempt_order(self.running())):
                if st.status == RUNNING:
                    adv = 1 if advances is None \
                        else max(int(advances.get(st.rid, 1)), 1)
                    self._grow(st, out, adv)
        # advance in-flight chunked prefills before admitting anything new:
        # a half-prefilled slot that stops getting chunks is pure waste
        for st in self.prefilling():
            if st.status == PREFILLING and st not in out.preempt:
                self._plan_chunk(st, out)
        for st in self.policy.admit_order(list(self.pending)):
            # a request preempted in THIS plan keeps its turn for next
            # step — resuming it immediately would just thrash
            if st in out.preempt:
                continue
            if not self._try_admit(st, out):
                break                      # head-of-line: no queue jumping
        for st in self.pending:
            st.wait_steps += 1
        return out

    # -- internals ------------------------------------------------------
    def _preempt(self, victim: RequestState, out: StepPlan) -> None:
        # a mid-prefill victim has sampled nothing: recompute semantics
        # are exact and free of swap bookkeeping — drop the pages, reset
        # the cursor, re-prefill (chunked again) on re-admission
        mid_prefill = victim.status == PREFILLING
        victim.status = PREEMPTED
        victim.preemptions += 1
        self.preemptions += 1
        self.tracer.event("preempt", track="sched", rid=victim.rid,
                          mode=self.preempt_mode,
                          mid_prefill=mid_prefill)
        victim.prefill_cursor = 0
        victim.forked_len = 0
        if self.kv is not None:
            if self.preempt_mode == "swap" and not mid_prefill:
                n_blocks = self.kv.blocks_for(victim.kv_len)
                victim.swap_block_ids = \
                    self.kv.mapped_pages(victim.slot)[:n_blocks]
                victim.saved_len = victim.kv_len
            self.kv.free(victim.slot)
            self.tables_dirty = True
        # the slot is free for reuse from this moment; the state keeps
        # victim.slot so the executor can save/clear it, and drops it there
        self.slot_req[victim.slot] = None
        self.preempted.append(victim)
        out.preempt.append(victim)

    def _grow(self, st: RequestState, out: StepPlan,
              advance: int = 1) -> bool:
        """Map the page(s) covering ``st``'s next ``advance`` decode
        positions, evicting victims (possibly ``st`` itself) under page
        pressure."""
        return self._grow_to(st, min(st.kv_len + advance, self.max_len),
                             out)

    def _grow_to(self, st: RequestState, target: int,
                 out: StepPlan) -> bool:
        """Map pages so ``st`` covers ``target`` positions, evicting
        victims (possibly ``st`` itself) under page pressure.  Candidates
        are every slot holder — a mid-prefill slot's pages are as
        reclaimable (by recompute) as a decoding slot's."""
        while True:
            try:
                self.kv.alloc(st.slot, target)
                self.tables_dirty = True
                return True
            except PagesExhausted:
                pass
            cands = self.resident()
            victims = self.policy.preempt_order(cands)
            v = victims[0]             # cands always contains st itself
            if v is st and len(cands) == 1:
                # alone and still short: every usable page is already
                # ours, so no later step can ever satisfy this request
                raise RuntimeError(
                    f"scheduler stalled: request {st.rid} needs "
                    f"{self.kv.blocks_for(target)} pages but the pool "
                    f"holds {self.kv.usable_pages}")
            self._preempt(v, out)
            if v is st:
                return False           # sit out; resume when pages free

    def _chunk_end(self, st: RequestState) -> int:
        """Where the next prefill chunk stops: cursor + chunk_tokens,
        capped at the full prompt + generated (recompute resumes replay
        generated tokens through the same chunked path)."""
        n = len(st.prompt) + len(st.generated)
        if self.chunk_tokens is None:
            return n                   # dedupe tail: one chunk to the end
        return min(st.prefill_cursor + self.chunk_tokens, n)

    def _plan_chunk(self, st: RequestState, out: StepPlan) -> None:
        """Emit the next chunk of an in-flight chunked prefill.  The
        final chunk maps one extra position (the slot joins that step's
        decode, mirroring :meth:`_admit_need_tokens`'s +1)."""
        end = self._chunk_end(st)
        n = len(st.prompt) + len(st.generated)
        if self.optimistic:
            target = min(end + 1, self.max_len) if end == n else end
            if not self._grow_to(st, target, out):
                return                 # self-preempted under pressure
        st.prefill_target = end
        self.chunks_planned += 1
        out.prefill.append(st)

    def _admit_need_tokens(self, st: RequestState, shared_len: int,
                           chunked: bool) -> int:
        """KV positions an admission must map up front."""
        if not self.optimistic:
            # classic reservation: everything the request could ever want
            # (max_new is the request's total budget, resumes included)
            return min(len(st.prompt) + st.max_new, self.max_len)
        if st.swap_block_ids is not None:
            # +1: a restored request joins this same step's decode
            return min(st.saved_len + 1, self.max_len)
        if chunked:
            # first chunk only; later chunks grow step by step
            return min(shared_len + self.chunk_tokens, self.max_len)
        n = len(st.prompt) + len(st.generated)
        # +1: a started request joins this same step's decode
        return min(n + 1, self.max_len)

    def _dedupe_probe(self, st: RequestState):
        """Longest page-aligned prompt prefix already materialized in a
        resident slot: returns (shared tokens, source request).  Only
        *full* pages are shared (aliasing needs immutability) and at
        least one tail token is always left to prefill, so the admission
        produces first-token logits."""
        if not self.prefix_dedupe or st.swap_block_ids is not None \
                or not st.prefix_hashes:
            return 0, None
        ps = self.kv.page_size
        n = len(st.prompt) + len(st.generated)
        best_j, best_src = 0, None
        for src in self.resident():
            if not src.prefix_hashes:
                continue
            limit = len(src.prefix_hashes)
            if src.status == PREFILLING:
                # only pages the cursor has fully written are shareable
                limit = min(limit, src.prefill_cursor // ps)
            limit = min(limit, len(st.prefix_hashes), (n - 1) // ps)
            for j in range(limit, best_j, -1):
                # chained digests: one equality implies the whole prefix
                if st.prefix_hashes[j - 1] == src.prefix_hashes[j - 1]:
                    best_j, best_src = j, src
                    break
        return best_j * ps, best_src

    def _free_slot(self) -> Optional[int]:
        for i, occ in enumerate(self.slot_req):
            if occ is None:
                return i
        return None

    def _try_admit(self, st: RequestState, out: StepPlan) -> bool:
        n = len(st.prompt) + len(st.generated)
        shared_len, src = self._dedupe_probe(st)
        chunked = (self.chunk_tokens is not None
                   and st.swap_block_ids is None
                   and n - shared_len > self.chunk_tokens)
        # any admission that does not land fully-materialized goes through
        # the prefilling state: chunked prompts, and dedupe hits (which
        # prefill only the tail past the forked prefix)
        prefilling = chunked or shared_len > 0
        need_tokens = self._admit_need_tokens(st, shared_len, chunked)
        need_blocks = 0 if self.kv is None \
            else self.kv.blocks_for(need_tokens) \
            - self.kv.blocks_for(shared_len)
        slot = self._free_slot()
        avail = None if self.kv is None else self.kv.free_pages
        victims: List[RequestState] = []
        if slot is None or (avail is not None and avail < need_blocks):
            # plan the minimal policy-sanctioned eviction set first, so a
            # doomed admission preempts nobody; requests started earlier
            # in THIS plan are never victims — they have not prefilled
            # yet, and appearing in both start and preempt would hand the
            # executor a contradiction.  The dedupe source is spared too:
            # evicting it would free the pages we are about to alias.
            cands = [v for v in self.policy.preempt_order(self.running())
                     if v.status == RUNNING and v not in out.start
                     and v is not src
                     and self.policy.may_preempt(st, v)]
            have_slot = slot is not None
            for v in cands:
                if have_slot and (avail is None or avail >= need_blocks):
                    break
                victims.append(v)
                have_slot = True
                if avail is not None:
                    avail += len(self.kv.mapped_pages(v.slot))
            if not have_slot or (avail is not None
                                 and avail < need_blocks):
                return False
        for v in victims:
            self._preempt(v, out)
        if slot is None:
            slot = victims[0].slot
        if self.kv is not None:
            if shared_len:
                self.kv.fork_aligned(src.slot, slot, shared_len)
                self.tables_dirty = True
            try:
                self.kv.alloc(slot, need_tokens)
            except PagesExhausted:
                # shared (forked) pages can make a victim's mapped count
                # an over-estimate of what freeing reclaims
                if shared_len:
                    self.kv.free(slot)   # undo the fork's aliases
                return False
            self.tables_dirty = True
        resume = st in self.preempted
        if st in self.waiting:
            self.waiting.remove(st)
        if resume:
            self.preempted.remove(st)
        self.tracer.event("resume" if resume else "admit", track="sched",
                          rid=st.rid, slot=slot,
                          wait_steps=st.wait_steps)
        st.slot = slot
        st.resumed_at = len(st.generated)
        st.wait_steps = 0
        self.slot_req[slot] = st
        if prefilling:
            st.status = PREFILLING
            st.prefill_cursor = shared_len
            st.forked_len = shared_len
            if shared_len:
                self.dedupe_hits += 1
                self.dedupe_tokens += shared_len
            self._plan_chunk(st, out)  # first chunk rides this same plan
        else:
            st.status = RUNNING
            out.start.append(st)
        return True
