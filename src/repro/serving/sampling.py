"""Token samplers: greedy / temperature / top-k / nucleus (top-p).

All samplers are jit-safe pure functions (B, V) fp32 logits -> (B,) int32.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    kind: str = "greedy"        # greedy | temperature | topk | topp
    temperature: float = 1.0
    top_k: int = 40
    top_p: float = 0.9


def greedy(logits: jax.Array, key: Optional[jax.Array] = None) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(logits: jax.Array, key: jax.Array,
                       temperature: float = 1.0) -> jax.Array:
    t = max(temperature, 1e-4)
    return jax.random.categorical(key, logits / t).astype(jnp.int32)


def topk_sample(logits: jax.Array, key: jax.Array, k: int = 40,
                temperature: float = 1.0) -> jax.Array:
    vals, idx = jax.lax.top_k(logits, k)
    t = max(temperature, 1e-4)
    choice = jax.random.categorical(key, vals / t)
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0] \
        .astype(jnp.int32)


def topp_sample(logits: jax.Array, key: jax.Array, p: float = 0.9,
                temperature: float = 1.0) -> jax.Array:
    t = max(temperature, 1e-4)
    probs = jax.nn.softmax(logits / t, axis=-1)
    sorted_probs = jnp.sort(probs, axis=-1)[:, ::-1]
    csum = jnp.cumsum(sorted_probs, axis=-1)
    # smallest set with cumulative mass >= p: keep tokens whose prob >= cutoff
    cutoff_idx = jnp.sum(csum < p, axis=-1)
    cutoff = jnp.take_along_axis(sorted_probs, cutoff_idx[:, None], axis=-1)
    masked = jnp.where(probs >= cutoff, jnp.log(probs + 1e-30), -1e30)
    return jax.random.categorical(key, masked).astype(jnp.int32)


def make_sampler(cfg: SamplerConfig):
    if cfg.kind == "greedy":
        return lambda logits, key: greedy(logits)
    if cfg.kind == "temperature":
        return lambda logits, key: temperature_sample(
            logits, key, cfg.temperature)
    if cfg.kind == "topk":
        return lambda logits, key: topk_sample(logits, key, cfg.top_k,
                                               cfg.temperature)
    if cfg.kind == "topp":
        return lambda logits, key: topp_sample(logits, key, cfg.top_p,
                                               cfg.temperature)
    raise ValueError(f"unknown sampler {cfg.kind!r}")
