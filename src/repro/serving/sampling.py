"""Token samplers: greedy / temperature / top-k / nucleus (top-p).

All samplers are jit-safe pure functions (B, V) fp32 logits -> (B,) int32.

Two generations of API live here:

  * the original whole-batch samplers (``greedy`` / ``temperature_sample``
    / ... / ``make_sampler(SamplerConfig)``) apply ONE sampler config to
    every row — kept for the jitted scan-resident decode step, where
    sampling fuses into the compiled loop;
  * the request-level API (:class:`SamplingParams`, :func:`pack_sampling`,
    :func:`sample_rows`) vectorizes the sampler *parameters* over rows:
    each row carries its own kind/temperature/top-k/top-p and its own PRNG
    key, so one decode batch can mix greedy and stochastic requests.

Row independence is the load-bearing property of :func:`sample_rows`:
every row's draw depends only on that row's logits and that row's key —
never on its position in the batch or on the other rows.  Per-request
keys (:func:`request_key` / :func:`step_key`) are derived from the
request id and its generated-token count, so reordering or compacting
the batch (the paged batcher drops finished slots) cannot renumber the
stream a stochastic sampler draws from: paged and dense decode are
token-identical, not merely identical in distribution.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    kind: str = "greedy"        # greedy | temperature | topk | topp
    temperature: float = 1.0
    top_k: int = 40
    top_p: float = 0.9


def greedy(logits: jax.Array, key: Optional[jax.Array] = None) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(logits: jax.Array, key: jax.Array,
                       temperature: float = 1.0) -> jax.Array:
    t = max(temperature, 1e-4)
    return jax.random.categorical(key, logits / t).astype(jnp.int32)


def topk_sample(logits: jax.Array, key: jax.Array, k: int = 40,
                temperature: float = 1.0) -> jax.Array:
    vals, idx = jax.lax.top_k(logits, k)
    t = max(temperature, 1e-4)
    choice = jax.random.categorical(key, vals / t)
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0] \
        .astype(jnp.int32)


def topp_sample(logits: jax.Array, key: jax.Array, p: float = 0.9,
                temperature: float = 1.0) -> jax.Array:
    t = max(temperature, 1e-4)
    probs = jax.nn.softmax(logits / t, axis=-1)
    sorted_probs = jnp.sort(probs, axis=-1)[:, ::-1]
    csum = jnp.cumsum(sorted_probs, axis=-1)
    # smallest set with cumulative mass >= p: keep tokens whose prob >= cutoff
    cutoff_idx = jnp.sum(csum < p, axis=-1)
    cutoff = jnp.take_along_axis(sorted_probs, cutoff_idx[:, None], axis=-1)
    masked = jnp.where(probs >= cutoff, jnp.log(probs + 1e-30), -1e30)
    return jax.random.categorical(key, masked).astype(jnp.int32)


def make_sampler(cfg: SamplerConfig):
    if cfg.kind == "greedy":
        return lambda logits, key: greedy(logits)
    if cfg.kind == "temperature":
        return lambda logits, key: temperature_sample(
            logits, key, cfg.temperature)
    if cfg.kind == "topk":
        return lambda logits, key: topk_sample(logits, key, cfg.top_k,
                                               cfg.temperature)
    if cfg.kind == "topp":
        return lambda logits, key: topp_sample(logits, key, cfg.top_p,
                                               cfg.temperature)
    raise ValueError(f"unknown sampler {cfg.kind!r}")


# ---------------------------------------------------------------------------
# Request-level sampling: per-row parameters, per-request PRNG streams.
# ---------------------------------------------------------------------------

_KINDS = ("greedy", "temperature", "topk", "topp")
_KIND_ID = {k: i for i, k in enumerate(_KINDS)}


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling parameters (the serving front door's unit).

    ``top_k <= 0`` disables top-k truncation; ``top_p >= 1`` disables
    nucleus truncation — both filters compose, so ``kind="topp"`` with a
    positive ``top_k`` applies both.  ``seed`` pins the request's PRNG
    stream; ``None`` derives it from the scheduler's base key and the
    request id (:func:`request_key`).
    """

    kind: str = "greedy"        # greedy | temperature | topk | topp
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    # None = no logprobs; k >= 0 = record each sampled token's logprob
    # plus its k most likely alternatives (k=0: the chosen token only)
    logprobs: Optional[int] = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown sampler kind {self.kind!r}")
        if self.logprobs is not None and self.logprobs < 0:
            raise ValueError("logprobs must be None or >= 0")

    @classmethod
    def from_config(cls, cfg: SamplerConfig,
                    seed: Optional[int] = None) -> "SamplingParams":
        """Lift a whole-batch :class:`SamplerConfig` to request level."""
        return cls(kind=cfg.kind, temperature=cfg.temperature,
                   top_k=cfg.top_k if cfg.kind == "topk" else 0,
                   top_p=cfg.top_p if cfg.kind == "topp" else 1.0,
                   seed=seed)


def request_key(base_key: jax.Array, rid: int,
                params: SamplingParams) -> jax.Array:
    """The PRNG key owning one request's whole sampling stream."""
    if params.seed is not None:
        return jax.random.PRNGKey(params.seed)
    return jax.random.fold_in(base_key, rid)


def step_key(req_key: jax.Array, n_generated: int) -> jax.Array:
    """Key for the request's ``n_generated``-th sampled token (0-based).

    Indexing by the request's own token count — not by decode-step or
    batch-row number — is what makes draws independent of scheduling.
    """
    return jax.random.fold_in(req_key, n_generated)


def pack_sampling(params: Sequence[SamplingParams]) -> Dict[str, jax.Array]:
    """Row-vectorize a list of per-request params into device arrays."""
    return {
        "kind": jnp.asarray([_KIND_ID[p.kind] for p in params], jnp.int32),
        "temperature": jnp.asarray([p.temperature for p in params],
                                   jnp.float32),
        "top_k": jnp.asarray([p.top_k for p in params], jnp.int32),
        "top_p": jnp.asarray([p.top_p for p in params], jnp.float32),
    }


def sample_rows(logits: jax.Array, keys: jax.Array,
                packed: Dict[str, jax.Array],
                top_logprobs: Optional[int] = None):
    """Sample one token per row under per-row parameters.  Jit-safe.

    ``logits``: (B, V) fp; ``keys``: (B, 2) uint32 stacked PRNG keys (one
    per row — rows with ``kind="greedy"`` never consume theirs);
    ``packed``: :func:`pack_sampling` output with (B,) leaves.

    One descending sort per row serves every kind: top-k keeps the first
    ``k`` sorted positions, top-p keeps the smallest prefix whose
    cumulative mass reaches ``p`` (the crossing token included), and the
    draw is a per-row categorical over the surviving sorted logits with
    that row's own key.  Position 0 always survives, so the filters can
    never empty a row.

    With ``top_logprobs`` (an int >= 0) the same sort also yields the
    serving-API logprob payload — returns ``(tokens, info)`` where
    ``info`` holds ``logprob`` (B,) for the sampled token and
    ``top_tokens`` / ``top_logprobs`` (B, k) alternatives, all under the
    raw model distribution (argsort order is temperature-invariant, so
    no second sort is ever needed).
    """
    logits = logits.astype(jnp.float32)
    n_vocab = logits.shape[-1]
    t = jnp.maximum(packed["temperature"], 1e-4)[:, None]
    order = jnp.argsort(logits, axis=-1)[:, ::-1]
    sorted_scaled = jnp.take_along_axis(logits / t, order, axis=-1)
    probs = jax.nn.softmax(sorted_scaled, axis=-1)
    pos = jnp.arange(n_vocab)[None, :]
    k = packed["top_k"][:, None]
    keep = jnp.where(k > 0, pos < k, True)
    csum = jnp.cumsum(probs, axis=-1)
    keep &= (csum - probs) < packed["top_p"][:, None]
    keep = keep.at[:, 0].set(True)
    masked = jnp.where(keep, sorted_scaled, -jnp.inf)
    choice = jax.vmap(jax.random.categorical)(keys, masked)
    sampled = jnp.take_along_axis(order, choice[:, None], axis=-1)[:, 0]
    toks = jnp.where(packed["kind"] == _KIND_ID["greedy"],
                     jnp.argmax(logits, axis=-1),
                     sampled).astype(jnp.int32)
    if top_logprobs is None:
        return toks
    kk = max(int(top_logprobs), 0)
    log_z = jax.scipy.special.logsumexp(logits, axis=-1)
    chosen = jnp.take_along_axis(logits, toks[:, None], axis=-1)[:, 0] \
        - log_z
    sorted_raw = jnp.take_along_axis(logits, order[:, :kk], axis=-1)
    info = {"logprob": chosen,
            "top_tokens": order[:, :kk],
            "top_logprobs": sorted_raw - log_z[:, None]}
    return toks, info
