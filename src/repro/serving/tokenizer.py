"""Tokenizer-aware text IO for the serving front door (ROADMAP item).

The serving stack is token-native — every queue, cache, and sampler
works on int32 ids — so text support is a thin boundary layer: a
:class:`Tokenizer` protocol (``encode``/``decode`` plus an eos id) that
the :class:`repro.serving.api.LLM` facade calls at submit time and in
its output/streaming paths.  Anything with those two methods plugs in
(a sentencepiece/BPE wrapper in real deployments); the in-repo default
is :class:`ByteTokenizer`, which maps UTF-8 bytes to ids 0..255 — no
vocabulary files, works with any model whose vocab covers 256 ids, and
is exactly what the tiny test config needs.

Streaming text is stateful: a token boundary can split a multi-byte
UTF-8 character, so :class:`StreamDecoder` buffers incomplete suffixes
and only releases whole characters — a facade stream yields ``""`` for
a token that ends mid-character and the full character once its last
byte arrives.
"""

from __future__ import annotations

from typing import List, Optional, Protocol, Sequence, runtime_checkable


@runtime_checkable
class Tokenizer(Protocol):
    """The text boundary: ids in, ids out; everything inside is tokens.

    ``eos_id`` may be None (no end-of-sequence convention); the facade
    threads it into submissions that don't pass an explicit ``eos``.
    """

    eos_id: Optional[int]

    def encode(self, text: str) -> List[int]: ...

    def decode(self, tokens: Sequence[int]) -> str: ...


class ByteTokenizer:
    """UTF-8 bytes as token ids 0..255 (vocab 256 + optional eos).

    ``eos_id`` defaults to 0 (the NUL byte, which never appears in
    sensible text); pass ``eos_id=None`` to disable.  Ids outside 0..255
    decode as the replacement character rather than raising — a sampled
    model token need not be a valid byte.
    """

    vocab_size = 256

    def __init__(self, eos_id: Optional[int] = 0):
        self.eos_id = eos_id

    def encode(self, text: str) -> List[int]:
        return list(text.encode("utf-8"))

    def decode(self, tokens: Sequence[int]) -> str:
        data = bytes(max(0, min(int(t), 255)) for t in tokens)
        return data.decode("utf-8", errors="replace")


class StreamDecoder:
    """Incremental UTF-8 decoding over a token stream.

    ``push(token)`` returns the text completed by that token — possibly
    ``""`` while a multi-byte character is still accumulating; ``flush``
    drains whatever trailing bytes remain (replacement characters for an
    incomplete tail)."""

    def __init__(self, tok: Tokenizer):
        self.tok = tok
        self._pending: List[int] = []

    def push(self, token: int) -> str:
        self._pending.append(int(token))
        text = self.tok.decode(self._pending)
        # a trailing replacement char usually means a split character —
        # hold the bytes back until the sequence completes or diverges
        if text.endswith("�"):
            probe = self.tok.decode(self._pending[-1:])
            if probe == "�" and len(self._pending) < 8:
                return ""
        self._pending = []
        return text

    def flush(self) -> str:
        text = self.tok.decode(self._pending)
        self._pending = []
        return text
