"""Pluggable linear backends — one layer-math core, many executions.

The decoder math for the dense GQA families is written once
(:func:`repro.models.model.decoder_layer` /
:func:`repro.models.model.backend_prefill`) with every weight matmul routed
through an injected ``linear(x, name)`` callable.  This module provides the
two concrete executions of that seam:

    ResidentBackend   weights live in accelerator memory; the whole forward
                      is jitted (prefill/decode compiled once per shape,
                      decode cache donated) — the production resident path.
    HeteGenBackend    weights live in host memory; linears execute through
                      :class:`repro.core.engine.HeteGenEngine` under a
                      batch-aware placement plan (resident / alpha-split /
                      streamed), eagerly layer by layer, exactly how
                      offloading runtimes run.

Both expose the same driver surface — ``init_cache`` / ``prefill`` /
``decode`` / ``linear`` — so :class:`repro.serving.engine.Generator` and
:class:`repro.serving.batcher.ContinuousBatcher` schedule over either one
interchangeably, and their outputs match to fp tolerance
(tests/test_backends.py).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alpha import resolve_phase_tokens
from repro.core.engine import HeteGenEngine, ModulePlan, StreamStats
from repro.core.hw import HardwareSpec, TPU_V5E
from repro.core.policy import LinearSpec, PolicyResult, build_policy
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.kv_cache import PagedKVCache
from repro.telemetry.recalibrate import recalibrate_alpha
from repro.telemetry.tracer import NULL_TRACER, Tracer


@runtime_checkable
class LinearBackend(Protocol):
    """The backend seam: everything the shared layer math needs.

    ``linear(x, name)`` computes ``x @ W[name]`` with bias applied, for the
    flat linear names produced by :func:`enumerate_linears`
    ("blk{l}.wq", "blk{l}.w_down", ...).  ``cache_batch_axis`` is the axis
    carrying the batch in every cache buffer (the continuous batcher's
    slot-merge axis).

    The serving **phase** is part of the seam: ``prefill`` and ``decode``
    are distinct entry points because their placement economics differ
    (paper §4.1 — prefill is compute-bound, decode link-bound), and a
    planning backend may execute them under different plans.  Backends
    that re-plan expose ``retune(batch, phase=..., tokens_per_seq=...)``;
    schedulers probe for it with ``hasattr`` (resident backends don't
    plan, so it is not part of the required protocol).  Backends with a
    staging pipeline may likewise expose ``prefetch_next_step()`` — the
    executor calls it between a decode step's math and its host-side
    sampling so step N+1's weight pins overlap step N's tail.

    Backends may also expose ``verify(batch, cache)`` — a prefill-shaped
    step that returns logits for **all** positions (B, S, V) instead of
    just the last, the scoring pass of speculative decoding.  The batcher
    probes for it with ``hasattr``; backends without it cannot serve
    speculative requests.
    """

    cache_batch_axis: int

    def linear(self, x: jax.Array, name: str) -> jax.Array: ...

    def init_cache(self, batch: int, max_len: int) -> Dict: ...

    def init_paged_cache(self, batch: int, max_len: int, *,
                         page_size: int = 16,
                         n_pages: Optional[int] = None,
                         kv_dtype: Optional[str] = None,
                         check: bool = False
                         ) -> "PagedKVCache": ...

    def prefill(self, batch: Dict, cache: Dict
                ) -> Tuple[Dict, jax.Array]: ...

    def decode(self, token: jax.Array, cache: Dict
               ) -> Tuple[Dict, jax.Array]: ...

    def close(self) -> None: ...


def enumerate_linears(cfg: ModelConfig,
                      wstream: str = "fp") -> List[LinearSpec]:
    """The model's offloadable linears with size groups (paper §4.3).

    ``wstream`` stamps the streamed wire format on every spec so the
    policy layer prices the link in wire bytes (``LinearSpec.wire_bytes``)
    while compute stays in fp bytes."""
    by = cfg.dtype_bytes()
    hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    d, f = cfg.d_model, cfg.d_ff
    ws = wstream

    def spec(name, n_in, n_out, group):
        return LinearSpec(name, n_in, n_out, group, by, wire=ws)

    out = []
    for l in range(cfg.n_layers):
        out += [
            spec(f"blk{l}.wq", d, hq * hd, "attn"),
            spec(f"blk{l}.wk", d, hkv * hd, "attn_kv"),
            spec(f"blk{l}.wv", d, hkv * hd, "attn_kv"),
            spec(f"blk{l}.wo", hq * hd, d, "attn"),
        ]
        if cfg.mlp_kind.startswith("gated"):
            out += [spec(f"blk{l}.w_gate", d, f, "mlp"),
                    spec(f"blk{l}.w_up", d, f, "mlp"),
                    spec(f"blk{l}.w_down", f, d, "mlp_down")]
        else:
            out += [spec(f"blk{l}.w_in", d, f, "mlp"),
                    spec(f"blk{l}.w_down", f, d, "mlp_down")]
    return out


def _np(x) -> np.ndarray:
    return np.asarray(jax.device_get(x))


class ResidentBackend:
    """Device-resident weights; the shared forward jitted end to end.

    Construction materializes an unstacked copy of every linear (jax
    indexing copies, it does not view), so a caller that also keeps the
    stacked ``params`` tree alive holds ~2x the weight bytes on the
    device — drop the stacked tree after construction when serving large
    models through this backend.
    """

    cache_batch_axis = 0

    def __init__(self, cfg: ModelConfig, params: Dict):
        self.cfg = cfg
        shared, weights, biases = M.extract_backend_params(cfg, params)
        self.shared = shared
        self.weights = {k: jnp.asarray(v) for k, v in weights.items()}
        self.biases = {k: jnp.asarray(v) for k, v in biases.items()}

        def _linear_from(weights, biases):
            def lin(x, name):
                y = x @ weights[name]
                b = biases.get(name)
                return y if b is None else y + b
            return lin

        self._lin = _linear_from(self.weights, self.biases)

        def _prefill(shared, weights, biases, batch, cache):
            return M.backend_prefill(cfg, shared, batch, cache,
                                     linear=_linear_from(weights, biases))

        def _decode(shared, weights, biases, token, cache):
            return M.backend_decode(cfg, shared, token, cache,
                                    linear=_linear_from(weights, biases))

        def _verify(shared, weights, biases, batch, cache):
            return M.backend_prefill(cfg, shared, batch, cache,
                                     linear=_linear_from(weights, biases),
                                     all_logits=True)

        # the cache is donated in ALL steps: callers never reuse the
        # input cache, and for paged admission donation lets the page
        # pools update in place instead of copying every pool per admit
        self._prefill = jax.jit(_prefill, donate_argnums=(4,))
        self._decode = jax.jit(_decode, donate_argnums=(4,))
        self._verify = jax.jit(_verify, donate_argnums=(4,))

    # -- LinearBackend surface -----------------------------------------
    def linear(self, x: jax.Array, name: str) -> jax.Array:
        return self._lin(x, name)

    def init_cache(self, batch: int, max_len: int) -> Dict:
        return M.init_backend_cache(self.cfg, batch, max_len)

    def init_paged_cache(self, batch: int, max_len: int, *,
                         page_size: int = 16,
                         n_pages: Optional[int] = None,
                         kv_dtype: Optional[str] = None,
                         check: bool = False) -> PagedKVCache:
        return PagedKVCache(self.cfg, batch, max_len, page_size=page_size,
                            n_pages=n_pages, kv_dtype=kv_dtype, check=check)

    def prefill(self, batch: Dict, cache: Dict) -> Tuple[Dict, jax.Array]:
        return self._prefill(self.shared, self.weights, self.biases,
                             batch, cache)

    def decode(self, token: jax.Array, cache: Dict
               ) -> Tuple[Dict, jax.Array]:
        return self._decode(self.shared, self.weights, self.biases,
                            token, cache)

    def verify(self, batch: Dict, cache: Dict) -> Tuple[Dict, jax.Array]:
        """Score all positions of a draft run: (B, S) tokens in, logits
        (B, S, V) out — one prefill-shaped step replaces S decode steps."""
        return self._verify(self.shared, self.weights, self.biases,
                            batch, cache)

    def close(self) -> None:
        pass


class ScanResidentBackend:
    """The scan-stacked resident path behind the backend driver surface.

    Wraps ``M.prefill`` / ``M.decode_step`` over the stacked params — the
    compiled trunk the :class:`repro.serving.engine.Generator` runs by
    default.  Unlike :class:`ResidentBackend` it supports every transformer
    family (MLA, MoE, int8 KV, encdec), but its per-linear execution is not
    pluggable; the batch axis of its cache leaves is 1 (stack-major).
    """

    cache_batch_axis = 1

    def __init__(self, cfg: ModelConfig, params: Dict):
        self.cfg = cfg
        self.params = params

        def _prefill(params, batch, cache):
            return M.prefill(cfg, params, batch, cache)

        def _decode(params, token, cache):
            return M.decode_step(cfg, params, token, cache)

        def _verify(params, batch, cache):
            return M.prefill(cfg, params, batch, cache, all_logits=True)

        self._prefill_fn = jax.jit(_prefill)
        self._decode_fn = jax.jit(_decode, donate_argnums=(2,))
        self._verify_fn = jax.jit(_verify, donate_argnums=(2,))

    def init_cache(self, batch: int, max_len: int) -> Dict:
        return M.init_cache(self.cfg, batch, max_len)

    def init_paged_cache(self, batch: int, max_len: int, **kw):
        raise NotImplementedError(
            "the scan-stacked cache is not pageable; use ResidentBackend "
            "or HeteGenBackend for paged serving")

    def prefill(self, batch: Dict, cache: Dict) -> Tuple[Dict, jax.Array]:
        return self._prefill_fn(self.params, batch, cache)

    def decode(self, token: jax.Array, cache: Dict
               ) -> Tuple[Dict, jax.Array]:
        return self._decode_fn(self.params, token, cache)

    def verify(self, batch: Dict, cache: Dict) -> Tuple[Dict, jax.Array]:
        return self._verify_fn(self.params, batch, cache)

    def close(self) -> None:
        pass


class HeteGenBackend:
    """HeteGen-scheduled offloaded execution of the shared layer math.

    Weights live in host memory; every ``linear`` runs through a threaded
    :class:`HeteGenEngine` under a placement plan built for the *real*
    workload — §4.1's cost model shifts the optimal alpha with compute
    intensity, so ``retune(batch, phase=...)`` rebuilds the plan (and the
    engine's weight partition) whenever the serving batch changes.

    The backend is **phase-aware** (docs/SERVING.md): it holds one plan
    and one engine partition per serving phase.  Decode moves every weight
    byte to produce ``batch`` tokens (link/host bound — small alpha, the
    host GEMM earns its keep), while prefill computes ``batch * prompt``
    positions against the same traffic (compute bound — alpha -> 1, stream
    nearly everything to the accelerator).  ``prefill``/``decode`` route
    their linears through their own phase's partition; the prefill plan is
    (re)tuned lazily from the observed prompt shape, with a multiplicative
    hysteresis (``prefill_retune_factor``) so prompt-length jitter does
    not rebuild the engine.  Engines share device-resident module copies
    through a common ``resident_store``, so dual plans never duplicate
    promoted weights on the accelerator.
    """

    cache_batch_axis = 0

    def __init__(self, cfg: ModelConfig, params: Dict, *,
                 hw: HardwareSpec = TPU_V5E,
                 budget_bytes: Optional[float] = None,
                 batch: int = 1,
                 use_alpha_benchmark: bool = True,
                 use_module_scheduler: bool = True,
                 alpha_override: Optional[float] = None,
                 phase_plans: bool = True,
                 prefill_retune_factor: float = 2.0,
                 tracer: Tracer = NULL_TRACER,
                 recalibrate: Optional[float] = None,
                 recalibrate_every: int = 16,
                 wstream: str = "fp"):
        if wstream not in ("fp", "q8"):
            raise ValueError(f"unknown wire format {wstream!r} "
                             "(expected 'fp' or 'q8')")
        self.cfg = cfg
        shared, weights, biases = M.extract_backend_params(cfg, params)
        self.shared = shared
        self._host_weights = {k: _np(v) for k, v in weights.items()}
        self._host_biases = {k: _np(v) for k, v in biases.items()}
        self._ops = M.make_backend_ops(cfg)   # jitted norms/attention/head
        self.wstream = wstream
        self.linears = enumerate_linears(cfg, wstream=wstream)
        self.hw = hw
        self.budget_bytes = budget_bytes
        self.use_alpha_benchmark = use_alpha_benchmark
        self.use_module_scheduler = use_module_scheduler
        self.alpha_override = alpha_override
        self.phase_plans = phase_plans
        self.prefill_retune_factor = max(float(prefill_retune_factor), 1.0)
        self.batch: Optional[int] = None
        self.policies: Dict[str, PolicyResult] = {}
        self.engines: Dict[str, HeteGenEngine] = {}
        self._resident_store: Dict[str, jax.Array] = {}
        self._stats_tally = StreamStats()   # closed engines' busy seconds
        self._phase = "decode"
        self.step_prefetches = 0            # cross-step prefetch nudges
        self.tracer = tracer
        # trace-driven alpha recalibration (docs/OBSERVABILITY.md): when
        # set, every `recalibrate_every` decode steps the measured stream
        # speeds re-solve Eq. 10-12 and the decode plan is rebuilt if the
        # refined alpha drifted by more than `recalibrate` (absolute).
        self.recalibrate = recalibrate
        self.recalibrate_every = max(int(recalibrate_every), 1)
        self.recalibrations = 0
        self.last_fit = None                # most recent trace FitResult
        self._recal_steps = 0
        self._recal_mark = tracer.mark() if tracer else 0.0
        self.retune(batch)

    # -- phase/batch-aware planning ------------------------------------
    @property
    def policy(self) -> Optional[PolicyResult]:
        """The decode-phase plan (the historical single-plan surface)."""
        return self.policies.get("decode")

    @property
    def engine(self) -> Optional[HeteGenEngine]:
        """The decode-phase engine (the historical single-engine surface)."""
        return self.engines.get("decode")

    def retune(self, batch: int, phase: str = "decode", *,
               tokens_per_seq: Optional[int] = None) -> PolicyResult:
        """(Re)build ``phase``'s placement plan and engine for ``batch``.

        No-op when the phase already holds a plan for exactly this
        (batch, tokens_per_seq); the soft (hysteresis-guarded) prefill
        path is :meth:`_ensure_prefill_plan`.
        """
        batch = max(int(batch), 1)
        tokens_per_seq = resolve_phase_tokens(phase, tokens_per_seq)
        cur = self.policies.get(phase)
        if cur is not None and cur.batch == batch \
                and cur.tokens_per_seq == tokens_per_seq:
            return cur
        pol = build_policy(
            self.linears, self.hw, budget_bytes=self.budget_bytes,
            batch=batch, phase=phase, tokens_per_seq=tokens_per_seq,
            use_alpha_benchmark=self.use_alpha_benchmark,
            use_module_scheduler=self.use_module_scheduler)
        if self.alpha_override is not None:
            pol.plan = [
                ModulePlan(p.name, p.group, p.mode,
                           self.alpha_override if p.mode == "hetegen"
                           else p.alpha)
                for p in pol.plan]
        old = self.engines.pop(phase, None)
        if old is not None:
            # a replaced partition's busy seconds still happened: bank
            # them so finish_stats never undercounts across retunes
            self._stats_tally = self._stats_tally + old.finish_stats()
            old.close()
        self.policies[phase] = pol
        # drop store entries no current plan keeps resident BEFORE building
        # the new engine, so stale device copies are released
        keep = {p.name for r in self.policies.values()
                for p in r.plan if p.mode == "resident"}
        for name in list(self._resident_store):
            if name not in keep:
                del self._resident_store[name]
        eng = HeteGenEngine(self._host_weights, pol.plan,
                            biases=self._host_biases,
                            resident_store=self._resident_store,
                            tracer=self.tracer, trace_phase=phase,
                            wstream=self.wstream)
        eng.warm_prefetch()
        self.engines[phase] = eng
        if phase == "decode":
            self.batch = batch
        return pol

    def _ensure_prefill_plan(self, batch: int, seq: int) -> None:
        """Tune the prefill plan to the observed prompt shape, with
        multiplicative hysteresis: rebuild only when the observed
        intensity leaves [cur/f, cur*f] (prompt-length jitter across
        requests must not thrash the engine partition)."""
        cur = self.policies.get("prefill")
        intensity = max(batch, 1) * max(seq, 1)
        if cur is not None:
            f = self.prefill_retune_factor
            if cur.intensity / f <= intensity <= cur.intensity * f:
                return
        self.retune(batch, phase="prefill", tokens_per_seq=seq)

    def _ensure_verify_plan(self, batch: int, seq: int) -> None:
        """Tune the verify plan to the observed draft-run shape.

        Verification is its own phase, NOT a reuse of the prefill plan:
        admission prefills run at intensity batch x prompt_len (hundreds
        of tokens) while verify runs at batch x (k + 1) (a handful), and
        sharing one plan would make the hysteresis thrash between the two
        regimes on every interleaved step.  Same multiplicative guard so
        adaptive-k wobble does not rebuild the engine."""
        cur = self.policies.get("verify")
        intensity = max(batch, 1) * max(seq, 1)
        if cur is not None:
            f = self.prefill_retune_factor
            if cur.intensity / f <= intensity <= cur.intensity * f:
                return
        self.retune(batch, phase="verify", tokens_per_seq=seq)

    # -- tracing + trace-driven recalibration --------------------------
    def set_tracer(self, tracer: Tracer) -> None:
        """Attach a tracer to the backend and every live phase engine
        (the LLM facade calls this when ``trace=`` is enabled after the
        backend was constructed)."""
        self.tracer = tracer
        self._recal_mark = tracer.mark() if tracer else 0.0
        for phase, eng in self.engines.items():
            eng.set_tracer(tracer, trace_phase=phase)

    def recalibrate_from_trace(self, phase: str = "decode"):
        """Refine ``phase``'s alpha from the spans recorded since the
        last recalibration; returns the ``FitResult`` (or None if the
        trace has no measurable spans for that phase — e.g. an all-
        resident plan, or tracing disabled)."""
        pol = self.policies.get(phase)
        if pol is None or not self.tracer:
            return None
        spans = self.tracer.spans(since=self._recal_mark or None)
        try:
            fit = recalibrate_alpha(spans, pol.alpha, phase=phase)
        except ValueError:
            return None
        self.last_fit = fit
        return fit

    def _apply_alpha(self, phase: str, alpha: float) -> None:
        """Rebuild ``phase``'s engine with a new hetegen alpha, keeping
        the residency/streaming decisions of the existing plan."""
        pol = self.policies[phase]
        pol.plan = [ModulePlan(p.name, p.group, p.mode,
                               alpha if p.mode == "hetegen" else p.alpha)
                    for p in pol.plan]
        pol.alpha = float(alpha)
        old = self.engines.pop(phase, None)
        if old is not None:
            self._stats_tally = self._stats_tally + old.finish_stats()
            old.close()
        eng = HeteGenEngine(self._host_weights, pol.plan,
                            biases=self._host_biases,
                            resident_store=self._resident_store,
                            tracer=self.tracer, trace_phase=phase,
                            wstream=self.wstream)
        eng.warm_prefetch()
        self.engines[phase] = eng

    def _maybe_recalibrate(self) -> None:
        """Periodic trace-driven re-tune, called at the top of a decode
        or verify step — the engines are idle there, so swapping a phase
        partition is safe.  Opt-in (``recalibrate=``), with the drift
        threshold acting as hysteresis: a plan is only rebuilt when
        |refined - current| exceeds it.  Every phase that has recorded
        measurable spans since the last mark recalibrates from *its own*
        spans (phase-tagged), so a drifting verify plan re-tunes even
        though decode traffic dominates the trace."""
        if self.recalibrate is None or not self.tracer:
            return
        self._recal_steps += 1
        if self._recal_steps % self.recalibrate_every:
            return
        mark = self.tracer.mark()
        fitted = False
        for phase in ("decode", "verify"):
            if phase not in self.policies:
                continue
            fit = self.recalibrate_from_trace(phase)
            if fit is None:
                continue
            fitted = True
            cur = self.policies[phase].alpha
            if abs(fit.alpha - cur) > self.recalibrate:
                self._apply_alpha(phase, fit.alpha)
                self.recalibrations += 1
        if fitted:
            self._recal_mark = mark

    # -- LinearBackend surface -----------------------------------------
    def linear(self, x: jax.Array, name: str) -> jax.Array:
        eng = self.engines.get(self._phase) or self.engines["decode"]
        return eng.linear(x, name)

    def init_cache(self, batch: int, max_len: int) -> Dict:
        return M.init_backend_cache(self.cfg, batch, max_len)

    def init_paged_cache(self, batch: int, max_len: int, *,
                         page_size: int = 16,
                         n_pages: Optional[int] = None,
                         kv_dtype: Optional[str] = None,
                         check: bool = False) -> PagedKVCache:
        return PagedKVCache(self.cfg, batch, max_len, page_size=page_size,
                            n_pages=n_pages, kv_dtype=kv_dtype, check=check)

    def prefill(self, batch: Dict, cache: Dict) -> Tuple[Dict, jax.Array]:
        if self.phase_plans:
            if "tokens" in batch:
                b, s = batch["tokens"].shape
            else:
                b, s = batch["embeds"].shape[:2]
            self._ensure_prefill_plan(b, s)
            self._phase = "prefill"
        try:
            return M.backend_prefill(self.cfg, self.shared, batch, cache,
                                     linear=self.linear, ops=self._ops)
        finally:
            self._phase = "decode"

    def decode(self, token: jax.Array, cache: Dict
               ) -> Tuple[Dict, jax.Array]:
        self._maybe_recalibrate()
        return M.backend_decode(self.cfg, self.shared, token, cache,
                                linear=self.linear, ops=self._ops)

    def verify(self, batch: Dict, cache: Dict) -> Tuple[Dict, jax.Array]:
        """Speculative scoring pass under the "verify" phase plan —
        intensity batch x (k + 1), the prefill-like regime where alpha
        pushes toward the accelerator even though the step advances the
        decode frontier."""
        self._maybe_recalibrate()
        if self.phase_plans:
            b, s = batch["tokens"].shape
            self._ensure_verify_plan(b, s)
            self._phase = "verify"
        try:
            return M.backend_prefill(self.cfg, self.shared, batch, cache,
                                     linear=self.linear, ops=self._ops,
                                     all_logits=True)
        finally:
            self._phase = "decode"

    def prefetch_next_step(self) -> None:
        """Drive step N+1's pins while step N's host tail drains.

        The engine's wrap-around prefetch order already points the last
        module of a decode step at the first module of the next one
        (:func:`repro.core.param_manager.plan_prefetch_order`), but that
        wrap prefetch is issued while the last module's own slot is still
        staged — when the ring is full it silently loses.  The scheduler
        calls this between a decode step's math and its host-side
        sampling/bookkeeping: by then every slot has been released, so
        re-issuing the first-of-each-group prefetch is guaranteed to
        land, and the pin thread stages the next step concurrently with
        sampling (ROADMAP decode-overlap item).  Idempotent and
        non-blocking — modules already staged are left alone.
        """
        eng = self.engines.get("decode")
        if eng is not None:
            eng.warm_prefetch()
            self.step_prefetches += 1

    # -- stats over all phase engines ----------------------------------
    def reset_stats(self) -> None:
        self._stats_tally = StreamStats()
        for eng in self.engines.values():
            eng.reset_stats()

    def finish_stats(self) -> StreamStats:
        out = self._stats_tally
        for eng in self.engines.values():
            out = out + eng.finish_stats()
        return out

    def device_resident_bytes(self) -> int:
        seen: Dict[str, int] = {}
        for eng in self.engines.values():
            for name, arr in eng._resident.items():
                seen[name] = int(np.prod(arr.shape)) * arr.dtype.itemsize
        return sum(seen.values())

    def pinned_overhead_bytes(self) -> int:
        return sum(eng.pinned_overhead_bytes()
                   for eng in self.engines.values())

    def close(self) -> None:
        for eng in self.engines.values():
            eng.close()
        self.engines.clear()
        self._resident_store.clear()
