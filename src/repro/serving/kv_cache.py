"""Paged KV cache: a block-pool allocator for the offload serving path.

``init_backend_cache`` allocates dense (B, max_len) buffers per layer, so
the continuous batcher's slot admit/release copies whole-cache slices and
long contexts cannot fit alongside offloaded weights.  This module
replaces that with the block-table design of vLLM-style serving: KV
tokens live in fixed-size **pages** drawn from one global pool per layer,
and each slot owns a **block table** mapping logical kv blocks to
physical page ids.  Admission maps pages, release unmaps them — no cache
buffer is ever sliced or merged.

Split of responsibilities:

  * :class:`PagedKVCache` is the *host-side allocator*: free-list,
    ref-counts, per-slot block tables.  It never holds device arrays —
    pools live in the cache dict it mints (:meth:`init_cache`) and flow
    functionally through the model step (which may donate them), while
    the allocator only re-exports its block tables to the device after
    map/unmap events.
  * the *device-side* page pools are plain cache-dict leaves
    ("pages_k{l}" / "pages_v{l}", layout (n_pages, Hkv, page_size, hd) —
    one (page_size, hd) tile per (page, head), the layout the Pallas
    paged decode kernel DMAs directly) consumed by
    :func:`repro.models.model.backend_prefill`'s paged plumbing.

Ref-counts make shared prompt prefixes cheap: :meth:`fork` aliases the
fully-immutable pages of a prefix into another slot's table and bumps
their counts (the trailing partial page is copied, so no copy-on-write
is ever needed mid-decode); pages return to the free list only when the
last owner releases them.

Page id 0 is a reserved trash page: unmapped block-table entries point at
it, so the masked garbage writes of inactive batcher slots land somewhere
harmless instead of in another slot's pages.

Layout decision (recorded for ROADMAP): page_size defaults to 16 tokens —
small enough that a slot wastes < 1 page of KV on average at release,
large enough that the (page_size, hd) kernel tile fills a TPU sublane
register for fp32/bf16 head dims >= 128 lanes.  int8 ("q8") pools carry
per-(page, head, token) fp32 scale pages mirroring the dense int8 cache.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

TRASH_PAGE = 0


class PagesExhausted(RuntimeError):
    """Raised when an allocation needs more pages than the free list has."""


class PagedCacheCorruption(RuntimeError):
    """Raised by the ``check=True`` self-check when an allocator invariant
    is violated (double release, ref-count drift, leaked pages, ...)."""


class PagedKVCache:
    """Block-pool allocator + block tables for a slot-based serving cache.

    ``n_pages`` bounds the pool (page 0 is reserved as trash); the default
    matches dense capacity — ``max_slots * ceil(max_len / page_size)``
    usable pages — but smaller pools are valid and simply make admission
    wait for pages (the OOM-of-pages regime the batcher queues through).
    """

    def __init__(self, cfg: ModelConfig, max_slots: int, max_len: int, *,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None, check: bool = False):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.page_size = page_size
        self.blocks_per_slot = -(-max_len // page_size)
        self.n_pages = (1 + max_slots * self.blocks_per_slot
                        if n_pages is None else int(n_pages))
        if self.n_pages < 2:
            raise ValueError("need at least one usable page beyond trash")
        self.kv_dtype = kv_dtype
        # runtime self-check mode (LLM(selfcheck=True) / serve --selfcheck):
        # validate the free-list/ref-count/table invariants after every
        # mutating operation and refuse double releases / leaked closes
        self.check = check
        self._refcount_max = 0
        # host-side metadata: free list, ref-counts, block tables
        self._free: List[int] = list(range(self.n_pages - 1, TRASH_PAGE, -1))
        self._ref = np.zeros((self.n_pages,), np.int32)
        self._tables = np.full((max_slots, self.blocks_per_slot), TRASH_PAGE,
                               np.int32)
        self._n_blocks = np.zeros((max_slots,), np.int32)

    # -- device-side pool construction ---------------------------------
    def init_cache(self) -> Dict:
        """Mint the cache dict the model's paged plumbing consumes."""
        cfg = self.cfg
        q8 = self.kv_dtype == "int8"
        dt = jnp.int8 if q8 else jnp.dtype(cfg.dtype)
        shape = (self.n_pages, cfg.n_kv_heads, self.page_size, cfg.hd)
        cache: Dict = {"len": jnp.zeros((self.max_slots,), jnp.int32),
                       "block_tables": self.device_block_tables()}
        for l in range(cfg.n_layers):
            cache[f"pages_k{l}"] = jnp.zeros(shape, dt)
            cache[f"pages_v{l}"] = jnp.zeros(shape, dt)
            if q8:
                cache[f"pages_ks{l}"] = jnp.zeros(shape[:3], jnp.float32)
                cache[f"pages_vs{l}"] = jnp.zeros(shape[:3], jnp.float32)
        return cache

    def device_block_tables(self) -> jnp.ndarray:
        """The (max_slots, blocks_per_slot) tables as a device array —
        re-exported after every map/unmap event (tiny: int32 per block)."""
        return jnp.asarray(self._tables)

    # -- allocator -----------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def usable_pages(self) -> int:
        """Pool capacity excluding the reserved trash page."""
        return self.n_pages - 1

    def blocks_for(self, n_tokens: int) -> int:
        return max(-(-n_tokens // self.page_size), 0)

    def alloc(self, slot: int, n_tokens: int) -> None:
        """Map pages so ``slot`` covers ``n_tokens`` logical positions.

        Growth is incremental — already-mapped pages are kept, only the
        shortfall is drawn from the free list — which is what makes
        *optimistic* paging (ROADMAP follow-up, now the scheduler's
        default) a pure policy change: the scheduler simply calls
        ``alloc(slot, kv_len + 1)`` every decode step instead of
        ``alloc(slot, prompt + max_new)`` once at admission, and treats
        :class:`PagesExhausted` as a preemption event instead of an
        admission error.

        All-or-nothing: raises :class:`PagesExhausted` (mapping nothing)
        when the free list cannot cover the growth, so a failed admission
        leaves the pool untouched and the request can simply stay queued.
        """
        need_blocks = self.blocks_for(n_tokens)
        if need_blocks > self.blocks_per_slot:
            raise ValueError(
                f"{n_tokens} tokens exceed max_len={self.max_len}")
        grow = need_blocks - int(self._n_blocks[slot])
        if grow <= 0:
            return
        if grow > len(self._free):
            raise PagesExhausted(
                f"slot {slot} needs {grow} pages, {len(self._free)} free")
        for j in range(int(self._n_blocks[slot]), need_blocks):
            pid = self._free.pop()
            self._ref[pid] = 1
            self._tables[slot, j] = pid
        self._n_blocks[slot] = need_blocks
        self._refcount_max = max(self._refcount_max, 1)
        if self.check:
            self.validate()

    def free(self, slot: int) -> None:
        """Unmap every page of ``slot``; pages whose ref-count hits zero
        return to the free list (shared prefix pages survive)."""
        if self.check and not self._n_blocks[slot]:
            raise PagedCacheCorruption(
                f"double release: slot {slot} holds no pages")
        for j in range(int(self._n_blocks[slot])):
            pid = int(self._tables[slot, j])
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                self._free.append(pid)
        self._tables[slot, :] = TRASH_PAGE
        self._n_blocks[slot] = 0
        if self.check:
            self.validate()

    def fork_aligned(self, src_slot: int, dst_slot: int,
                     n_tokens: int) -> None:
        """Alias ``src_slot``'s first ``n_tokens`` (a multiple of
        ``page_size``) into ``dst_slot`` by reference — pure metadata:
        ref-count bumps and table writes, no page data moves.  This is
        the admission-time prefix-dedupe primitive: page-aligned shared
        prefixes are immutable (prefill only ever appends past them), so
        aliasing is always safe without copy-on-write."""
        if self._n_blocks[dst_slot]:
            raise ValueError(f"dst slot {dst_slot} still holds pages")
        n_full, partial = divmod(n_tokens, self.page_size)
        if partial:
            raise ValueError(
                f"fork_aligned needs page-aligned n_tokens, got {n_tokens}")
        if n_full > int(self._n_blocks[src_slot]):
            raise ValueError("fork extends past src slot's mapped pages")
        for j in range(n_full):
            pid = int(self._tables[src_slot, j])
            self._ref[pid] += 1
            self._refcount_max = max(self._refcount_max, int(self._ref[pid]))
            self._tables[dst_slot, j] = pid
        self._n_blocks[dst_slot] = n_full
        if self.check:
            self.validate()

    def fork(self, cache: Dict, src_slot: int, dst_slot: int,
             n_tokens: int) -> Dict:
        """Alias ``src_slot``'s first ``n_tokens`` into ``dst_slot``.

        Fully-covered pages are shared by reference (via
        :meth:`fork_aligned` — ref-count bump, no data movement); the
        trailing partial page — the only one a future append could write
        into — is deep-copied into a fresh page, so no copy-on-write
        machinery is needed on the decode path.  Returns the cache dict
        (with the partial-page copies applied).
        """
        n_full, partial = divmod(n_tokens, self.page_size)
        if n_full + (1 if partial else 0) > int(self._n_blocks[src_slot]):
            raise ValueError("fork extends past src slot's mapped pages")
        if partial and not self._free:
            raise PagesExhausted("no free page for the partial prefix page")
        self.fork_aligned(src_slot, dst_slot, n_full * self.page_size)
        if partial:
            src_pid = int(self._tables[src_slot, n_full])
            dst_pid = self._free.pop()
            self._ref[dst_pid] = 1
            self._tables[dst_slot, n_full] = dst_pid
            self._n_blocks[dst_slot] = n_full + 1
            cache = dict(cache)
            for key in list(cache):
                if key.startswith("pages_"):
                    pool = cache[key]
                    cache[key] = pool.at[dst_pid].set(pool[src_pid])
            if self.check:
                self.validate()
        return cache

    def truncate(self, cache: Dict, slot: int, new_len: int) -> Dict:
        """Shrink ``slot`` to ``new_len`` logical positions — the rollback
        primitive of speculative decoding (rejected draft tokens vanish as
        block-table metadata, the payoff of the paged design).

        Pages past ``blocks_for(new_len)`` are unmapped: ref-counts drop,
        pages return to the free list at zero, and a truncate that lands
        exactly on a page boundary releases the boundary page too.  The
        kept trailing page is *writable* again (future appends land in
        it), so when it is shared (ref > 1 — a forked/deduped page) it is
        **copied on shrink** into a fresh page first; appending can then
        never corrupt the sibling that still aliases the original.
        Returns the cache dict (with the page copy applied when one was
        needed — pure-metadata truncates return ``cache`` unchanged).
        """
        keep = self.blocks_for(new_len)
        n = int(self._n_blocks[slot])
        if keep > n:
            raise ValueError(
                f"truncate to {new_len} tokens needs {keep} pages but "
                f"slot {slot} maps only {n}")
        for j in range(keep, n):
            pid = int(self._tables[slot, j])
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                self._free.append(pid)
            self._tables[slot, j] = TRASH_PAGE
        self._n_blocks[slot] = keep
        if keep and new_len % self.page_size:
            pid = int(self._tables[slot, keep - 1])
            if self._ref[pid] > 1:
                if not self._free:
                    raise PagesExhausted(
                        "no free page for copy-on-shrink of a shared page")
                new_pid = self._free.pop()
                self._ref[pid] -= 1
                self._ref[new_pid] = 1
                self._tables[slot, keep - 1] = new_pid
                cache = dict(cache)
                for key in list(cache):
                    if key.startswith("pages_"):
                        pool = cache[key]
                        cache[key] = pool.at[new_pid].set(pool[pid])
        if self.check:
            self.validate()
        return cache

    def mapped_pages(self, slot: int) -> List[int]:
        return [int(p) for p in self._tables[slot, :self._n_blocks[slot]]]

    def refcount(self, page_id: int) -> int:
        return int(self._ref[page_id])

    # -- runtime self-check --------------------------------------------
    def validate(self) -> None:
        """Prove the allocator invariants; raise
        :class:`PagedCacheCorruption` naming the first violated one.

        Called after every mutating op when ``check=True`` (and directly
        by the batcher's per-step hook); safe to call at any time.
        """
        free = self._free
        if len(set(free)) != len(free):
            raise PagedCacheCorruption("free list holds duplicate page ids")
        for pid in free:
            if not (TRASH_PAGE < pid < self.n_pages):
                raise PagedCacheCorruption(
                    f"free list holds out-of-range page id {pid}")
            if self._ref[pid] != 0:
                raise PagedCacheCorruption(
                    f"free page {pid} has ref-count {int(self._ref[pid])}")
        if self._ref[TRASH_PAGE] != 0:
            raise PagedCacheCorruption("trash page has a non-zero ref-count")
        # count table occurrences of every real page
        occ = np.zeros((self.n_pages,), np.int64)
        for slot in range(self.max_slots):
            n = int(self._n_blocks[slot])
            row = self._tables[slot]
            for j in range(self.blocks_per_slot):
                pid = int(row[j])
                if not (0 <= pid < self.n_pages):
                    raise PagedCacheCorruption(
                        f"slot {slot} block {j} maps out-of-range page {pid}")
                if j >= n:
                    if pid != TRASH_PAGE:
                        raise PagedCacheCorruption(
                            f"slot {slot} block {j} beyond its {n} mapped "
                            f"pages points at page {pid}, not trash")
                elif pid == TRASH_PAGE:
                    raise PagedCacheCorruption(
                        f"slot {slot} block {j} inside its {n} mapped pages "
                        f"points at the trash page")
                else:
                    occ[pid] += 1
        for pid in range(TRASH_PAGE + 1, self.n_pages):
            if int(self._ref[pid]) != int(occ[pid]):
                raise PagedCacheCorruption(
                    f"page {pid}: ref-count {int(self._ref[pid])} != "
                    f"{int(occ[pid])} block-table occurrence(s)")
        referenced = int((self._ref > 0).sum())
        if len(free) + referenced != self.usable_pages:
            raise PagedCacheCorruption(
                f"page accounting drift: {len(free)} free + {referenced} "
                f"referenced != {self.usable_pages} usable")

    def stats(self) -> Dict:
        """Cheap allocator counters (O(n_pages), no device sync) — safe to
        poll every request even with ``check=False``.

        ``pages_leaked`` is the gap between pool capacity and what the
        free list plus live ref-counts account for: non-zero means pages
        were lost to ref-count drift.  ``refcount_max`` is the high-water
        sharing degree (>= 2 once any prefix was forked/deduped).
        """
        referenced = int((self._ref > 0).sum())
        return {
            "page_size": self.page_size,
            "n_pages": self.n_pages,
            "usable_pages": self.usable_pages,
            "free_pages": len(self._free),
            "mapped_pages": referenced,
            "pages_leaked": self.usable_pages - len(self._free) - referenced,
            "refcount_max": self._refcount_max,
        }

    def close(self) -> Dict:
        """End-of-life audit: returns :meth:`stats`; with ``check=True``
        raises :class:`PagedCacheCorruption` when pages leaked (pages
        still mapped by live slots are fine — the batcher may close
        mid-flight — only unaccounted-for pages count as leaks)."""
        st = self.stats()
        if self.check and st["pages_leaked"]:
            raise PagedCacheCorruption(
                f"{st['pages_leaked']} page(s) leaked at close "
                f"(free {st['free_pages']} + mapped {st['mapped_pages']} "
                f"< usable {st['usable_pages']})")
        return st


def slot_view(cache: Dict, slot: int, length: int = 0) -> Dict:
    """A batch-1 view of a paged cache for admission prefill: the pools
    are shared (writes scatter into the slot's mapped pages), only the
    block-table row and length are sliced — no buffer copies.
    ``length`` is the slot's already-materialized KV length (non-zero when
    continuing a chunked prefill mid-prompt)."""
    one = {k: v for k, v in cache.items()
           if k.startswith("pages_")}
    one["block_tables"] = cache["block_tables"][slot:slot + 1]
    one["len"] = jnp.asarray(length, jnp.int32).reshape(())
    return one
