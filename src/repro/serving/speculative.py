"""Heterogeneous speculative decoding: CPU drafts, the accelerator verifies.

HeteGen's thesis is that the host should do real work instead of serving
as a weight warehouse; Dovetail (PAPERS.md) carries that CPU/GPU split
into speculative decoding.  A cheap **drafter** proposes up to ``k``
tokens on the host, and the target model scores all ``batch x (k + 1)``
candidate positions in ONE prefill-shaped pass (``backend.verify`` — the
paged-prefill kernel's per-batch ``kv_offset`` makes it a multi-token
verify kernel for free).  In the offload serving path this turns ``k``
decode steps — ``k`` full streams of every offloaded weight over the
link — into one, precisely the high-intensity regime where
``build_policy`` already pushes alpha toward the accelerator.

Two drafters ship behind one protocol:

  * :class:`NgramDrafter` — prompt-lookup/self-ngram: match the newest
    n-gram of the request's own token history against earlier positions
    and propose the continuation.  Pure host-side list matching, zero
    extra weights — the degenerate-but-free drafter that wins big on
    repetitive text (code, JSON, retrieval-stuffed prompts).
  * :class:`ModelDrafter` — a small draft model run greedily through its
    own :class:`repro.serving.backends.ResidentBackend`: the draft model
    lives in cheap resident memory while the big offloaded model only
    verifies.  Keeps one batch-1 dense cache per request, reconciled
    against the request's token history by longest-common-prefix (a
    dense cache truncate is just a length reset).

Acceptance is standard speculative rejection sampling specialized to
**deterministic (point-mass) drafters**: draft ``d`` is accepted with
probability ``p(d)`` under the request's *filtered* sampling
distribution (the exact top-k/top-p/temperature filter
``sample_rows`` applies, mirrored on host by :func:`filtered_probs`);
on rejection the replacement is drawn from ``p`` with ``d`` removed and
renormalized — the marginal of the emitted token is exactly ``p``, so
output is distribution-identical to the baseline sampler.  Greedy
requests degenerate to ``accept iff d == argmax`` with the argmax
emitted on rejection — token-identical to the baseline, consuming zero
entropy.  Every draw uses the request-owned PRNG stream: position ``j``
of a spec step emits generated-token index ``n0 + j`` and folds its
accept/residual draws out of ``step_key(req_key, n0 + j)``, so
scheduling (batching, preemption, resume) can never renumber a stream;
the bonus position draws through :func:`sample_rows` itself with the
plain step key, which makes a draft-less row bitwise-identical to the
baseline decode draw.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampling import (SamplingParams, pack_sampling,
                                    sample_rows, step_key)


# ---------------------------------------------------------------------------
# Drafters
# ---------------------------------------------------------------------------

@runtime_checkable
class Drafter(Protocol):
    """The drafting seam: host-side token proposal.

    ``propose`` sees the request's full known token history (prompt plus
    every generated token, the pending input included) and returns up to
    ``k`` candidate continuations — fewer (or none) when it has no
    confident guess; an empty proposal simply falls back to a plain
    decode step for that request.  Drafters must be deterministic in
    their inputs: a preempted request re-proposes on resume, and
    determinism is what keeps mid-speculation preemption token-identical.
    """

    def propose(self, rid: int, tokens: Sequence[int],
                k: int) -> List[int]: ...

    def release(self, rid: int) -> None:
        """Drop any per-request state (the request finished)."""
        ...

    def close(self) -> None: ...


class NgramDrafter:
    """Prompt-lookup drafting over the request's own history.

    Finds the most recent earlier occurrence of the newest ``n``-gram
    (longest ``n`` first) and proposes the tokens that followed it.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if min_ngram < 1 or max_ngram < min_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, rid: int, tokens: Sequence[int], k: int) -> List[int]:
        toks = [int(t) for t in tokens]
        n_toks, k = len(toks), int(k)
        if k <= 0:
            return []
        for n in range(self.max_ngram, self.min_ngram - 1, -1):
            if n_toks <= n:
                continue
            pat = toks[-n:]
            # most recent earlier occurrence wins (local context beats
            # a stale match from the distant prompt)
            for i in range(n_toks - n - 1, -1, -1):
                if toks[i:i + n] == pat:
                    cont = toks[i + n:i + n + k]
                    if cont:
                        return cont
                    break              # suffix-at-end match: shorter n
        return []

    def release(self, rid: int) -> None:
        pass

    def close(self) -> None:
        pass


class ModelDrafter:
    """A small draft model decoded greedily on resident memory.

    One batch-1 dense cache per request; ``propose`` reconciles it with
    the request's current token history by longest common prefix —
    rejected speculation just resets the cache length (a dense truncate
    is metadata) and re-feeds the divergent tail.
    """

    def __init__(self, cfg, params=None, *, backend=None,
                 max_len: int = 512):
        if backend is None:
            from repro.serving.backends import ResidentBackend
            if params is None:
                raise ValueError("ModelDrafter needs params or a backend")
            backend = ResidentBackend(cfg, params)
            self._own_backend = True
        else:
            self._own_backend = False
        self.cfg = cfg
        self.backend = backend
        self.max_len = max_len
        self._fed: Dict[int, List[int]] = {}    # tokens whose KV is cached
        self._cache: Dict[int, Dict] = {}

    def propose(self, rid: int, tokens: Sequence[int], k: int) -> List[int]:
        toks = [int(t) for t in tokens]
        k = min(int(k), self.max_len - len(toks))
        if k <= 0 or not toks:
            return []
        fed = self._fed.get(rid, [])
        lcp = 0
        for a, b in zip(fed, toks):
            if a != b:
                break
            lcp += 1
        # always re-feed at least the newest token: its logits are the
        # first draft's distribution (the cache stores KV, not logits)
        start = min(lcp, len(toks) - 1)
        cache = self._cache.get(rid)
        if cache is None or start == 0:
            cache = self.backend.init_cache(1, self.max_len)
            start = 0
        else:
            cache = dict(cache)
        cache["len"] = jnp.full((1,), start, jnp.int32)
        chunk = jnp.asarray([toks[start:]], jnp.int32)
        cache, logits = self.backend.prefill({"tokens": chunk}, cache)
        drafts: List[int] = []
        for j in range(k):
            nxt = int(jnp.argmax(logits[0]))
            drafts.append(nxt)
            if j + 1 == k:
                break
            cache, logits = self.backend.decode(
                jnp.asarray([nxt], jnp.int32), cache)
        self._cache[rid] = cache
        # KV materialized: toks plus every draft except the last
        self._fed[rid] = toks + drafts[:-1]
        return drafts

    def release(self, rid: int) -> None:
        self._fed.pop(rid, None)
        self._cache.pop(rid, None)

    def close(self) -> None:
        self._fed.clear()
        self._cache.clear()
        if self._own_backend:
            self.backend.close()


# ---------------------------------------------------------------------------
# Config / stats / adaptive-k
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpecConfig:
    """Speculative-decoding knobs the serving front door exposes.

    ``k`` is the draft length (per step, before per-request budget and
    capacity caps); ``adaptive=True`` lets :class:`AdaptiveK` steer each
    request's draft length from its observed acceptance — grow on a
    fully-accepted run, shrink when less than half the run survives —
    bounded to ``[k_min, k_max]``.
    """

    drafter: Drafter
    k: int = 4
    adaptive: bool = False
    k_min: int = 1
    k_max: int = 8

    def __post_init__(self):
        if self.k < 1:
            raise ValueError("SpecConfig.k must be >= 1")
        if not (1 <= self.k_min <= self.k_max):
            raise ValueError("need 1 <= k_min <= k_max")


@dataclasses.dataclass
class SpecStats:
    """Counters of one request's (or the whole batcher's) speculation."""

    steps: int = 0          # verify steps that carried >= 1 draft token
    drafted: int = 0        # draft tokens scored
    accepted: int = 0       # draft tokens emitted
    rolled_back: int = 0    # draft tokens rejected (KV truncated away)

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.drafted if self.drafted else 0.0

    def record(self, drafted: int, accepted: int) -> None:
        if drafted <= 0:
            return
        self.steps += 1
        self.drafted += drafted
        self.accepted += accepted
        self.rolled_back += drafted - accepted

    def as_dict(self) -> Dict[str, float]:
        return {"steps": self.steps, "drafted": self.drafted,
                "accepted": self.accepted, "rolled_back": self.rolled_back,
                "acceptance_rate": self.acceptance_rate}


class AdaptiveK:
    """Per-request draft-length controller.

    Deterministic hill-climb on the per-step acceptance: a fully
    accepted run earns one more draft token next step, a run where less
    than ``shrink_below`` of the drafts survived loses one.  Bounded to
    ``[k_min, k_max]`` so a pathological request can neither stall
    speculation nor blow up the verify batch.
    """

    def __init__(self, k0: int, k_min: int = 1, k_max: int = 8,
                 shrink_below: float = 0.5):
        self.k0 = min(max(int(k0), k_min), k_max)
        self.k_min = k_min
        self.k_max = k_max
        self.shrink_below = shrink_below
        self._k: Dict[int, int] = {}

    def k_for(self, rid: int) -> int:
        return self._k.get(rid, self.k0)

    def update(self, rid: int, proposed: int, accepted: int) -> None:
        if proposed <= 0:
            return
        k = self._k.get(rid, self.k0)
        if accepted >= proposed:
            k = min(k + 1, self.k_max)
        elif accepted < proposed * self.shrink_below:
            k = max(k - 1, self.k_min)
        self._k[rid] = k

    def release(self, rid: int) -> None:
        self._k.pop(rid, None)


# ---------------------------------------------------------------------------
# Verification: host mirror of the row sampler + rejection sampling
# ---------------------------------------------------------------------------

def filtered_probs(logits: np.ndarray,
                   params: SamplingParams) -> np.ndarray:
    """Full-vocab probabilities after ``sample_rows``' per-row filter.

    The exact host mirror of the device sampler's masking: one stable
    descending sort (``jnp.argsort(x)[::-1]`` semantics — among ties the
    *higher* index sorts first, so the mirror reverses an ascending
    stable argsort rather than sorting ``-x``), temperature-scaled
    softmax over the sorted logits, top-k keeps the first ``k`` sorted
    positions, top-p keeps the smallest prefix reaching mass ``p``
    (crossing token included), position 0 always survives.  Returns the
    renormalized distribution in original vocab order — the ``p`` of
    speculative rejection sampling.
    """
    x = np.asarray(logits, np.float32)
    t = np.float32(max(params.temperature, 1e-4))
    order = np.argsort(x, kind="stable")[::-1]
    sorted_scaled = (x / t)[order]
    e = np.exp(sorted_scaled - sorted_scaled.max())
    probs = (e / e.sum()).astype(np.float32)
    keep = np.ones(x.shape[0], bool)
    if params.top_k > 0:
        keep[params.top_k:] = False
    csum = np.cumsum(probs, dtype=np.float32)
    keep &= (csum - probs) < np.float32(params.top_p)
    keep[0] = True
    kept = np.where(keep, probs, np.float32(0))
    out = np.zeros_like(kept)
    out[order] = kept / kept.sum()
    return out


def _uniform(key: jax.Array) -> float:
    return float(jax.random.uniform(key))


def _inverse_cdf(probs: np.ndarray, u: float) -> int:
    idx = int(np.searchsorted(np.cumsum(probs, dtype=np.float64), u,
                              side="right"))
    return min(idx, probs.shape[0] - 1)


def accept_row(rows: np.ndarray, drafts: Sequence[int],
               params: SamplingParams, req_key: jax.Array,
               n0: int) -> List[int]:
    """Run speculative rejection sampling for one request.

    ``rows`` is the request's slice of the verify logits — shape
    ``(len(drafts) + 1, V)`` where row ``j`` is the model's distribution
    for generated-token index ``n0 + j`` (row 0 conditions on the
    pending input, row ``j`` on drafts ``< j``).  Returns the emitted
    tokens: accepted drafts, then either the rejection replacement (run
    cut) or the bonus token (all drafts survived).  Greedy requests use
    the pure argmax chain (zero entropy, token-identical to baseline);
    stochastic requests accept draft ``d`` with probability ``p(d)``
    under :func:`filtered_probs` and resample from the ``d``-excluded
    renormalized residual on rejection — the emitted marginal is exactly
    ``p``.  The bonus/draft-less draw goes through ``sample_rows``
    itself so it is bitwise the baseline decode draw.
    """
    m = len(drafts)
    assert rows.shape[0] == m + 1
    out: List[int] = []
    if params.kind == "greedy":
        for j, d in enumerate(drafts):
            tgt = int(np.argmax(rows[j]))
            out.append(tgt)
            if int(d) != tgt:
                return out
        out.append(int(np.argmax(rows[m])))
        return out
    for j, d in enumerate(drafts):
        d = int(d)
        skey = step_key(req_key, n0 + j)
        p = filtered_probs(rows[j], params)
        if _uniform(jax.random.fold_in(skey, 1)) < p[d]:
            out.append(d)
            continue
        q = p.copy()
        q[d] = 0.0
        s = q.sum()
        if s <= 0.0:                     # p was a point mass at d
            out.append(d)
            return out
        out.append(_inverse_cdf(q / s,
                                _uniform(jax.random.fold_in(skey, 2))))
        return out
    # bonus position: the baseline draw for token n0 + m, bit-for-bit
    tok = sample_rows(jnp.asarray(rows[m][None]),
                      jnp.stack([step_key(req_key, n0 + m)]),
                      pack_sampling([params]))
    out.append(int(tok[0]))
    return out


def logprob_record(row: np.ndarray, token: int, top_k: int) -> Dict:
    """The serving API's per-token logprob payload, computed host-side
    for spec-emitted tokens (mirrors ``sample_rows``' info dict: raw
    model distribution, top-k by the same descending stable order)."""
    x = np.asarray(row, np.float64)
    log_z = float(np.log(np.exp(x - x.max()).sum()) + x.max())
    order = np.argsort(np.asarray(row, np.float32),
                       kind="stable")[::-1][:max(top_k, 0)]
    return {"token": int(token),
            "logprob": float(x[int(token)] - log_z),
            "top": {int(t): float(x[int(t)] - log_z) for t in order}}
