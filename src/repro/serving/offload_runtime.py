"""The paper's runtime: offloaded serving with hybrid heterogeneous
parallelism (HeteGen §4).

Weights live in host memory.  Each linear module executes under the
scheduler's placement plan (resident / hetegen-split / streamed) through
:class:`repro.core.engine.HeteGenEngine`; everything else (norms, rope,
attention core, softmax, sampling) runs on the device.  The forward is
eager per layer — exactly how offloading runtimes execute, since weights
arrive layer by layer — with the small device pieces jitted.

Supports the dense GQA decoder families (the paper's OPT models and
mistral-style configs).  Correctness: outputs match the fully-resident
jitted path to fp tolerance (tests/test_offload_runtime.py).
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import HeteGenEngine
from repro.core.hw import HardwareSpec, TPU_V5E
from repro.core.policy import LinearSpec, PolicyResult, build_policy
from repro.models import layers as L
from repro.models import model as M
from repro.models.config import ModelConfig


def enumerate_linears(cfg: ModelConfig) -> List[LinearSpec]:
    """The model's offloadable linears with size groups (paper §4.3)."""
    by = cfg.dtype_bytes()
    hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    d, f = cfg.d_model, cfg.d_ff
    out = []
    for l in range(cfg.n_layers):
        out += [
            LinearSpec(f"blk{l}.wq", d, hq * hd, "attn", by),
            LinearSpec(f"blk{l}.wk", d, hkv * hd, "attn_kv", by),
            LinearSpec(f"blk{l}.wv", d, hkv * hd, "attn_kv", by),
            LinearSpec(f"blk{l}.wo", hq * hd, d, "attn", by),
        ]
        if cfg.mlp_kind.startswith("gated"):
            out += [LinearSpec(f"blk{l}.w_gate", d, f, "mlp", by),
                    LinearSpec(f"blk{l}.w_up", d, f, "mlp", by),
                    LinearSpec(f"blk{l}.w_down", f, d, "mlp_down", by)]
        else:
            out += [LinearSpec(f"blk{l}.w_in", d, f, "mlp", by),
                    LinearSpec(f"blk{l}.w_down", f, d, "mlp_down", by)]
    return out


def _np(x) -> np.ndarray:
    return np.asarray(jax.device_get(x))


class OffloadGenerator:
    """HeteGen-scheduled offloaded generation for dense GQA decoders."""

    def __init__(self, cfg: ModelConfig, params: Dict, *,
                 hw: HardwareSpec = TPU_V5E,
                 budget_bytes: Optional[float] = None,
                 use_alpha_benchmark: bool = True,
                 use_module_scheduler: bool = True,
                 alpha_override: Optional[float] = None):
        if cfg.family not in ("dense", "vlm") or cfg.attn_kind != "gqa":
            raise NotImplementedError(
                "offload runtime supports dense GQA decoders "
                f"(got family={cfg.family}, attn={cfg.attn_kind})")
        self.cfg = cfg
        self.linears = enumerate_linears(cfg)
        self.policy: PolicyResult = build_policy(
            self.linears, hw, budget_bytes=budget_bytes, batch=1,
            use_alpha_benchmark=use_alpha_benchmark,
            use_module_scheduler=use_module_scheduler)
        if alpha_override is not None:
            from repro.core.engine import ModulePlan
            self.policy.plan = [
                ModulePlan(p.name, p.group, p.mode,
                           alpha_override if p.mode == "hetegen" else p.alpha)
                for p in self.policy.plan]

        # unstack per-layer host weights
        weights: Dict[str, np.ndarray] = {}
        biases: Dict[str, np.ndarray] = {}
        blocks = params["blocks"]
        for l in range(cfg.n_layers):
            blk = jax.tree.map(lambda x: x[l], blocks)["pos0"]
            a, m = blk["attn"], blk.get("mlp", {})
            for nm, w in (("wq", a["wq"]), ("wk", a["wk"]), ("wv", a["wv"]),
                          ("wo", a["wo"])):
                weights[f"blk{l}.{nm}"] = _np(w)
            if cfg.attn_bias:
                for nm, b in (("wq", a["bq"]), ("wk", a["bk"]),
                              ("wv", a["bv"]), ("wo", a["bo"])):
                    biases[f"blk{l}.{nm}"] = _np(b)
            for nm in ("w_gate", "w_up", "w_down", "w_in"):
                if nm in m:
                    weights[f"blk{l}.{nm}"] = _np(m[nm])
            if cfg.attn_bias and "b_in" in m:
                biases[f"blk{l}.w_in"] = _np(m["b_in"])
                biases[f"blk{l}.w_down"] = _np(m["b_down"])
            self._norms_cache = None
        self.engine = HeteGenEngine(weights, self.policy.plan, biases=biases)
        self.engine.warm_prefetch()

        # device-resident small params
        self.blocks = blocks
        self.params = params
        self._norm = jax.jit(partial(L.apply_norm, cfg))
        self._attend = jax.jit(partial(self._attend_impl))
        self._act = jax.jit(self._act_impl)
        self._logits = jax.jit(lambda p, x: M.lm_logits(cfg, p, x))

    # ------------------------------------------------------------------
    def _attend_impl(self, q, k_buf, v_buf, q_positions, kv_len):
        kvpos = jnp.arange(k_buf.shape[1])
        return L.attention(q, k_buf, v_buf, q_positions=q_positions,
                           kv_positions=kvpos[None], kv_len=kv_len,
                           causal=True, window=self.cfg.window,
                           attn_softcap=self.cfg.attn_softcap)

    def _act_impl(self, h):
        k = self.cfg.mlp_kind
        if k == "relu":
            return jax.nn.relu(h)
        if k == "relu2":
            return jnp.square(jax.nn.relu(h))
        if k == "gelu":
            return jax.nn.gelu(h)
        return h

    def _layer(self, l: int, x: jax.Array, positions, cache, cur_len):
        cfg = self.cfg
        b, s, d = x.shape
        blk = jax.tree.map(lambda a: a[l], self.blocks)["pos0"]
        eng = self.engine

        h = self._norm(blk["ln1"], x)
        h2 = h.reshape(b * s, d)
        q = eng.linear(h2, f"blk{l}.wq").reshape(b, s, cfg.n_heads, cfg.hd)
        k = eng.linear(h2, f"blk{l}.wk").reshape(b, s, cfg.n_kv_heads, cfg.hd)
        v = eng.linear(h2, f"blk{l}.wv").reshape(b, s, cfg.n_kv_heads, cfg.hd)
        if cfg.pos_emb == "rope":
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
        k_buf, v_buf = cache[l]
        k_buf = jax.lax.dynamic_update_slice_in_dim(
            k_buf, k.astype(k_buf.dtype), cur_len, axis=1)
        v_buf = jax.lax.dynamic_update_slice_in_dim(
            v_buf, v.astype(v_buf.dtype), cur_len, axis=1)
        cache[l] = (k_buf, v_buf)
        o = self._attend(q, k_buf, v_buf, positions, cur_len + s)
        o = eng.linear(o.reshape(b * s, -1), f"blk{l}.wo").reshape(b, s, d)
        x = x + o

        h = self._norm(blk["ln2"], x).reshape(b * s, d)
        if cfg.mlp_kind.startswith("gated"):
            act = jax.nn.silu if cfg.mlp_kind == "gated_silu" else jax.nn.gelu
            g = eng.linear(h, f"blk{l}.w_gate")
            u = eng.linear(h, f"blk{l}.w_up")
            y = eng.linear(act(g) * u, f"blk{l}.w_down")
        else:
            hmid = self._act(eng.linear(h, f"blk{l}.w_in"))
            y = eng.linear(hmid, f"blk{l}.w_down")
        return x + y.reshape(b, s, d), cache

    def _forward(self, batch_tokens, cache, cur_len):
        cfg = self.cfg
        b, s = batch_tokens.shape
        positions = cur_len + jnp.arange(s, dtype=jnp.int32)[None, :] \
            + jnp.zeros((b, 1), jnp.int32)
        x = M.embed_tokens(cfg, self.params, batch_tokens)
        x = M._add_learned_pos(cfg, self.params, x, positions)
        for l in range(cfg.n_layers):
            x, cache = self._layer(l, x, positions, cache, cur_len)
        x = self._norm(self.params["final_norm"], x[:, -1:])
        logits = self._logits(self.params, x)
        return logits[:, 0], cache

    # ------------------------------------------------------------------
    def generate(self, tokens: np.ndarray, max_new_tokens: int,
                 *, max_len: Optional[int] = None) -> Dict:
        cfg = self.cfg
        b, s = tokens.shape
        total = max_len or (s + max_new_tokens)
        cache = [
            (jnp.zeros((b, total, cfg.n_kv_heads, cfg.hd), jnp.dtype(cfg.dtype)),
             jnp.zeros((b, total, cfg.n_kv_heads, cfg.hd), jnp.dtype(cfg.dtype)))
            for _ in range(cfg.n_layers)]
        self.engine.reset_stats()
        t0 = time.perf_counter()
        logits, cache = self._forward(jnp.asarray(tokens), cache, 0)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t1 = time.perf_counter()
        out = [tok]
        cur = s
        for _ in range(max_new_tokens - 1):
            logits, cache = self._forward(out[-1][:, None], cache, cur)
            out.append(jnp.argmax(logits, axis=-1).astype(jnp.int32))
            cur += 1
        jax.block_until_ready(out[-1])
        t2 = time.perf_counter()
        stats = self.engine.finish_stats()
        return {
            "tokens": np.stack([np.asarray(t) for t in out], axis=1),
            "prefill_s": t1 - t0,
            "decode_s": t2 - t1,
            "tokens_per_s": b * max(max_new_tokens - 1, 1) / max(t2 - t1, 1e-9),
            "stream_stats": stats,
            "alpha": self.policy.alpha,
            "resident_bytes": self.engine.device_resident_bytes(),
            "pinned_overhead_bytes": self.engine.pinned_overhead_bytes(),
        }

    def close(self):
        self.engine.close()
