"""The paper's runtime: offloaded serving with hybrid heterogeneous
parallelism (HeteGen §4).

Weights live in host memory.  Each linear module executes under the
scheduler's placement plan (resident / hetegen-split / streamed) through
:class:`repro.core.engine.HeteGenEngine`; everything else (norms, rope,
attention core, softmax, sampling) runs on the device.  The forward is
eager per layer — exactly how offloading runtimes execute, since weights
arrive layer by layer.

The decoder math itself is NOT defined here: the offload path executes the
same shared layer functions as the resident path
(:func:`repro.models.model.decoder_layer` via
:class:`repro.serving.backends.HeteGenBackend`), differing only in the
injected linear backend.  The placement plan is tuned for the *real*
decode batch size — §4.1's cost model shifts the optimal alpha with
compute intensity — and sampling is pluggable via
:class:`repro.serving.sampling.SamplerConfig`.

Supports the dense GQA decoder families (the paper's OPT models and
mistral-style configs).  Correctness: outputs match the fully-resident
jitted path to fp tolerance (tests/test_offload_runtime.py).

For request-level serving (per-request sampling, streaming, continuous
batching) drive the backend through :class:`repro.serving.api.LLM`
instead — this generator is the phase-aware one-shot executor kept for
stats-rich offload benchmarking (docs/SERVING.md).
"""

from __future__ import annotations

import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hw import HardwareSpec, TPU_V5E
from repro.serving.backends import HeteGenBackend, enumerate_linears  # noqa: F401  (re-export)
from repro.models.config import ModelConfig
from repro.serving.sampling import SamplerConfig, make_sampler


class OffloadGenerator:
    """HeteGen-scheduled offloaded generation for dense GQA decoders.

    ``batch`` sizes the initial placement plan; by default the plan is
    re-tuned automatically when :meth:`generate` is called with a different
    batch size (``auto_retune=False`` pins the constructed plan).
    """

    def __init__(self, cfg: ModelConfig, params: Dict, *,
                 hw: HardwareSpec = TPU_V5E,
                 budget_bytes: Optional[float] = None,
                 use_alpha_benchmark: bool = True,
                 use_module_scheduler: bool = True,
                 alpha_override: Optional[float] = None,
                 batch: int = 1,
                 sampler: SamplerConfig = SamplerConfig(),
                 auto_retune: bool = True):
        self.cfg = cfg
        self.backend = HeteGenBackend(
            cfg, params, hw=hw, budget_bytes=budget_bytes, batch=batch,
            use_alpha_benchmark=use_alpha_benchmark,
            use_module_scheduler=use_module_scheduler,
            alpha_override=alpha_override)
        self.sample = make_sampler(sampler)
        self.auto_retune = auto_retune

    @property
    def policy(self):
        return self.backend.policy

    @property
    def engine(self):
        return self.backend.engine

    # ------------------------------------------------------------------
    def generate(self, tokens: np.ndarray, max_new_tokens: int,
                 *, max_len: Optional[int] = None, seed: int = 0) -> Dict:
        b, s = tokens.shape
        if self.auto_retune:
            self.backend.retune(b)
        total = max_len or (s + max_new_tokens)
        cache = self.backend.init_cache(b, total)
        self.backend.reset_stats()
        t0 = time.perf_counter()
        cache, logits = self.backend.prefill(
            {"tokens": jnp.asarray(tokens)}, cache)
        # lint: allow[prng-discipline] the benchmark runtime's seed key;
        # serving paths derive request-owned keys via sampling.request_key
        key = jax.random.PRNGKey(seed)
        tok = self.sample(logits, key)
        jax.block_until_ready(tok)
        t1 = time.perf_counter()
        out = [tok]
        for i in range(max_new_tokens - 1):
            key = jax.random.fold_in(key, i)
            cache, logits = self.backend.decode(out[-1], cache)
            out.append(self.sample(logits, key))
        jax.block_until_ready(out[-1])
        t2 = time.perf_counter()
        # stream stats aggregate over the backend's phase engines (the
        # prefill partition ran the prompt, the decode partition the loop)
        stats = self.backend.finish_stats()
        prefill_policy = self.backend.policies.get("prefill")
        return {
            "tokens": np.stack([np.asarray(t) for t in out], axis=1),
            "prefill_s": t1 - t0,
            "decode_s": t2 - t1,
            "tokens_per_s": b * max(max_new_tokens - 1, 1) / max(t2 - t1, 1e-9),
            "stream_stats": stats,
            "alpha": self.policy.alpha,
            "prefill_alpha": (None if prefill_policy is None
                              else prefill_policy.alpha),
            "batch": self.backend.batch,
            "resident_bytes": self.backend.device_resident_bytes(),
            "pinned_overhead_bytes": self.backend.pinned_overhead_bytes(),
        }

    def close(self):
        self.backend.close()
