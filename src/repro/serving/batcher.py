"""Continuous batching: a pure executor under a pluggable scheduler.

Requests join/leave a fixed pool of ``max_slots`` decode slots without
stopping the batch.  *Who* occupies those slots is no longer this
module's business: every admit/preempt/resume decision lives in
:class:`repro.serving.scheduler.Scheduler` behind the
:class:`repro.serving.scheduler.SchedulerPolicy` seam (``fcfs`` /
``priority`` / ``fair_share``), and the batcher merely applies the
scheduler's per-step :class:`repro.serving.scheduler.StepPlan`:

  * **preempt** — save the victim's KV pages to host memory (swap mode)
    and clear its slot;
  * **start** — restore saved pages (swap resume) or prefill
    ``prompt + generated`` through a batch-1 view (fresh admissions and
    recompute resumes are literally the same code path — a fresh request
    just has no ``generated`` yet);
  * **decode** — advance every active slot one token (inactive slots in
    dense mode decode garbage that is masked out — the standard
    static-shape TPU pattern; paged mode *compacts* to the active
    block-table rows instead).

Per-slot sequence lengths are first-class: the model's decode path accepts
a vector ``len`` and scatters each slot's new K/V at its own position.

The batcher schedules over any :mod:`repro.serving.backends` driver: the
default is the jitted scan-stacked resident path, but
``backend=HeteGenBackend(...)`` runs the SAME executor over
HeteGen-offloaded weights.  Between a decode step's math and its host-side
sampling/bookkeeping the executor nudges the offload engine's pinned ring
(``backend.prefetch_next_step()``): the ring's wrap-around prefetch order
already points the last module of step N at the first module of step N+1,
so the nudge retries any wrap prefetch that found the ring full — step
N+1's pins run while step N's host work drains (ROADMAP perf item).

Sampling is **per request** (docs/SERVING.md): each submit may carry its
own :class:`repro.serving.sampling.SamplingParams`, rows of one decode
batch are sampled under their own parameters (row-vectorized sampler),
and every request owns a PRNG stream keyed by its id and generated-token
count — never by batch-row number.  Scheduling (compaction, preemption,
resume) therefore cannot perturb tokens: paged and dense, pressured and
unpressured runs are token-identical.  ``SamplingParams.logprobs``
additionally records each sampled token's log-probability (and top-k
alternatives) straight out of the sampler's existing sort.

``paged=True`` swaps the dense per-layer cache for the
:class:`repro.serving.kv_cache.PagedKVCache` subsystem; with
``optimistic=True`` (the default) admission maps only the prompt's pages
and the scheduler grows each running slot one decode position per step,
so page pressure triggers policy-driven preemption instead of
head-of-queue blocking (``optimistic=False`` restores the classic
``prompt + max_new`` reservation).  ``kv_dtype="int8"`` stores q8 pages.

``retune_hysteresis`` (with a retune-capable backend, i.e. HeteGen)
re-tunes the decode placement plan when the *executed* decode batch
drifts from the planned batch by more than the hysteresis margin —
§4.1's cost model shifts alpha with compute intensity, but rebuilding
the engine every time one request finishes would thrash; the margin
makes retunes sticky.  Only paged mode executes occupancy-sized batches
(compaction), so only paged mode ever re-tunes.

The batcher owns backend lifetime when it constructed the backend (or
when handed one with ``own_backend=True``): ``close()`` — or leaving the
``with`` block — shuts down the owned backend's engine threads.
"""

from __future__ import annotations

import contextlib
import itertools
import time
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.serving.backends import ScanResidentBackend
from repro.serving.kv_cache import slot_view
from repro.serving.sampling import (SamplerConfig, SamplingParams, greedy,
                                    pack_sampling, request_key, sample_rows,
                                    step_key)
from repro.serving.scheduler import (PREFILLING, RequestState, RUNNING,
                                     Scheduler, SchedulerPolicy)
from repro.serving.speculative import (AdaptiveK, SpecConfig, SpecStats,
                                       accept_row, logprob_record)
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.tracer import NULL_TRACER, Tracer

# back-compat: PR 3 exposed the queue entry as batcher.Request
Request = RequestState


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params: Optional[Dict] = None, *,
                 max_slots: int = 4, max_len: int = 512,
                 backend=None, sampler: SamplerConfig = SamplerConfig(),
                 seed: int = 0, paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None,
                 retune_hysteresis: Optional[int] = None,
                 own_backend: Optional[bool] = None,
                 policy: Union[str, SchedulerPolicy, None] = "fcfs",
                 optimistic: bool = True,
                 preempt_mode: Optional[str] = None,
                 chunk_tokens: Optional[int] = None,
                 prefix_dedupe: Optional[bool] = None,
                 spec: Optional[SpecConfig] = None,
                 selfcheck: bool = False,
                 tracer: Tracer = NULL_TRACER,
                 metrics: Optional[MetricsRegistry] = None):
        if cfg.family in ("ssm", "hybrid", "encdec"):
            raise NotImplementedError(
                "continuous batching supports transformer KV caches")
        if backend is None and params is None:
            raise ValueError("ContinuousBatcher needs params or a backend")
        self.cfg = cfg
        # own the backend when we constructed it; callers handing one over
        # transfer ownership with own_backend=True
        self._own_backend = backend is None if own_backend is None \
            else bool(own_backend)
        # observability (docs/OBSERVABILITY.md): spans land on the "step"
        # and "phase" tracks here, the backend's engines add the stream
        # tracks; the registry holds live serving counters and absorbs
        # the legacy stats() dicts on snapshot
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._step_no = 0
        self.backend = backend or ScanResidentBackend(cfg, params)
        if tracer and hasattr(self.backend, "set_tracer"):
            self.backend.set_tracer(tracer)
        if hasattr(self.backend, "retune"):
            # the decode batch is the slot count — enforce the documented
            # contract instead of trusting the caller's constructed plan
            self.backend.retune(max_slots)
        self.max_slots = max_slots
        self.max_len = max_len
        self.default_sampling = SamplingParams.from_config(sampler)
        # lint: allow[prng-discipline] the one base key request_key folds
        # request ids into; every sampling draw derives from it per request
        self._base_key = jax.random.PRNGKey(seed)
        self.paged = paged
        self.kv = None
        if paged:
            self.kv = self.backend.init_paged_cache(
                max_slots, max_len, page_size=page_size, n_pages=n_pages,
                kv_dtype=kv_dtype, check=selfcheck)
            self.cache = self.kv.init_cache()
        else:
            self.cache = self.backend.init_cache(max_slots, max_len)
        # the decision seam: admission order, preemption victims, page
        # growth — everything except device work (docs/SERVING.md)
        self.scheduler = Scheduler(policy, max_slots, max_len, kv=self.kv,
                                   optimistic=optimistic,
                                   preempt_mode=preempt_mode,
                                   chunk_tokens=chunk_tokens,
                                   prefix_dedupe=prefix_dedupe,
                                   tracer=tracer)
        # per-slot lengths (vector 'len' drives per-slot scatter updates)
        self.cache["len"] = jnp.zeros((max_slots,), jnp.int32)
        # dense chunked prefill accumulates each slot's KV in a private
        # batch-1 cache (merged into the global cache only on the final
        # chunk, so full-width decode's masked garbage writes can never
        # land inside a half-prefilled slot row)
        self._pending_dense: Dict[int, Dict] = {}
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        self._ids = itertools.count()
        self.retune_hysteresis = retune_hysteresis
        self._plan_batch = max_slots
        self.retunes = 0
        # speculative decoding: CPU-side drafting + batched verification
        # (docs/SERVING.md).  The batcher owns the drafter's lifetime.
        self.spec = spec
        self.spec_stats = SpecStats()
        self.spec_by_req: Dict[int, SpecStats] = {}
        self._adaptive: Optional[AdaptiveK] = None
        if spec is not None:
            if not hasattr(self.backend, "verify"):
                raise ValueError(
                    "speculative decoding needs a backend exposing "
                    "verify(batch, cache); "
                    f"{type(self.backend).__name__} does not")
            if spec.adaptive:
                self._adaptive = AdaptiveK(spec.k, spec.k_min, spec.k_max)
        self._closed = False
        # packed sampling params change only when slot->request assignment
        # does (admit/release), not every step — cache the device arrays
        self._pack_sig: Optional[tuple] = None
        self._packed = None
        self._packed_lp: Optional[int] = None

    # -- scheduler views the facade and tests read ----------------------
    @property
    def requests(self) -> Dict[int, RequestState]:
        return self.scheduler.requests

    @property
    def queue(self) -> List[RequestState]:
        """Everything still wanting a slot (waiting + preempted)."""
        return self.scheduler.pending

    @property
    def active(self) -> np.ndarray:
        return self.scheduler.active_mask()

    @property
    def policy(self) -> SchedulerPolicy:
        return self.scheduler.policy

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int,
               eos: Optional[int] = None, *,
               sampling: Optional[SamplingParams] = None,
               rid: Optional[int] = None,
               priority: int = 0) -> int:
        """Queue a request.  ``sampling`` defaults to the batcher-wide
        config; ``rid`` lets an owning facade keep one id space;
        ``priority`` matters to priority-aware scheduler policies."""
        rid = next(self._ids) if rid is None else rid
        sp = self.default_sampling if sampling is None else sampling
        st = RequestState(rid, list(prompt), max_new, eos, sampling=sp,
                          key=request_key(self._base_key, rid, sp),
                          priority=priority)
        self.scheduler.submit(st)
        return rid

    def _sample_slot_rows(self, logits: jax.Array,
                          slots: List[int]) -> jax.Array:
        """Sample one token per logits row, row i belonging to slot
        ``slots[i]``.  Each occupied slot draws under its request's own
        params with the key for its next token; vacant rows (the dense
        path's masked garbage) sample greedily with a dead key, so they
        consume no entropy and cannot perturb real requests.  Rows whose
        request asked for logprobs get their per-token record appended
        here, straight out of the sampler's existing sort."""
        with self.tracer.span("sample", track="sample", rows=len(slots)):
            return self._sample_slot_rows_traced(logits, slots)

    def _sample_slot_rows_traced(self, logits: jax.Array,
                                 slots: List[int]) -> jax.Array:
        slot_req = self.scheduler.slot_req
        params, keys = [], []
        for s in slots:
            req = slot_req[s]
            # a mid-prefill slot's decode row is masked garbage exactly
            # like a vacant one — its real first token is sampled by the
            # final chunk, after the status flips to running
            if req is None or req.status == PREFILLING:
                params.append(SamplingParams())
                keys.append(jnp.zeros((2,), jnp.uint32))
            else:
                params.append(req.sampling)
                keys.append(step_key(req.key, len(req.generated)))
        lp_k = [p.logprobs for p in params if p.logprobs is not None]
        if not lp_k and all(p.kind == "greedy" for p in params):
            # the default serving config: skip the full-vocab sort the
            # mixed-kind sampler needs (greedy rows never draw entropy,
            # so this is exactly equivalent)
            return greedy(logits)
        sig = tuple((s, -1 if slot_req[s] is None
                     or slot_req[s].status == PREFILLING
                     else slot_req[s].rid)
                    for s in slots)
        if sig != self._pack_sig:
            self._pack_sig = sig
            self._packed = pack_sampling(params)
            self._packed_lp = max(lp_k) if lp_k else None
        if self._packed_lp is None:
            return sample_rows(logits, jnp.stack(keys), self._packed)
        toks, lp = sample_rows(logits, jnp.stack(keys), self._packed,
                               top_logprobs=self._packed_lp)
        chosen = np.asarray(lp["logprob"])
        top_ids = np.asarray(lp["top_tokens"])
        top_lp = np.asarray(lp["top_logprobs"])
        for i, s in enumerate(slots):
            req = slot_req[s]
            if req is None or req.sampling.logprobs is None:
                continue
            k = req.sampling.logprobs
            req.logprobs.append({
                "token": int(toks[i]),
                "logprob": float(chosen[i]),
                "top": {int(t): float(l)
                        for t, l in zip(top_ids[i, :k], top_lp[i, :k])},
            })
        return toks

    # -- plan application ----------------------------------------------
    def _apply_preempt(self, st: RequestState) -> None:
        """Device side of an eviction: gather the victim's KV pages to
        host (swap mode — before anything can rewrite them) and clear its
        slot length.  Recompute mode keeps only the token ids."""
        if st.swap_block_ids is not None:
            ids = jnp.asarray(st.swap_block_ids, jnp.int32)
            # lint: allow[hot-path-sync] swap-mode preemption host-saves
            # the victim's KV pages by design; it runs on the rare
            # PagesExhausted path, not on a normal decode step
            st.saved_kv = {k: np.asarray(v[ids])
                           for k, v in self.cache.items()
                           if k.startswith("pages_")}
        self._pending_dense.pop(st.slot, None)
        self.cache["len"] = self.cache["len"].at[st.slot].set(0)
        st.slot = None

    def _start(self, st: RequestState) -> None:
        """Device side of an admission: swap-restore saved pages, or
        prefill ``prompt + generated`` (fresh and recompute resumes)."""
        slot = st.slot
        if st.saved_kv is not None:
            # token-exact resume: scatter the saved KV bits into the
            # freshly mapped pages; the pending input token is the last
            # one generated before eviction
            ids = jnp.asarray(
                self.kv.mapped_pages(slot)[:len(st.swap_block_ids)],
                jnp.int32)
            for key, saved in st.saved_kv.items():
                self.cache[key] = self.cache[key].at[ids].set(
                    jnp.asarray(saved))
            self.cache["len"] = self.cache["len"].at[slot].set(st.saved_len)
            self.tokens = self.tokens.at[slot].set(st.generated[-1])
            st.saved_kv = None
            st.swap_block_ids = None
            return
        toks = jnp.asarray([st.prompt + st.generated], jnp.int32)
        if self.paged:
            logits = self._prefill_paged_slot(slot, toks)
        else:
            logits = self._prefill_dense_slot(slot, toks)
        first = self._sample_slot_rows(logits, [slot])
        self.cache["len"] = self.cache["len"].at[slot].set(
            toks.shape[1])
        self.tokens = self.tokens.at[slot].set(first[0])
        st.generated.append(int(first[0]))
        self._maybe_finish(st)

    def _prefill_dense_slot(self, slot: int, toks: jax.Array) -> jax.Array:
        """Batch-1 prefill into a fresh dense cache, then whole-slice
        merge of every leaf into the global cache (the copy the paged
        path exists to avoid)."""
        axis = self.backend.cache_batch_axis
        one_cache = self.backend.init_cache(1, self.max_len)
        one_cache, logits = self.backend.prefill({"tokens": toks},
                                                 one_cache)

        # merge slot: every cache leaf carries batch at `axis`
        def merge(glob, one):
            if glob.ndim == 0 or glob.shape == ():
                return glob
            return jax.lax.dynamic_update_slice_in_dim(
                glob, one.astype(glob.dtype), slot, axis=axis)
        for key in self.cache:
            if key == "len":
                continue
            self.cache[key] = merge(self.cache[key], one_cache[key])
        return logits

    def _prefill_paged_slot(self, slot: int, toks: jax.Array) -> jax.Array:
        """Prefill through a batch-1 block-table view: the page pools are
        shared, so the prompt's KV scatters straight into the pages just
        mapped for this slot — admission moves exactly the new tokens,
        never a (1, max_len) cache slice."""
        self.cache["block_tables"] = self.kv.device_block_tables()
        self.scheduler.tables_dirty = False
        one = slot_view(self.cache, slot)
        one, logits = self.backend.prefill({"tokens": toks}, one)
        for key in one:
            if key.startswith("pages_"):
                self.cache[key] = one[key]
        return logits

    def _start_batch(self, sts: List[RequestState]) -> None:
        """Admit several same-length fresh requests in ONE prefill call
        instead of a batch-1 Python loop.  Attention rows are independent,
        so the batched call is token-identical to per-slot admission —
        it just amortizes the weight streaming (the whole point on an
        offload backend, where prefill cost is dominated by moving
        weights over the PCIe link once per call)."""
        slots = [st.slot for st in sts]
        toks = jnp.asarray([st.prompt + st.generated for st in sts],
                           jnp.int32)
        n = toks.shape[1]
        if self.paged:
            self.cache["block_tables"] = self.kv.device_block_tables()
            self.scheduler.tables_dirty = False
            view = {k: v for k, v in self.cache.items()
                    if k.startswith("pages_")}
            view["block_tables"] = self.cache["block_tables"][
                jnp.asarray(slots)]
            view["len"] = jnp.zeros((), jnp.int32)
            view, logits = self.backend.prefill({"tokens": toks}, view)
            for key in view:
                if key.startswith("pages_"):
                    self.cache[key] = view[key]
        else:
            axis = self.backend.cache_batch_axis
            grp = self.backend.init_cache(len(sts), self.max_len)
            grp, logits = self.backend.prefill({"tokens": toks}, grp)
            for key in self.cache:
                if key == "len":
                    continue
                glob = self.cache[key]
                if glob.ndim == 0 or glob.shape == ():
                    continue
                for i, slot in enumerate(slots):
                    row = jax.lax.dynamic_slice_in_dim(grp[key], i, 1,
                                                       axis=axis)
                    glob = jax.lax.dynamic_update_slice_in_dim(
                        glob, row.astype(glob.dtype), slot, axis=axis)
                self.cache[key] = glob
        firsts = self._sample_slot_rows(logits, slots)
        for i, st in enumerate(sts):
            self.cache["len"] = self.cache["len"].at[st.slot].set(n)
            self.tokens = self.tokens.at[st.slot].set(firsts[i])
            st.generated.append(int(firsts[i]))
            self._maybe_finish(st)

    def _prefill_chunk(self, st: RequestState) -> None:
        """Advance one chunk of a chunked prefill: run tokens
        ``[prefill_cursor, prefill_target)`` through ``backend.prefill``
        at the right KV offset.  Intermediate chunks only write KV; the
        final chunk samples the request's first token and flips it to
        running, so the slot joins this same step's decode — exactly
        :meth:`_start`'s semantics, just spread over several steps."""
        slot = st.slot
        start, end = st.prefill_cursor, st.prefill_target
        seq = st.prompt + st.generated
        n = len(seq)
        toks = jnp.asarray([seq[start:end]], jnp.int32)
        if self.paged:
            self.cache["block_tables"] = self.kv.device_block_tables()
            self.scheduler.tables_dirty = False
            one = slot_view(self.cache, slot, length=start)
            one, logits = self.backend.prefill({"tokens": toks}, one)
            for key in one:
                if key.startswith("pages_"):
                    self.cache[key] = one[key]
        else:
            one_cache = self._pending_dense.get(slot)
            if one_cache is None:
                one_cache = self.backend.init_cache(1, self.max_len)
            one_cache, logits = self.backend.prefill({"tokens": toks},
                                                     one_cache)
            self._pending_dense[slot] = one_cache
        st.prefill_cursor = end
        if end < n:
            return
        # final chunk — merge the private dense cache into the slot row
        # (paged chunks scattered straight into the slot's pages)
        if not self.paged:
            one_cache = self._pending_dense.pop(slot)
            axis = self.backend.cache_batch_axis
            for key in self.cache:
                if key == "len":
                    continue
                glob = self.cache[key]
                if glob.ndim == 0 or glob.shape == ():
                    continue
                self.cache[key] = jax.lax.dynamic_update_slice_in_dim(
                    glob, one_cache[key].astype(glob.dtype), slot,
                    axis=axis)
        st.status = RUNNING            # before sampling: the row is real
        first = self._sample_slot_rows(logits, [slot])
        self.cache["len"] = self.cache["len"].at[slot].set(n)
        self.tokens = self.tokens.at[slot].set(first[0])
        st.generated.append(int(first[0]))
        self._maybe_finish(st)

    def _maybe_finish(self, st: RequestState) -> None:
        hit_eos = (st.eos is not None and st.generated
                   and st.generated[-1] == st.eos)
        if hit_eos or len(st.generated) >= st.max_new:
            st.finish_reason = "eos" if hit_eos else "length"
            slot = st.slot
            self.scheduler.finish(st)
            if slot is not None:
                self.cache["len"] = self.cache["len"].at[slot].set(0)
                st.slot = None
            if self.spec is not None:
                self.spec.drafter.release(st.rid)
                if self._adaptive is not None:
                    self._adaptive.release(st.rid)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Run one scheduler step: apply the policy's plan (preempt /
        admit / resume / grow pages), then advance all active slots one
        token.  Returns the number of active slots after the step.

        With speculative decoding configured, drafting happens host-side
        BEFORE the plan (the scheduler needs each request's ``k_eff + 1``
        advance to reserve the whole draft run's pages up front), and the
        decode step becomes a verify step that can advance a slot several
        tokens; proposals for slots the plan preempts are simply dropped
        (no entropy was consumed, and deterministic drafters re-propose
        identically on resume — mid-speculation preemption stays
        token-identical).

        Each step records one ``step`` span (its ``phase`` attr names
        the dominant work) plus per-phase spans on the ``phase`` track,
        and feeds the live serving metrics — all no-ops with the null
        tracer/default registry idle.
        """
        self._step_no += 1
        t0 = time.perf_counter()
        toks_before = sum(len(r.generated) for r in self.requests.values())
        sp = self.tracer.span(f"step{self._step_no}", track="step")
        with sp:
            n = self._step_inner(sp)
        m = self.metrics
        m.counter("serve.steps").inc()
        m.counter("serve.tokens").inc(
            sum(len(r.generated) for r in self.requests.values())
            - toks_before)
        m.histogram("serve.step_s").observe(time.perf_counter() - t0)
        m.gauge("serve.active_slots").set(n)
        return n

    def _step_inner(self, sp) -> int:
        if self.kv is not None and self.kv.check:
            # selfcheck mode: prove the allocator invariants at the step
            # boundary too, so drift introduced between the per-op hooks
            # (e.g. direct metadata edits) surfaces before the next plan
            self.kv.validate()
        with self.tracer.span("plan", track="phase"):
            proposals = self._draft_proposals() if self.spec is not None \
                else None
            advances = None
            if proposals:
                advances = {rid: len(d) + 1 for rid, d in proposals.items()}
            plan = self.scheduler.plan(advances)
        admit_cm = self.tracer.span("prefill", track="phase") \
            if (plan.preempt or plan.start or plan.prefill) \
            else contextlib.nullcontext()
        with admit_cm:
            for st in plan.preempt:
                self._apply_preempt(st)
            # group same-length fresh admissions into one prefill call;
            # swap restores and odd lengths keep the batch-1 path
            fresh: Dict[int, List[RequestState]] = {}
            for st in plan.start:
                if st.saved_kv is not None:
                    self._start(st)
                else:
                    fresh.setdefault(
                        len(st.prompt) + len(st.generated), []).append(st)
            for sts in fresh.values():
                if len(sts) == 1:
                    self._start(sts[0])
                else:
                    self._start_batch(sts)
            for st in plan.prefill:
                self._prefill_chunk(st)
        if self.paged and self.scheduler.tables_dirty:
            # page growth / release since the last export (admission
            # prefills re-export on their own)
            self.cache["block_tables"] = self.kv.device_block_tables()
            self.scheduler.tables_dirty = False
        active = self.scheduler.active_mask()
        if not active.any():
            sp.set(phase="prefill" if (plan.start or plan.prefill)
                   else "idle")
            return 0
        occ = int(active.sum())
        # the batch a decode step actually executes: paged decode compacts
        # to the active slots (cheap — a block-table row gather), dense
        # decode always runs the full slot width (inactive slots compute
        # masked garbage, the static-shape pattern)
        executed = occ if self.paged else self.max_slots
        if (self.retune_hysteresis is not None
                and hasattr(self.backend, "retune")
                and abs(executed - self._plan_batch)
                > self.retune_hysteresis):
            # executed batch drifted past the hysteresis margin: rebuild
            # the decode placement plan for it (ROADMAP item); small
            # oscillations stay on the current plan.  §4.1's cost model
            # only sees the executed width, so dense mode never re-tunes
            # on occupancy.  The prefill plan is the backend's own
            # business (phase-tuned on observed prompt shapes).
            self.backend.retune(executed, phase="decode")
            self._plan_batch = executed
            self.retunes += 1
        if proposals:
            # drop proposals whose request the plan preempted or that
            # lost their slot — then run draft + undrafted rows through
            # one verify step (an undrafted row's bonus draw IS the
            # baseline decode draw, so mixing costs nothing)
            proposals = {rid: d for rid, d in proposals.items()
                         if d and rid in self.requests
                         and self.requests[rid].status == RUNNING}
        if proposals:
            sp.set(phase="verify")
            with self.tracer.span("verify", track="phase"):
                self._spec_step(proposals, active)
            return int(self.scheduler.active_mask().sum())
        sp.set(phase="decode")
        with self.tracer.span("decode", track="phase"):
            if self.paged and occ < self.max_slots:
                self._decode_active_slots(active)
            else:
                self.cache, logits = self.backend.decode(self.tokens,
                                                         self.cache)
                self._prefetch_next_step()
                self.tokens = self._sample_slot_rows(
                    logits, list(range(self.max_slots)))
        nxt = self.tokens
        for st in self.scheduler.running():
            st.generated.append(int(nxt[st.slot]))
            self._maybe_finish(st)
        return int(self.scheduler.active_mask().sum())

    def _draft_proposals(self) -> Dict[int, List[int]]:
        """Host-side drafting over the running slots, capped per request
        so a fully-accepted run can never overshoot ``max_new`` (the
        bonus token needs headroom of 1) or ``max_len`` (the run's KV
        must fit: ``kv_len + k + 1 <= max_len``)."""
        out: Dict[int, List[int]] = {}
        for st in self.scheduler.running():
            k = self._adaptive.k_for(st.rid) if self._adaptive is not None \
                else self.spec.k
            k = min(k, st.max_new - len(st.generated) - 1,
                    self.max_len - st.kv_len - 1)
            if k <= 0:
                continue
            d = self.spec.drafter.propose(st.rid, st.prompt + st.generated,
                                          k)
            if d:
                out[st.rid] = [int(t) for t in d[:k]]
        return out

    def _spec_step(self, proposals: Dict[int, List[int]],
                   active: np.ndarray) -> None:
        """Draft -> verify -> accept -> rollback, as one step.

        Every running slot joins the verify batch — drafted rows carry
        ``[pending] + drafts``, undrafted rows just their pending token —
        padded to the widest run.  One ``backend.verify`` call scores all
        rows at their own ``kv_len`` (the paged-prefill kernel's
        per-batch ``kv_offset``); acceptance runs host-side per row under
        the request's own sampling params and PRNG stream; rejected
        drafts roll back as metadata (``PagedKVCache.truncate`` /
        a dense length reset — stale KV past the new length is masked
        and overwritten before it could ever be attended, the same
        argument that makes chunked prefill exact)."""
        slot_req = self.scheduler.slot_req
        slots = [int(s) for s in np.flatnonzero(active)]
        drafts = {s: proposals.get(slot_req[s].rid, []) for s in slots}
        width = max(len(d) for d in drafts.values()) + 1

        def row_tokens(s: int) -> List[int]:
            st = slot_req[s]
            d = drafts[s]
            return [st.generated[-1]] + d + [0] * (width - 1 - len(d))

        if self.paged:
            idx = jnp.asarray(slots)
            toks = jnp.asarray([row_tokens(s) for s in slots], jnp.int32)
            sub = {k: v for k, v in self.cache.items()
                   if k.startswith("pages_")}
            sub["block_tables"] = self.cache["block_tables"][idx]
            sub["len"] = self.cache["len"][idx]
            sub, logits = self.backend.verify({"tokens": toks}, sub)
            self._prefetch_next_step()
            for key in sub:
                if key.startswith("pages_"):
                    self.cache[key] = sub[key]
            row_of = {s: i for i, s in enumerate(slots)}
        else:
            # dense runs full width (static shapes); garbage rows of
            # vacant/prefilling slots are masked and their cache rows are
            # wholly overwritten at admission, exactly like plain decode.
            # Keep their lengths: verify bumps every row's len by the
            # padded width, but the real new lengths are only known after
            # acceptance — restore, then set per-slot below.
            # lint: allow[hot-path-sync] host mirror of slot lengths for
            # the accept/reject loop; dense "len" is a small host-side row
            lens_before = np.asarray(self.cache["len"])
            toks = jnp.asarray(
                [row_tokens(s) if active[s] else [0] * width
                 for s in range(self.max_slots)], jnp.int32)
            self.cache, logits = self.backend.verify({"tokens": toks},
                                                     self.cache)
            self._prefetch_next_step()
            self.cache["len"] = jnp.asarray(lens_before)
            row_of = {s: s for s in slots}

        with self.tracer.span("sample", track="sample", rows=len(slots)):
            # lint: allow[hot-path-sync] speculative accept/reject is
            # host-side by design (point-mass rejection sampling over the
            # verify logits); the step's one sync, same budget as sampling
            lg = np.asarray(logits, np.float32)     # (rows, width, V)
        for s in slots:
            st = slot_req[s]
            m = len(drafts[s])
            rows = lg[row_of[s], :m + 1]
            emitted = accept_row(rows, drafts[s], st.sampling, st.key,
                                 len(st.generated))
            n_full = len(emitted) - 1            # drafts accepted, pre-cut
            if st.eos is not None and st.eos in emitted:
                emitted = emitted[:emitted.index(st.eos) + 1]
            accepted = min(len(emitted), n_full)
            if m > 0:
                self.spec_stats.record(m, accepted)
                self.spec_by_req.setdefault(st.rid, SpecStats()) \
                    .record(m, accepted)
                if self._adaptive is not None:
                    self._adaptive.update(st.rid, m, accepted)
            if st.sampling.logprobs is not None:
                for j, t in enumerate(emitted):
                    st.logprobs.append(
                        logprob_record(rows[j], t, st.sampling.logprobs))
            st.generated.extend(emitted)
            # rollback: kv_len now counts only pending + accepted drafts;
            # pages past it unmap (paged) and the length vector shrinks
            new_len = st.kv_len
            if self.paged:
                self.cache = self.kv.truncate(self.cache, s, new_len)
                self.scheduler.tables_dirty = True
            self.cache["len"] = self.cache["len"].at[s].set(new_len)
            self.tokens = self.tokens.at[s].set(emitted[-1])
            self._maybe_finish(st)

    def _prefetch_next_step(self) -> None:
        """Kick step N+1's pins while step N's host tail (sampling,
        bookkeeping) drains.  The engine's wrap-around prefetch order
        already points each step's last module at the next step's first,
        but that wrap prefetch silently loses when the pinned ring is
        still full — retrying here, after the step's linears released
        their slots, lets the pin thread stage the next step's first
        module of every group concurrently with everything below."""
        if hasattr(self.backend, "prefetch_next_step"):
            self.backend.prefetch_next_step()

    def _decode_active_slots(self, active: np.ndarray) -> None:
        """One decode step over the active slots only.

        The paged cache makes batch compaction a metadata operation: the
        pools are global, so selecting the active block-table / length /
        token rows yields a smaller decode batch whose GEMMs match the
        real occupancy (what ``retune`` plans for) — inactive slots cost
        nothing and write nothing.  Results scatter back by slot index.
        """
        slots = np.flatnonzero(active)
        idx = jnp.asarray(slots)
        sub = {k: v for k, v in self.cache.items()
               if k.startswith("pages_")}
        sub["block_tables"] = self.cache["block_tables"][idx]
        sub["len"] = self.cache["len"][idx]
        sub, logits = self.backend.decode(self.tokens[idx], sub)
        self._prefetch_next_step()
        for key in sub:
            if key.startswith("pages_"):
                self.cache[key] = sub[key]
        self.cache["len"] = self.cache["len"].at[idx].set(sub["len"])
        nxt = self._sample_slot_rows(logits, list(slots))
        self.tokens = self.tokens.at[idx].set(nxt)

    def run_until_done(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            # resident() (not active) — a slot mid-chunked-prefill is not
            # decoding yet but still owes work
            if not self.queue and not self.scheduler.resident():
                break
            self.step()
        return {rid: r.generated for rid, r in self.requests.items()}

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Release the backend when this batcher owns it (an offload
        backend holds engine threads and pinned rings — leaking it leaks
        non-daemon threads).  Idempotent; safe on shared backends (no-op
        unless owning)."""
        if self._closed:
            return
        self._closed = True
        if self.kv is not None:
            # end-of-life audit: raises PagedCacheCorruption on leaked
            # pages when the cache was built with check=True
            self.kv.close()
        if self._own_backend:
            self.backend.close()

    def __enter__(self) -> "ContinuousBatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
