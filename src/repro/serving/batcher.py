"""Continuous batching: slot-based request scheduling over a shared cache.

Requests join/leave a fixed pool of ``max_slots`` decode slots without
stopping the batch:

  * a new request is prefilled alone (batch-1) and its KV written into a
    free slot of the global cache;
  * every ``step()`` advances all active slots by one token (inactive
    slots decode garbage that is masked out — the standard static-shape
    TPU pattern);
  * finished requests (max_new reached / eos) free their slot immediately.

Per-slot sequence lengths are first-class: the model's decode path accepts
a vector ``len`` and scatters each slot's new K/V at its own position.

The batcher schedules over any :mod:`repro.serving.backends` driver: the
default is the jitted scan-stacked resident path (today's behavior), but
``backend=HeteGenBackend(...)`` runs the SAME slot admit/release logic
over HeteGen-offloaded weights — continuous batching over host-resident
parameters, with the placement plan tuned for the decode batch
(= ``max_slots``).  Supported for the dense/moe/vlm transformer families
(per-slot state for SSM trunks would need per-slot state snapshots; see
DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.serving.backends import ScanResidentBackend
from repro.serving.sampling import SamplerConfig, make_sampler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    eos: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params: Optional[Dict] = None, *,
                 max_slots: int = 4, max_len: int = 512,
                 backend=None, sampler: SamplerConfig = SamplerConfig(),
                 seed: int = 0):
        if cfg.family in ("ssm", "hybrid", "encdec"):
            raise NotImplementedError(
                "continuous batching supports transformer KV caches")
        if backend is None and params is None:
            raise ValueError("ContinuousBatcher needs params or a backend")
        self.cfg = cfg
        self.backend = backend or ScanResidentBackend(cfg, params)
        if hasattr(self.backend, "retune"):
            # the decode batch is the slot count — enforce the documented
            # contract instead of trusting the caller's constructed plan
            self.backend.retune(max_slots)
        self.max_slots = max_slots
        self.max_len = max_len
        self.sample = make_sampler(sampler)
        self._key = jax.random.PRNGKey(seed)
        self.cache = self.backend.init_cache(max_slots, max_len)
        # per-slot lengths (vector 'len' drives per-slot scatter updates)
        self.cache["len"] = jnp.zeros((max_slots,), jnp.int32)
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        self.active = np.zeros((max_slots,), bool)
        self.requests: Dict[int, Request] = {}
        self._ids = itertools.count()
        self.queue: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int,
               eos: Optional[int] = None) -> int:
        rid = next(self._ids)
        req = Request(rid, list(prompt), max_new, eos)
        self.requests[rid] = req
        self.queue.append(req)
        return rid

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.max_slots) if not self.active[i]]

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _admit(self) -> None:
        axis = self.backend.cache_batch_axis
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            req.slot = slot
            one_cache = self.backend.init_cache(1, self.max_len)
            toks = jnp.asarray([req.prompt], jnp.int32)
            one_cache, logits = self.backend.prefill({"tokens": toks},
                                                     one_cache)
            first = self.sample(logits, self._next_key())
            # merge slot: every cache leaf carries batch at `axis`
            def merge(glob, one):
                if glob.ndim == 0 or glob.shape == ():
                    return glob
                return jax.lax.dynamic_update_slice_in_dim(
                    glob, one.astype(glob.dtype), slot, axis=axis)
            for key in self.cache:
                if key == "len":
                    continue
                self.cache[key] = merge(self.cache[key], one_cache[key])
            self.cache["len"] = self.cache["len"].at[slot].set(
                len(req.prompt))
            self.tokens = self.tokens.at[slot].set(first[0])
            req.generated.append(int(first[0]))
            self.active[slot] = True
            self._maybe_finish(req)

    def _maybe_finish(self, req: Request) -> None:
        if len(req.generated) >= req.max_new or \
                (req.eos is not None and req.generated
                 and req.generated[-1] == req.eos):
            req.done = True
            if req.slot is not None:
                self.active[req.slot] = False
                self.cache["len"] = self.cache["len"].at[req.slot].set(0)
                req.slot = None

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit waiting requests, advance all active slots one token.

        Returns the number of active slots after the step.
        """
        self._admit()
        if not self.active.any():
            return 0
        self.cache, logits = self.backend.decode(self.tokens, self.cache)
        nxt = self.sample(logits, self._next_key())
        self.tokens = nxt
        for req in list(self.requests.values()):
            if req.slot is not None and self.active[req.slot]:
                req.generated.append(int(nxt[req.slot]))
                self._maybe_finish(req)
        return int(self.active.sum())

    def run_until_done(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if not self.queue and not self.active.any():
                break
            self.step()
        return {rid: r.generated for rid, r in self.requests.items()}
