"""Continuous batching: slot-based request scheduling over a shared cache.

Requests join/leave a fixed pool of ``max_slots`` decode slots without
stopping the batch:

  * a new request is prefilled alone (batch-1) and its KV written into a
    free slot of the global cache;
  * every ``step()`` advances all active slots by one token (inactive
    slots decode garbage that is masked out — the standard static-shape
    TPU pattern);
  * finished requests (max_new reached / eos) free their slot immediately.

Per-slot sequence lengths are first-class: the model's decode path accepts
a vector ``len`` and scatters each slot's new K/V at its own position.

The batcher schedules over any :mod:`repro.serving.backends` driver: the
default is the jitted scan-stacked resident path (today's behavior), but
``backend=HeteGenBackend(...)`` runs the SAME slot admit/release logic
over HeteGen-offloaded weights — continuous batching over host-resident
parameters, with the placement plan tuned for the decode batch
(= ``max_slots``).  Supported for the dense/moe/vlm transformer families
(per-slot state for SSM trunks would need per-slot state snapshots; see
DESIGN.md §8).

``paged=True`` swaps the dense per-layer cache for the
:class:`repro.serving.kv_cache.PagedKVCache` subsystem: admission *maps*
pages for the request and prefill scatters its KV straight into them
through a batch-1 block-table view; release *unmaps* them back to the
free list.  No whole-cache slice is ever copied in or out of the global
cache, and when the pool runs dry requests simply stay queued until a
finishing request returns pages.  Decode attends through the paged
flash-decode kernel (block-table gather on TPU, jnp gather oracle here)
and *compacts* to the active slots: the pools are global, so selecting
the active block-table rows shrinks the decode batch to the real
occupancy instead of computing masked garbage in empty slots.  Paged
results are token-identical to the dense path under greedy sampling;
stochastic samplers draw per logits *row*, and compaction renumbers
rows, so they match only in distribution.  ``kv_dtype="int8"`` stores
q8 pages (int8 + scale pools) for half the cache footprint.

``retune_hysteresis`` (with a retune-capable backend, i.e. HeteGen)
re-tunes the placement plan when the *executed* decode batch drifts from
the planned batch by more than the hysteresis margin — §4.1's cost model
shifts alpha with compute intensity, but rebuilding the engine every
time one request finishes would thrash; the margin makes retunes sticky.
Only paged mode executes occupancy-sized batches (compaction), so only
paged mode ever re-tunes; the dense cache always runs ``max_slots``-wide
and its plan correctly stays put.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.serving.backends import ScanResidentBackend
from repro.serving.kv_cache import PagesExhausted, slot_view
from repro.serving.sampling import SamplerConfig, make_sampler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    eos: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params: Optional[Dict] = None, *,
                 max_slots: int = 4, max_len: int = 512,
                 backend=None, sampler: SamplerConfig = SamplerConfig(),
                 seed: int = 0, paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None,
                 retune_hysteresis: Optional[int] = None):
        if cfg.family in ("ssm", "hybrid", "encdec"):
            raise NotImplementedError(
                "continuous batching supports transformer KV caches")
        if backend is None and params is None:
            raise ValueError("ContinuousBatcher needs params or a backend")
        self.cfg = cfg
        self.backend = backend or ScanResidentBackend(cfg, params)
        if hasattr(self.backend, "retune"):
            # the decode batch is the slot count — enforce the documented
            # contract instead of trusting the caller's constructed plan
            self.backend.retune(max_slots)
        self.max_slots = max_slots
        self.max_len = max_len
        self.sample = make_sampler(sampler)
        self._key = jax.random.PRNGKey(seed)
        self.paged = paged
        self.kv = None
        if paged:
            self.kv = self.backend.init_paged_cache(
                max_slots, max_len, page_size=page_size, n_pages=n_pages,
                kv_dtype=kv_dtype)
            self.cache = self.kv.init_cache()
        else:
            self.cache = self.backend.init_cache(max_slots, max_len)
        # per-slot lengths (vector 'len' drives per-slot scatter updates)
        self.cache["len"] = jnp.zeros((max_slots,), jnp.int32)
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        self.active = np.zeros((max_slots,), bool)
        self.requests: Dict[int, Request] = {}
        self._ids = itertools.count()
        self.queue: List[Request] = []
        self.retune_hysteresis = retune_hysteresis
        self._plan_batch = max_slots
        self.retunes = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int,
               eos: Optional[int] = None) -> int:
        rid = next(self._ids)
        req = Request(rid, list(prompt), max_new, eos)
        self.requests[rid] = req
        self.queue.append(req)
        return rid

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.max_slots) if not self.active[i]]

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                break
            if self.paged:
                # map pages for the whole request up front (prompt +
                # generated tokens) — all-or-nothing, so when the pool is
                # dry the request stays queued (FIFO) until a finishing
                # request unmaps pages
                need = min(len(self.queue[0].prompt)
                           + self.queue[0].max_new, self.max_len)
                try:
                    self.kv.alloc(slot, need)
                except PagesExhausted:
                    break
            req = self.queue.pop(0)
            req.slot = slot
            toks = jnp.asarray([req.prompt], jnp.int32)
            if self.paged:
                logits = self._prefill_paged_slot(slot, toks)
            else:
                logits = self._prefill_dense_slot(slot, toks)
            first = self.sample(logits, self._next_key())
            self.cache["len"] = self.cache["len"].at[slot].set(
                len(req.prompt))
            self.tokens = self.tokens.at[slot].set(first[0])
            req.generated.append(int(first[0]))
            self.active[slot] = True
            self._maybe_finish(req)

    def _prefill_dense_slot(self, slot: int, toks: jax.Array) -> jax.Array:
        """Batch-1 prefill into a fresh dense cache, then whole-slice
        merge of every leaf into the global cache (the copy the paged
        path exists to avoid)."""
        axis = self.backend.cache_batch_axis
        one_cache = self.backend.init_cache(1, self.max_len)
        one_cache, logits = self.backend.prefill({"tokens": toks},
                                                 one_cache)

        # merge slot: every cache leaf carries batch at `axis`
        def merge(glob, one):
            if glob.ndim == 0 or glob.shape == ():
                return glob
            return jax.lax.dynamic_update_slice_in_dim(
                glob, one.astype(glob.dtype), slot, axis=axis)
        for key in self.cache:
            if key == "len":
                continue
            self.cache[key] = merge(self.cache[key], one_cache[key])
        return logits

    def _prefill_paged_slot(self, slot: int, toks: jax.Array) -> jax.Array:
        """Prefill through a batch-1 block-table view: the page pools are
        shared, so the prompt's KV scatters straight into the pages just
        mapped for this slot — admission moves exactly the new tokens,
        never a (1, max_len) cache slice."""
        self.cache["block_tables"] = self.kv.device_block_tables()
        one = slot_view(self.cache, slot)
        one, logits = self.backend.prefill({"tokens": toks}, one)
        for key in one:
            if key.startswith("pages_"):
                self.cache[key] = one[key]
        return logits

    def _maybe_finish(self, req: Request) -> None:
        if len(req.generated) >= req.max_new or \
                (req.eos is not None and req.generated
                 and req.generated[-1] == req.eos):
            req.done = True
            if req.slot is not None:
                self.active[req.slot] = False
                if self.paged:
                    # unmap: pages go back to the free list (shared
                    # prefix pages survive via their ref-counts)
                    self.kv.free(req.slot)
                    self.cache["block_tables"] = \
                        self.kv.device_block_tables()
                self.cache["len"] = self.cache["len"].at[req.slot].set(0)
                req.slot = None

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit waiting requests, advance all active slots one token.

        Returns the number of active slots after the step.
        """
        self._admit()
        if not self.active.any():
            return 0
        occ = int(self.active.sum())
        # the batch a decode step actually executes: paged decode compacts
        # to the active slots (cheap — a block-table row gather), dense
        # decode always runs the full slot width (inactive slots compute
        # masked garbage, the static-shape pattern)
        executed = occ if self.paged else self.max_slots
        if (self.retune_hysteresis is not None
                and hasattr(self.backend, "retune")
                and abs(executed - self._plan_batch)
                > self.retune_hysteresis):
            # executed batch drifted past the hysteresis margin: rebuild
            # the placement plan for it (ROADMAP item); small oscillations
            # stay on the current plan.  §4.1's cost model only sees the
            # executed width, so dense mode never re-tunes on occupancy.
            self.backend.retune(executed)
            self._plan_batch = executed
            self.retunes += 1
        if self.paged and occ < self.max_slots:
            self._decode_active_slots()
        else:
            self.cache, logits = self.backend.decode(self.tokens,
                                                     self.cache)
            self.tokens = self.sample(logits, self._next_key())
        nxt = self.tokens
        for req in list(self.requests.values()):
            if req.slot is not None and self.active[req.slot]:
                req.generated.append(int(nxt[req.slot]))
                self._maybe_finish(req)
        return int(self.active.sum())

    def _decode_active_slots(self) -> None:
        """One decode step over the active slots only.

        The paged cache makes batch compaction a metadata operation: the
        pools are global, so selecting the active block-table / length /
        token rows yields a smaller decode batch whose GEMMs match the
        real occupancy (what ``retune`` plans for) — inactive slots cost
        nothing and write nothing.  Results scatter back by slot index.
        """
        idx = jnp.asarray(np.flatnonzero(self.active))
        sub = {k: v for k, v in self.cache.items()
               if k.startswith("pages_")}
        sub["block_tables"] = self.cache["block_tables"][idx]
        sub["len"] = self.cache["len"][idx]
        sub, logits = self.backend.decode(self.tokens[idx], sub)
        for key in sub:
            if key.startswith("pages_"):
                self.cache[key] = sub[key]
        self.cache["len"] = self.cache["len"].at[idx].set(sub["len"])
        nxt = self.sample(logits, self._next_key())
        self.tokens = self.tokens.at[idx].set(nxt)

    def run_until_done(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if not self.queue and not self.active.any():
                break
            self.step()
        return {rid: r.generated for rid, r in self.requests.items()}
