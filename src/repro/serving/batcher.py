"""Continuous batching: slot-based request scheduling over a shared cache.

Requests join/leave a fixed pool of ``max_slots`` decode slots without
stopping the batch:

  * a new request is prefilled alone (batch-1) and its KV written into a
    free slot of the global cache;
  * every ``step()`` advances all active slots by one token (inactive
    slots decode garbage that is masked out — the standard static-shape
    TPU pattern);
  * finished requests (max_new reached / eos) free their slot immediately.

Per-slot sequence lengths are first-class: the model's decode path accepts
a vector ``len`` and scatters each slot's new K/V at its own position.
Supported for the dense/moe/vlm transformer families (per-slot state for
SSM trunks would need per-slot state snapshots; see DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    eos: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params: Dict, *, max_slots: int = 4,
                 max_len: int = 512):
        if cfg.family in ("ssm", "hybrid", "encdec"):
            raise NotImplementedError(
                "continuous batching supports transformer KV caches")
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = M.init_cache(cfg, max_slots, max_len)
        # per-slot lengths (vector 'len' drives per-slot scatter updates)
        self.cache["len"] = jnp.zeros((max_slots,), jnp.int32)
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        self.active = np.zeros((max_slots,), bool)
        self.requests: Dict[int, Request] = {}
        self._ids = itertools.count()
        self.queue: List[Request] = []

        def _decode(params, token, cache):
            cache, logits = M.decode_step(cfg, params, token, cache)
            return cache, jnp.argmax(logits, axis=-1).astype(jnp.int32)

        self._decode = jax.jit(_decode, donate_argnums=(2,))

        def _prefill_one(params, tokens, cache):
            cache, logits = M.prefill(cfg, params, {"tokens": tokens}, cache)
            return cache, jnp.argmax(logits, axis=-1).astype(jnp.int32)

        self._prefill_one = jax.jit(_prefill_one)

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int,
               eos: Optional[int] = None) -> int:
        rid = next(self._ids)
        req = Request(rid, list(prompt), max_new, eos)
        self.requests[rid] = req
        self.queue.append(req)
        return rid

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.max_slots) if not self.active[i]]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            req.slot = slot
            one_cache = M.init_cache(self.cfg, 1, self.max_len)
            toks = jnp.asarray([req.prompt], jnp.int32)
            one_cache, first = self._prefill_one(self.params, toks, one_cache)
            # merge slot: every kv leaf has batch at axis 1
            def merge(glob, one):
                if glob.ndim == 0 or glob.shape == ():
                    return glob
                return jax.lax.dynamic_update_slice_in_dim(
                    glob, one.astype(glob.dtype), slot, axis=1)
            for key in self.cache:
                if key == "len":
                    continue
                self.cache[key] = merge(self.cache[key], one_cache[key])
            self.cache["len"] = self.cache["len"].at[slot].set(
                len(req.prompt))
            self.tokens = self.tokens.at[slot].set(first[0])
            req.generated.append(int(first[0]))
            self.active[slot] = True
            self._maybe_finish(req)

    def _maybe_finish(self, req: Request) -> None:
        if len(req.generated) >= req.max_new or \
                (req.eos is not None and req.generated
                 and req.generated[-1] == req.eos):
            req.done = True
            if req.slot is not None:
                self.active[req.slot] = False
                self.cache["len"] = self.cache["len"].at[req.slot].set(0)
                req.slot = None

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit waiting requests, advance all active slots one token.

        Returns the number of active slots after the step.
        """
        self._admit()
        if not self.active.any():
            return 0
        self.cache, nxt = self._decode(self.params, self.tokens, self.cache)
        self.tokens = nxt
        for req in list(self.requests.values()):
            if req.slot is not None and self.active[req.slot]:
                req.generated.append(int(nxt[req.slot]))
                self._maybe_finish(req)
        return int(self.active.sum())

    def run_until_done(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if not self.queue and not self.active.any():
                break
            self.step()
        return {rid: r.generated for rid, r in self.requests.items()}
