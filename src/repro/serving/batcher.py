"""Continuous batching: slot-based request scheduling over a shared cache.

Requests join/leave a fixed pool of ``max_slots`` decode slots without
stopping the batch:

  * a new request is prefilled alone (batch-1) and its KV written into a
    free slot of the global cache;
  * every ``step()`` advances all active slots by one token (inactive
    slots decode garbage that is masked out — the standard static-shape
    TPU pattern);
  * finished requests (max_new reached / eos) free their slot immediately.

Per-slot sequence lengths are first-class: the model's decode path accepts
a vector ``len`` and scatters each slot's new K/V at its own position.

The batcher schedules over any :mod:`repro.serving.backends` driver: the
default is the jitted scan-stacked resident path (today's behavior), but
``backend=HeteGenBackend(...)`` runs the SAME slot admit/release logic
over HeteGen-offloaded weights — continuous batching over host-resident
parameters, with the placement plan tuned for the decode batch
(= ``max_slots``).  Supported for the dense/moe/vlm transformer families
(per-slot state for SSM trunks would need per-slot state snapshots; see
docs/SERVING.md).

Sampling is **per request** (docs/SERVING.md): each submit may carry its
own :class:`repro.serving.sampling.SamplingParams`, rows of one decode
batch are sampled under their own parameters (row-vectorized sampler),
and every request owns a PRNG stream keyed by its id and generated-token
count — never by batch-row number.  Paged compaction can therefore
renumber rows freely: paged and dense decode are token-identical even
under stochastic sampling.

``paged=True`` swaps the dense per-layer cache for the
:class:`repro.serving.kv_cache.PagedKVCache` subsystem: admission *maps*
pages for the request and prefill scatters its KV straight into them
through a batch-1 block-table view; release *unmaps* them back to the
free list.  No whole-cache slice is ever copied in or out of the global
cache, and when the pool runs dry requests simply stay queued until a
finishing request returns pages.  Decode attends through the paged
flash-decode kernel (block-table gather on TPU, jnp gather oracle here)
and *compacts* to the active slots: the pools are global, so selecting
the active block-table rows shrinks the decode batch to the real
occupancy instead of computing masked garbage in empty slots.
``kv_dtype="int8"`` stores q8 pages (int8 + scale pools) for half the
cache footprint.

``retune_hysteresis`` (with a retune-capable backend, i.e. HeteGen)
re-tunes the decode placement plan when the *executed* decode batch
drifts from the planned batch by more than the hysteresis margin —
§4.1's cost model shifts alpha with compute intensity, but rebuilding
the engine every time one request finishes would thrash; the margin
makes retunes sticky.  Only paged mode executes occupancy-sized batches
(compaction), so only paged mode ever re-tunes; the dense cache always
runs ``max_slots``-wide and its plan correctly stays put.  The *prefill*
plan is phase-tuned inside the backend itself from observed prompt
shapes, with its own multiplicative hysteresis — the two phases re-tune
independently.

The batcher owns backend lifetime when it constructed the backend (or
when handed one with ``own_backend=True``): ``close()`` — or leaving the
``with`` block — shuts down the owned backend's engine threads.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.serving.backends import ScanResidentBackend
from repro.serving.kv_cache import PagesExhausted, slot_view
from repro.serving.sampling import (SamplerConfig, SamplingParams, greedy,
                                    pack_sampling, request_key, sample_rows,
                                    step_key)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    eos: Optional[int] = None
    sampling: SamplingParams = SamplingParams()
    key: Optional[jax.Array] = None     # request-owned PRNG stream
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params: Optional[Dict] = None, *,
                 max_slots: int = 4, max_len: int = 512,
                 backend=None, sampler: SamplerConfig = SamplerConfig(),
                 seed: int = 0, paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None,
                 retune_hysteresis: Optional[int] = None,
                 own_backend: Optional[bool] = None):
        if cfg.family in ("ssm", "hybrid", "encdec"):
            raise NotImplementedError(
                "continuous batching supports transformer KV caches")
        if backend is None and params is None:
            raise ValueError("ContinuousBatcher needs params or a backend")
        self.cfg = cfg
        # own the backend when we constructed it; callers handing one over
        # transfer ownership with own_backend=True
        self._own_backend = backend is None if own_backend is None \
            else bool(own_backend)
        self.backend = backend or ScanResidentBackend(cfg, params)
        if hasattr(self.backend, "retune"):
            # the decode batch is the slot count — enforce the documented
            # contract instead of trusting the caller's constructed plan
            self.backend.retune(max_slots)
        self.max_slots = max_slots
        self.max_len = max_len
        self.default_sampling = SamplingParams.from_config(sampler)
        self._base_key = jax.random.PRNGKey(seed)
        self.paged = paged
        self.kv = None
        if paged:
            self.kv = self.backend.init_paged_cache(
                max_slots, max_len, page_size=page_size, n_pages=n_pages,
                kv_dtype=kv_dtype)
            self.cache = self.kv.init_cache()
        else:
            self.cache = self.backend.init_cache(max_slots, max_len)
        # per-slot lengths (vector 'len' drives per-slot scatter updates)
        self.cache["len"] = jnp.zeros((max_slots,), jnp.int32)
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        self.active = np.zeros((max_slots,), bool)
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self.requests: Dict[int, Request] = {}
        self._ids = itertools.count()
        self.queue: List[Request] = []
        self.retune_hysteresis = retune_hysteresis
        self._plan_batch = max_slots
        self.retunes = 0
        self._closed = False
        # packed sampling params change only when slot->request assignment
        # does (admit/release), not every step — cache the device arrays
        self._pack_sig: Optional[tuple] = None
        self._packed = None

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int,
               eos: Optional[int] = None, *,
               sampling: Optional[SamplingParams] = None,
               rid: Optional[int] = None) -> int:
        """Queue a request.  ``sampling`` defaults to the batcher-wide
        config; ``rid`` lets an owning scheduler keep one id space."""
        rid = next(self._ids) if rid is None else rid
        if rid in self.requests:
            raise ValueError(f"duplicate request id {rid}")
        sp = self.default_sampling if sampling is None else sampling
        req = Request(rid, list(prompt), max_new, eos, sampling=sp,
                      key=request_key(self._base_key, rid, sp))
        self.requests[rid] = req
        self.queue.append(req)
        return rid

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.max_slots) if not self.active[i]]

    def _sample_slot_rows(self, logits: jax.Array,
                          slots: List[int]) -> jax.Array:
        """Sample one token per logits row, row i belonging to slot
        ``slots[i]``.  Each occupied slot draws under its request's own
        params with the key for its next token; vacant rows (the dense
        path's masked garbage) sample greedily with a dead key, so they
        consume no entropy and cannot perturb real requests."""
        params, keys = [], []
        for s in slots:
            req = self.slot_req[s]
            if req is None:
                params.append(SamplingParams())
                keys.append(jnp.zeros((2,), jnp.uint32))
            else:
                params.append(req.sampling)
                keys.append(step_key(req.key, len(req.generated)))
        if all(p.kind == "greedy" for p in params):
            # the default serving config: skip the full-vocab sort the
            # mixed-kind sampler needs (greedy rows never draw entropy,
            # so this is exactly equivalent)
            return greedy(logits)
        sig = tuple((s, -1 if self.slot_req[s] is None
                     else self.slot_req[s].rid) for s in slots)
        if sig != self._pack_sig:
            self._pack_sig = sig
            self._packed = pack_sampling(params)
        return sample_rows(logits, jnp.stack(keys), self._packed)

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                break
            if self.paged:
                # map pages for the whole request up front (prompt +
                # generated tokens) — all-or-nothing, so when the pool is
                # dry the request stays queued (FIFO) until a finishing
                # request unmaps pages
                need = min(len(self.queue[0].prompt)
                           + self.queue[0].max_new, self.max_len)
                try:
                    self.kv.alloc(slot, need)
                except PagesExhausted:
                    break
            req = self.queue.pop(0)
            req.slot = slot
            self.slot_req[slot] = req
            toks = jnp.asarray([req.prompt], jnp.int32)
            if self.paged:
                logits = self._prefill_paged_slot(slot, toks)
            else:
                logits = self._prefill_dense_slot(slot, toks)
            first = self._sample_slot_rows(logits, [slot])
            self.cache["len"] = self.cache["len"].at[slot].set(
                len(req.prompt))
            self.tokens = self.tokens.at[slot].set(first[0])
            req.generated.append(int(first[0]))
            self.active[slot] = True
            self._maybe_finish(req)

    def _prefill_dense_slot(self, slot: int, toks: jax.Array) -> jax.Array:
        """Batch-1 prefill into a fresh dense cache, then whole-slice
        merge of every leaf into the global cache (the copy the paged
        path exists to avoid)."""
        axis = self.backend.cache_batch_axis
        one_cache = self.backend.init_cache(1, self.max_len)
        one_cache, logits = self.backend.prefill({"tokens": toks},
                                                 one_cache)

        # merge slot: every cache leaf carries batch at `axis`
        def merge(glob, one):
            if glob.ndim == 0 or glob.shape == ():
                return glob
            return jax.lax.dynamic_update_slice_in_dim(
                glob, one.astype(glob.dtype), slot, axis=axis)
        for key in self.cache:
            if key == "len":
                continue
            self.cache[key] = merge(self.cache[key], one_cache[key])
        return logits

    def _prefill_paged_slot(self, slot: int, toks: jax.Array) -> jax.Array:
        """Prefill through a batch-1 block-table view: the page pools are
        shared, so the prompt's KV scatters straight into the pages just
        mapped for this slot — admission moves exactly the new tokens,
        never a (1, max_len) cache slice."""
        self.cache["block_tables"] = self.kv.device_block_tables()
        one = slot_view(self.cache, slot)
        one, logits = self.backend.prefill({"tokens": toks}, one)
        for key in one:
            if key.startswith("pages_"):
                self.cache[key] = one[key]
        return logits

    def _maybe_finish(self, req: Request) -> None:
        if len(req.generated) >= req.max_new or \
                (req.eos is not None and req.generated
                 and req.generated[-1] == req.eos):
            req.done = True
            if req.slot is not None:
                self.active[req.slot] = False
                self.slot_req[req.slot] = None
                if self.paged:
                    # unmap: pages go back to the free list (shared
                    # prefix pages survive via their ref-counts)
                    self.kv.free(req.slot)
                    self.cache["block_tables"] = \
                        self.kv.device_block_tables()
                self.cache["len"] = self.cache["len"].at[req.slot].set(0)
                req.slot = None

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit waiting requests, advance all active slots one token.

        Returns the number of active slots after the step.
        """
        self._admit()
        if not self.active.any():
            return 0
        occ = int(self.active.sum())
        # the batch a decode step actually executes: paged decode compacts
        # to the active slots (cheap — a block-table row gather), dense
        # decode always runs the full slot width (inactive slots compute
        # masked garbage, the static-shape pattern)
        executed = occ if self.paged else self.max_slots
        if (self.retune_hysteresis is not None
                and hasattr(self.backend, "retune")
                and abs(executed - self._plan_batch)
                > self.retune_hysteresis):
            # executed batch drifted past the hysteresis margin: rebuild
            # the decode placement plan for it (ROADMAP item); small
            # oscillations stay on the current plan.  §4.1's cost model
            # only sees the executed width, so dense mode never re-tunes
            # on occupancy.  The prefill plan is the backend's own
            # business (phase-tuned on observed prompt shapes).
            self.backend.retune(executed, phase="decode")
            self._plan_batch = executed
            self.retunes += 1
        if self.paged and occ < self.max_slots:
            self._decode_active_slots()
        else:
            self.cache, logits = self.backend.decode(self.tokens,
                                                     self.cache)
            self.tokens = self._sample_slot_rows(
                logits, list(range(self.max_slots)))
        nxt = self.tokens
        for req in list(self.requests.values()):
            if req.slot is not None and self.active[req.slot]:
                req.generated.append(int(nxt[req.slot]))
                self._maybe_finish(req)
        return int(self.active.sum())

    def _decode_active_slots(self) -> None:
        """One decode step over the active slots only.

        The paged cache makes batch compaction a metadata operation: the
        pools are global, so selecting the active block-table / length /
        token rows yields a smaller decode batch whose GEMMs match the
        real occupancy (what ``retune`` plans for) — inactive slots cost
        nothing and write nothing.  Results scatter back by slot index.
        """
        slots = np.flatnonzero(self.active)
        idx = jnp.asarray(slots)
        sub = {k: v for k, v in self.cache.items()
               if k.startswith("pages_")}
        sub["block_tables"] = self.cache["block_tables"][idx]
        sub["len"] = self.cache["len"][idx]
        sub, logits = self.backend.decode(self.tokens[idx], sub)
        for key in sub:
            if key.startswith("pages_"):
                self.cache[key] = sub[key]
        self.cache["len"] = self.cache["len"].at[idx].set(sub["len"])
        nxt = self._sample_slot_rows(logits, list(slots))
        self.tokens = self.tokens.at[idx].set(nxt)

    def run_until_done(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if not self.queue and not self.active.any():
                break
            self.step()
        return {rid: r.generated for rid, r in self.requests.items()}

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Release the backend when this batcher owns it (an offload
        backend holds engine threads and pinned rings — leaking it leaks
        non-daemon threads).  Idempotent; safe on shared backends (no-op
        unless owning)."""
        if self._closed:
            return
        self._closed = True
        if self._own_backend:
            self.backend.close()

    def __enter__(self) -> "ContinuousBatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
