"""The request-level serving front door (docs/SERVING.md).

One facade, :class:`LLM`, covers every serving shape this repo has:
resident jitted generation, HeteGen-offloaded generation, continuous
batching (dense or paged KV), and streaming — behind a request-level API:

    with LLM(cfg, params) as llm:                       # resident
        outs = llm.generate([p1, p2], max_new=32)       # blocking batch

    llm = LLM(cfg, backend=HeteGenBackend(cfg, params, hw=..., ...),
              own_backend=True)                         # offloaded
    for tok in llm.stream(prompt, max_new=64):          # incremental
        ...
    rid = llm.submit(prompt, max_new=16,
                     sampling=SamplingParams(kind="topp", top_p=0.9),
                     on_token=print)                    # callback stream
    llm.drain()

Requests are the unit: each carries its prompt, budget, stop token, and
its own :class:`repro.serving.sampling.SamplingParams` (per-request PRNG
stream included).  The facade owns the scheduler and picks the executor:

  * **one-shot generator** — when a ``generate`` call arrives with no
    other requests in flight and a rectangular prompt batch, the whole
    batch runs as one prefill + decode loop
    (:class:`repro.serving.engine.Generator` under the hood);
  * **continuous batcher** — ``submit``/``stream``, ragged prompts, or
    calls overlapping in-flight work run through slot-based continuous
    batching (:class:`repro.serving.batcher.ContinuousBatcher`).

Because sampling draws from request-owned PRNG streams (keyed by request
id and token count, never batch row), the two executors produce
token-identical output for the same requests — executor choice is purely
a throughput decision.

Backends plug in unchanged: ``backend=None`` serves the scan-stacked
resident path from ``params`` (or a jitted per-layer
``ResidentBackend`` when ``paged=True``); any
:class:`repro.serving.backends.LinearBackend` — including the phase-aware
:class:`repro.serving.backends.HeteGenBackend`, which swaps placement
plans between prefill and decode — drops in via ``backend=``.
``own_backend=True`` transfers backend lifetime to the facade;
``close()`` (or the context manager) tears down everything the facade
owns.

Scheduling is a facade-level knob (``policy="fcfs" | "priority" |
"fair_share"``, ``optimistic=``, ``preempt_mode=`` — see
:mod:`repro.serving.scheduler` and docs/SERVING.md): requests carry a
``priority``, page pressure preempts and resumes them token-exactly, and
``SamplingParams.logprobs`` records per-token log-probabilities in the
:class:`RequestOutput`.

:class:`AsyncLLM` is the event-loop front end over the same facade: a
background thread owns the ``step()`` crank, ``submit`` returns an
:class:`AsyncRequest` handle (awaitable-style ``result()`` + token
iterator), and ``stream()`` yields with no caller-driven stepping.
"""

from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.serving.batcher import ContinuousBatcher
from repro.serving.engine import Generator
from repro.serving.sampling import SamplingParams, request_key
from repro.serving.scheduler import SchedulerPolicy
from repro.serving.speculative import SpecConfig
from repro.serving.tokenizer import StreamDecoder, Tokenizer
from repro.telemetry.export import write_chrome_trace
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.overlap import OverlapReport, compute_overlap
from repro.telemetry.tracer import Tracer, as_tracer

Prompt = Sequence[int]


@dataclasses.dataclass
class GenRequest:
    """One generation request, fully self-describing."""

    prompt: List[int]
    max_new: int
    eos: Optional[int] = None
    sampling: SamplingParams = SamplingParams()
    stream: Optional[Callable[[int], None]] = None   # per-token callback
    rid: Optional[int] = None                        # assigned by the LLM
    priority: int = 0           # larger = more important (priority policy)


@dataclasses.dataclass
class RequestOutput:
    """What a finished request produced."""

    rid: int
    prompt: List[int]
    tokens: List[int]
    finish_reason: str          # "length" | "eos"
    # one entry per token when SamplingParams.logprobs was set:
    # {"token": id, "logprob": float, "top": {id: logprob, ...}}
    logprobs: Optional[List[Dict]] = None
    text: Optional[str] = None  # decoded tokens when the LLM has a tokenizer


def _finish_reason(tokens: List[int], eos: Optional[int]) -> str:
    return "eos" if (eos is not None and tokens and tokens[-1] == eos) \
        else "length"


class LLM:
    """Request-level serving facade — the one front door.

    ``cfg, params`` serve resident weights; ``backend=`` swaps the
    execution engine (ResidentBackend, HeteGenBackend, ...).  Scheduler
    shape (``max_slots``, ``max_len``, ``paged``, ``retune_hysteresis``,
    ...) is facade-level config; everything request-level travels on the
    request itself.
    """

    def __init__(self, cfg: ModelConfig, params: Optional[Dict] = None, *,
                 backend=None, own_backend: Optional[bool] = None,
                 sampling: SamplingParams = SamplingParams(),
                 max_slots: int = 4, max_len: int = 512,
                 paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None,
                 retune_hysteresis: Optional[int] = None,
                 policy: Union[str, SchedulerPolicy, None] = "fcfs",
                 optimistic: bool = True,
                 preempt_mode: Optional[str] = None,
                 chunk_tokens: Optional[int] = None,
                 prefix_dedupe: Optional[bool] = None,
                 spec: Optional[SpecConfig] = None,
                 tokenizer: Optional[Tokenizer] = None,
                 seed: int = 0,
                 selfcheck: bool = False,
                 trace: Union[bool, Tracer] = False,
                 wstream: Optional[str] = None):
        if backend is None and params is None:
            raise ValueError("LLM needs params or a backend")
        # ``wstream`` is a property of the offload backend (the resident
        # paths never stream weights): accept it here only as a cross-check
        # against the backend actually passed in, so a caller asking for
        # q8 streaming cannot silently get an fp (or resident) run.
        if wstream not in (None, "fp", "q8"):
            raise ValueError(f"unknown wire format {wstream!r} "
                             "(expected 'fp' or 'q8')")
        if wstream is not None:
            be_ws = getattr(backend, "wstream", None)
            if be_ws is None:
                if wstream != "fp":
                    raise ValueError(
                        "wstream='q8' needs a streaming backend "
                        "(HeteGenBackend(wstream='q8')); this backend does "
                        "not stream weights")
            elif be_ws != wstream:
                raise ValueError(
                    f"wstream={wstream!r} conflicts with the backend's "
                    f"wire format {be_ws!r}")
        self.wstream = wstream
        self.cfg = cfg
        self._params = params
        self._backend = backend
        built_here = False
        if backend is None and paged:
            # the scan-stacked cache is not pageable; paged resident
            # serving runs through the jitted per-layer backend
            from repro.serving.backends import ResidentBackend
            self._backend = ResidentBackend(cfg, params)
            built_here = True
        self._own_backend = built_here if own_backend is None \
            else bool(own_backend)
        self.sampling = sampling
        self.max_slots = max_slots
        self.max_len = max_len
        self.paged = paged
        self.page_size = page_size
        self.n_pages = n_pages
        self.kv_dtype = kv_dtype
        self.retune_hysteresis = retune_hysteresis
        self.policy = policy
        self.optimistic = optimistic
        self.preempt_mode = preempt_mode
        self.chunk_tokens = chunk_tokens
        self.prefix_dedupe = prefix_dedupe
        self.spec = spec
        self.tokenizer = tokenizer
        self.seed = seed
        # selfcheck: PagedKVCache(check=True) — validate allocator
        # invariants every step and audit for leaked pages at close
        self.selfcheck = selfcheck
        # observability (docs/OBSERVABILITY.md): trace=True records
        # zero-sync spans across the whole stack (batcher steps, engine
        # streams, scheduler events); the registry is always live and
        # merges the legacy stats() keys on metrics()
        self.tracer = as_tracer(trace)
        self._metrics = MetricsRegistry()
        if self.tracer and backend is not None \
                and hasattr(backend, "set_tracer"):
            backend.set_tracer(self.tracer)
        # lint: allow[prng-discipline] the facade's base key: request_key
        # folds per-request ids into it, step_key derives per-token draws
        self._base_key = jax.random.PRNGKey(seed)
        self._ids = itertools.count()
        self._batcher: Optional[ContinuousBatcher] = None
        self._generator: Optional[Generator] = None
        self._callbacks: Dict[int, Callable[[int], None]] = {}
        self._delivered: Dict[int, int] = {}
        self._streaming: set = set()    # rids owned by live stream() iters
        self._closed = False
        self.last_executor: Optional[str] = None
        self.last_metrics: Dict[str, float] = {}

    # -- executors ------------------------------------------------------
    def _ensure_batcher(self) -> ContinuousBatcher:
        if self._batcher is None:
            kw = dict(max_slots=self.max_slots, max_len=self.max_len,
                      seed=self.seed, paged=self.paged,
                      page_size=self.page_size, n_pages=self.n_pages,
                      kv_dtype=self.kv_dtype,
                      retune_hysteresis=self.retune_hysteresis,
                      policy=self.policy, optimistic=self.optimistic,
                      preempt_mode=self.preempt_mode,
                      chunk_tokens=self.chunk_tokens,
                      prefix_dedupe=self.prefix_dedupe,
                      spec=self.spec, selfcheck=self.selfcheck,
                      tracer=self.tracer, metrics=self._metrics)
            if self._backend is None:
                self._batcher = ContinuousBatcher(self.cfg, self._params,
                                                  **kw)
            else:
                # the facade manages backend lifetime, not the batcher
                self._batcher = ContinuousBatcher(self.cfg,
                                                  backend=self._backend,
                                                  own_backend=False, **kw)
        return self._batcher

    def _ensure_generator(self) -> Generator:
        if self._generator is None:
            if self._backend is None:
                self._generator = Generator(self.cfg, self._params)
            else:
                self._generator = Generator(self.cfg,
                                            backend=self._backend)
        return self._generator

    # -- request normalization -----------------------------------------
    def _encode(self, text: str) -> List[int]:
        if self.tokenizer is None:
            raise ValueError("text prompts need a tokenizer "
                             "(LLM(..., tokenizer=ByteTokenizer()))")
        return list(self.tokenizer.encode(text))

    def _decode(self, tokens: Sequence[int]) -> Optional[str]:
        return None if self.tokenizer is None \
            else self.tokenizer.decode(tokens)

    def _default_eos(self, eos: Optional[int]) -> Optional[int]:
        if eos is None and self.tokenizer is not None:
            return self.tokenizer.eos_id
        return eos

    def _as_requests(self, prompts, max_new, eos, sampling
                     ) -> List[GenRequest]:
        if isinstance(prompts, (GenRequest, str)):
            prompts = [prompts]
        elif prompts and isinstance(prompts[0], (int, np.integer)):
            prompts = [prompts]          # a single raw token sequence
        eos = self._default_eos(eos)
        reqs: List[GenRequest] = []
        for i, p in enumerate(prompts):
            if isinstance(p, GenRequest):
                req = p
            else:
                if max_new is None:
                    raise ValueError("max_new is required for raw prompts")
                sp = sampling[i] if isinstance(sampling, (list, tuple)) \
                    else (sampling or self.sampling)
                toks = self._encode(p) if isinstance(p, str) \
                    else list(int(t) for t in p)
                req = GenRequest(toks, max_new, eos=eos, sampling=sp)
            if req.rid is None:
                req.rid = next(self._ids)
            reqs.append(req)
        return reqs

    # -- blocking batch -------------------------------------------------
    def generate(self,
                 prompts: Union[Prompt, Sequence[Prompt],
                                Sequence[GenRequest]],
                 max_new: Optional[int] = None, *,
                 eos: Optional[int] = None,
                 sampling: Union[SamplingParams,
                                 Sequence[SamplingParams], None] = None
                 ) -> List[RequestOutput]:
        """Run a batch of requests to completion and return their outputs.

        Executor selection: a rectangular batch with nothing else in
        flight runs one-shot (single prefill + jitted decode loop);
        ragged prompts, per-request budgets, or overlap with submitted
        work run through the continuous batcher.  Either way the tokens
        are identical (request-owned sampling streams).
        """
        reqs = self._as_requests(prompts, max_new, eos, sampling)
        if not reqs:
            return []
        busy = self._batcher is not None and (
            self._batcher.queue or self._batcher.scheduler.resident())
        rect = (len({len(r.prompt) for r in reqs}) == 1
                and len({r.max_new for r in reqs}) == 1
                and not any(r.stream for r in reqs)
                # logprob extraction rides the batcher's sampler
                and not any(r.sampling.logprobs is not None for r in reqs)
                # speculative decoding is a batcher feature (draft →
                # verify → rollback lives in its step loop)
                and self.spec is None)
        if rect and not busy:
            return self._generate_oneshot(reqs)
        return self._generate_batched(reqs)

    def _generate_oneshot(self, reqs: List[GenRequest]
                          ) -> List[RequestOutput]:
        g = self._ensure_generator()
        toks = jnp.asarray([r.prompt for r in reqs], jnp.int32)
        keys = [request_key(self._base_key, r.rid, r.sampling)
                for r in reqs]
        res = g.generate({"tokens": toks}, reqs[0].max_new,
                         sampling=[r.sampling for r in reqs],
                         request_keys=keys)
        self.last_executor = "generator"
        self.last_metrics = {"prefill_s": res.prefill_s,
                             "decode_s": res.decode_s,
                             "tokens_per_s": res.tokens_per_s}
        outs = []
        for req, row in zip(reqs, res.tokens):
            if req.eos is not None and req.eos in row:
                row = row[:row.index(req.eos) + 1]
            outs.append(RequestOutput(req.rid, req.prompt, list(row),
                                      _finish_reason(row, req.eos),
                                      text=self._decode(row)))
        return outs

    def _generate_batched(self, reqs: List[GenRequest]
                          ) -> List[RequestOutput]:
        b = self._ensure_batcher()
        for req in reqs:
            self._submit_req(req)
        t0 = time.perf_counter()
        steps = 0
        while not all(b.requests[r.rid].done for r in reqs):
            self._step_or_stall()
            steps += 1
        dt = max(time.perf_counter() - t0, 1e-9)
        n_tok = sum(len(b.requests[r.rid].generated) for r in reqs)
        self.last_executor = "batcher"
        self.last_metrics = {"steps": steps, "wall_s": dt,
                             "tokens_per_s": n_tok / dt}
        return [self._take_result(r.rid) for r in reqs]

    # -- incremental ----------------------------------------------------
    def submit(self, prompt: Union[Prompt, GenRequest],
               max_new: Optional[int] = None, *,
               eos: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               priority: Optional[int] = None,
               on_token: Optional[Callable[[int], None]] = None) -> int:
        """Queue one request on the continuous batcher; returns its id.

        ``on_token`` (or ``GenRequest.stream``) is called with each new
        token as scheduler steps deliver it.  ``priority`` matters to
        priority-aware scheduler policies (docs/SERVING.md); when given
        it overrides a ``GenRequest``'s own priority (0 included).
        """
        req = self._as_requests(prompt, max_new, eos, sampling)[0]
        if priority is not None:
            req.priority = priority
        return self._submit_req(req, on_token)

    def _submit_req(self, req: GenRequest,
                    on_token: Optional[Callable[[int], None]] = None
                    ) -> int:
        b = self._ensure_batcher()
        b.submit(req.prompt, req.max_new, req.eos,
                 sampling=req.sampling, rid=req.rid,
                 priority=req.priority)
        self._delivered[req.rid] = 0
        cb = on_token or req.stream
        if cb is not None:
            self._callbacks[req.rid] = cb
        return req.rid

    def step(self) -> int:
        """Advance the scheduler one decode step; fires stream callbacks.

        Returns the number of active slots after the step.
        """
        if self._batcher is None:
            return 0
        n = self._batcher.step()
        self._deliver()
        return n

    def _step_or_stall(self) -> int:
        """One scheduler step that refuses to spin: an idle scheduler
        whose admission makes no progress can never make any (a queued
        request wants more pages than the whole pool holds).  A resident
        slot mid-chunked-prefill counts as progress even though it is not
        decoding yet (step() legitimately returns 0 active slots then)."""
        b = self._batcher
        idle_before = not b.active.any() and not b.scheduler.resident()
        queued_before = len(b.queue)
        n = self.step()
        if n == 0 and b.queue and idle_before \
                and len(b.queue) == queued_before \
                and not b.scheduler.resident():
            raise RuntimeError("scheduler stalled with queued requests")
        return n

    def stream(self, prompt: Union[Prompt, GenRequest],
               max_new: Optional[int] = None, *,
               eos: Optional[int] = None,
               sampling: Optional[SamplingParams] = None
               ) -> Iterator[int]:
        """Submit one request and yield its tokens as they are decoded.

        Submission happens eagerly (the request is in the scheduler the
        moment this returns); only the token delivery is lazy.  Other
        in-flight requests keep advancing underneath (continuous
        batching); interleave several ``stream`` iterators freely.
        """
        rid = self.submit(prompt, max_new, eos=eos, sampling=sampling)
        # the iterator owns this request's reporting: a concurrent drain()
        # must neither evict it mid-iteration nor double-report it
        self._streaming.add(rid)
        return self._stream_tokens(rid)

    def _stream_tokens(self, rid: int) -> Iterator[int]:
        b = self._batcher
        req = b.requests[rid]
        sent = 0
        try:
            while True:
                while sent < len(req.generated):
                    yield req.generated[sent]
                    sent += 1
                if req.done:
                    break
                self._step_or_stall()
            self.last_executor = "batcher"
        finally:
            self._streaming.discard(rid)
            if req.done:
                self._take_result(rid)  # evict: fully delivered by yield

    def stream_text(self, prompt: Union[str, Prompt, GenRequest],
                    max_new: Optional[int] = None, *,
                    eos: Optional[int] = None,
                    sampling: Optional[SamplingParams] = None
                    ) -> Iterator[str]:
        """:meth:`stream`, decoded: yields text chunks as tokens land.

        Multi-byte characters that straddle token boundaries are held
        back until complete (empty chunks are skipped), so the
        concatenation of the yields is exactly ``decode(tokens)`` minus
        a trailing eos byte."""
        if self.tokenizer is None:
            raise ValueError("stream_text needs a tokenizer")
        dec = StreamDecoder(self.tokenizer)
        eos = self._default_eos(eos)
        for tok in self.stream(prompt, max_new, eos=eos,
                               sampling=sampling):
            if eos is not None and tok == eos:
                break
            chunk = dec.push(tok)
            if chunk:
                yield chunk
        tail = dec.flush()
        if tail:
            yield tail

    def drain(self, max_steps: int = 100_000) -> Dict[int, RequestOutput]:
        """Run the batcher until every submitted request finishes.

        Each finished request is reported exactly once (across drains and
        ``generate`` calls) and then evicted from the scheduler's books.
        """
        b = self._batcher
        if b is None:
            return {}
        t0 = time.perf_counter()
        before = sum(len(r.generated) for r in b.requests.values())
        steps = 0
        for _ in range(max_steps):
            if not b.queue and not b.scheduler.resident():
                break
            self._step_or_stall()
            steps += 1
        dt = max(time.perf_counter() - t0, 1e-9)
        toks = sum(len(r.generated) for r in b.requests.values()) - before
        self.last_executor = "batcher"
        self.last_metrics = {"steps": steps, "wall_s": dt,
                             "tokens_per_s": toks / dt}
        return {rid: self._take_result(rid)
                for rid in list(b.requests)
                if b.requests[rid].done and rid not in self._streaming}

    def result(self, rid: int) -> RequestOutput:
        """Output of a batcher-scheduled request (complete or partial)."""
        req = self._ensure_batcher().requests[rid]
        # the scheduler records why it finished a request; fall back to
        # inference for partial results (still running = "length" so far)
        reason = getattr(req, "finish_reason", None) \
            or _finish_reason(req.generated, req.eos)
        return RequestOutput(req.rid, req.prompt, list(req.generated),
                             reason,
                             logprobs=None if req.logprobs is None
                             else list(req.logprobs),
                             text=self._decode(req.generated))

    def _take_result(self, rid: int) -> RequestOutput:
        """result() + eviction: finished requests leave the scheduler's
        books once reported, so a long-lived facade doesn't accumulate
        every request it ever served (and repeated drains never re-report
        old work)."""
        out = self.result(rid)
        self._batcher.requests.pop(rid, None)
        self._delivered.pop(rid, None)
        return out

    def _deliver(self) -> None:
        for rid, cb in list(self._callbacks.items()):
            req = self._batcher.requests[rid]
            sent = self._delivered.get(rid, 0)
            for tok in req.generated[sent:]:
                cb(tok)
            self._delivered[rid] = len(req.generated)
            if req.done:
                del self._callbacks[rid]

    # -- introspection / lifecycle -------------------------------------
    @property
    def backend(self):
        """The executing backend (None = scan-stacked resident path)."""
        if self._backend is not None:
            return self._backend
        return self._batcher.backend if self._batcher is not None else None

    def stats(self) -> Dict:
        """Serving counters: executor choice, per-phase plans, engine
        stream busy-time — whatever the active backend exposes."""
        st: Dict = {"executor": self.last_executor, **self.last_metrics}
        be = self.backend
        if be is not None and hasattr(be, "wstream"):
            st["wstream"] = be.wstream
        if be is not None and hasattr(be, "policies"):
            st["phase_alpha"] = {ph: p.alpha
                                 for ph, p in be.policies.items()}
            st["phase_batch"] = {ph: (p.batch, p.tokens_per_seq)
                                 for ph, p in be.policies.items()}
        if be is not None and hasattr(be, "device_resident_bytes"):
            st["resident_bytes"] = be.device_resident_bytes()
        if be is not None and hasattr(be, "finish_stats"):
            st["stream"] = be.finish_stats()
        if self._batcher is not None:
            st["retunes"] = self._batcher.retunes
            sched = self._batcher.scheduler
            st["scheduler"] = {"policy": sched.policy.name,
                               "preemptions": sched.preemptions,
                               "waiting": len(sched.waiting),
                               "preempted": len(sched.preempted),
                               "chunks_planned": sched.chunks_planned,
                               "dedupe_hits": sched.dedupe_hits,
                               "dedupe_tokens": sched.dedupe_tokens,
                               # the current queue's worst holdup — the
                               # starvation signal a fairness/aging
                               # policy keys off
                               "max_wait_steps": max(
                                   (s.wait_steps for s in sched.pending),
                                   default=0)}
            kv = self._batcher.kv
            if kv is not None:
                st["paged"] = {"page_size": kv.page_size,
                               "pool_pages": kv.n_pages - 1,
                               "mapped_pages": kv.n_pages - 1
                               - kv.free_pages}
                # allocator self-check counters (cheap even without
                # check=True): pages_leaked != 0 means ref-count drift
                st["kv"] = kv.stats()
            if self._batcher.spec is not None:
                spec = self._batcher.spec_stats.as_dict()
                spec["per_request"] = {
                    rid: s.as_dict()
                    for rid, s in self._batcher.spec_by_req.items()}
                st["spec"] = spec
        return st

    def metrics(self) -> Dict:
        """One flat snapshot of every serving metric: the live batcher
        instruments (``serve.*``) merged with the legacy :meth:`stats`
        keys as namespaced gauges (``scheduler.preemptions``,
        ``kv.free_pages``, ``stream.cpu_s``, ...).  The nested
        :meth:`stats` dict remains during the deprecation window; this
        is its replacement surface (docs/OBSERVABILITY.md)."""
        reg = self._batcher.metrics if self._batcher is not None \
            else self._metrics
        reg.absorb(self.stats())
        return reg.snapshot()

    def write_trace(self, path: str) -> Dict:
        """Dump the recorded spans as Chrome trace JSON; returns the
        document (empty trace if tracing was never enabled)."""
        return write_chrome_trace(path, self.tracer)

    def overlap_report(self) -> OverlapReport:
        """Per-step I/O-hidden fraction / stream utilization / critical
        path from the recorded spans (paper Fig. 5c, Table 2)."""
        return compute_overlap(self.tracer.spans())

    def close(self) -> None:
        """Tear down everything the facade owns (idempotent)."""
        if self._closed:
            return
        self._closed = True
        if self._batcher is not None:
            self._batcher.close()
        if self._own_backend and self._backend is not None:
            self._backend.close()

    def __enter__(self) -> "LLM":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# AsyncLLM: the event-loop front end
# ---------------------------------------------------------------------------

_CLOSED = object()          # queue sentinel: no more tokens


class AsyncRequest:
    """Handle for a request submitted to :class:`AsyncLLM`.

    Iterate it to stream tokens as the background loop decodes them
    (blocking only while the next token is genuinely not ready), or call
    :meth:`result` to wait for the finished :class:`RequestOutput`.  Both
    are safe from any thread; the handle outlives the request inside the
    engine (tokens already queued keep flowing after completion)."""

    def __init__(self, rid: int):
        self.rid = rid
        self._q: "queue.Queue" = queue.Queue()
        self._done = threading.Event()
        self._output: Optional[RequestOutput] = None
        self._error: Optional[BaseException] = None

    # called by the AsyncLLM loop thread
    def _push(self, tok: int) -> None:
        self._q.put(tok)

    def _finish(self, output: Optional[RequestOutput] = None,
                error: Optional[BaseException] = None) -> None:
        self._output, self._error = output, error
        self._done.set()
        self._q.put(_CLOSED)

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> RequestOutput:
        """Block until the request finished; the awaitable-style surface.

        Raises the loop's failure (scheduler stall, closed mid-flight)
        instead of returning a partial output."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} still in flight")
        if self._error is not None:
            raise self._error
        return self._output

    def __iter__(self) -> Iterator[int]:
        while True:
            item = self._q.get()
            if item is _CLOSED:
                # keep the sentinel so a second iteration terminates too
                self._q.put(_CLOSED)
                if self._error is not None:
                    raise self._error
                return
            yield item


class AsyncLLM:
    """Event-loop serving: a background thread drives the scheduler.

    The synchronous :class:`LLM` only makes progress when the caller
    hand-cranks ``step()``; this front end owns that crank.  ``submit``
    returns an :class:`AsyncRequest` immediately and the loop thread
    steps the scheduler whenever requests are in flight — ``stream()``
    yields tokens with no caller-driven stepping, ``result()`` blocks
    like an awaitable, and many threads can submit/consume concurrently
    (the facade is guarded by one lock; decode steps batch work from
    every submitter).

        with AsyncLLM(cfg, params, policy="priority") as allm:
            hi = allm.submit(p1, max_new=32, priority=5)
            for tok in allm.stream(p2, max_new=64):   # no step() anywhere
                ...
            out = hi.result()

    Construction forwards every keyword to :class:`LLM` (policies, paged
    KV, backends, ...), or wraps an existing facade via ``llm=`` —
    ``close()`` tears down whatever it built.  ``close(drain=True)`` (the
    default) finishes in-flight requests first; ``close(drain=False)``
    abandons them, failing their handles with a ``RuntimeError``.  A
    scheduler failure (e.g. a stalled page pool) fails every in-flight
    handle and surfaces on the next ``submit``."""

    def __init__(self, cfg: Optional[ModelConfig] = None,
                 params: Optional[Dict] = None, *,
                 llm: Optional[LLM] = None, **llm_kwargs):
        if llm is None:
            llm = LLM(cfg, params, **llm_kwargs)
            self._own_llm = True
        else:
            if llm_kwargs or cfg is not None or params is not None:
                raise ValueError("pass either llm= or LLM constructor "
                                 "arguments, not both")
            self._own_llm = False
        self._llm = llm
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._handles: Dict[int, AsyncRequest] = {}
        self._closed = False
        self._failure: Optional[BaseException] = None
        self._busy_s = 0.0          # loop seconds spent inside step()
        self._tokens_done = 0       # tokens of finished requests
        self._thread = threading.Thread(target=self._loop,
                                        name="asyncllm-step", daemon=True)
        self._thread.start()

    # -- submission -----------------------------------------------------
    def _register(self, req: GenRequest) -> AsyncRequest:
        if self._closed:
            raise RuntimeError("AsyncLLM is closed")
        if self._failure is not None:
            raise RuntimeError("AsyncLLM loop failed") from self._failure
        h = AsyncRequest(-1)
        if req.stream is None:
            on_tok = h._push
        else:
            # the GenRequest's own per-token callback keeps firing (from
            # the loop thread) alongside the handle's queue
            def on_tok(tok, _user=req.stream, _push=h._push):
                _user(tok)
                _push(tok)
        h.rid = self._llm._submit_req(req, on_token=on_tok)
        self._handles[h.rid] = h
        return h

    def submit(self, prompt: Union[Prompt, GenRequest],
               max_new: Optional[int] = None, *,
               eos: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               priority: Optional[int] = None) -> AsyncRequest:
        """Queue one request; returns its handle immediately.  The
        background loop wakes and decodes without further calls."""
        with self._work:
            req = self._llm._as_requests(prompt, max_new, eos, sampling)[0]
            if priority is not None:
                req.priority = priority
            h = self._register(req)
            self._work.notify_all()
        return h

    def stream(self, prompt: Union[Prompt, GenRequest],
               max_new: Optional[int] = None, *,
               eos: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               priority: Optional[int] = None) -> Iterator[int]:
        """Submit and iterate tokens as the loop decodes them."""
        return iter(self.submit(prompt, max_new, eos=eos, sampling=sampling,
                                priority=priority))

    def generate(self,
                 prompts: Union[Prompt, Sequence[Prompt],
                                Sequence[GenRequest]],
                 max_new: Optional[int] = None, *,
                 eos: Optional[int] = None,
                 sampling: Union[SamplingParams,
                                 Sequence[SamplingParams], None] = None,
                 timeout: Optional[float] = None) -> List[RequestOutput]:
        """Blocking batch convenience over the event loop."""
        with self._work:
            reqs = self._llm._as_requests(prompts, max_new, eos, sampling)
            handles = [self._register(r) for r in reqs]
            self._work.notify_all()
        return [h.result(timeout) for h in handles]

    # -- the loop -------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._work:
                while not self._handles and not self._closed:
                    self._work.wait()
                if not self._handles:          # closed and drained
                    return
                t0 = time.perf_counter()
                try:
                    self._llm._step_or_stall()
                except BaseException as e:     # stall, backend death, ...
                    self._failure = e
                    for h in self._handles.values():
                        h._finish(error=e)
                    self._handles.clear()
                    continue
                self._busy_s += time.perf_counter() - t0
                b = self._llm._batcher
                fin = [rid for rid in self._handles
                       if rid in b.requests and b.requests[rid].done]
                for rid in fin:
                    out = self._llm._take_result(rid)
                    self._tokens_done += len(out.tokens)
                    self._handles.pop(rid)._finish(output=out)

    # -- introspection / lifecycle -------------------------------------
    @property
    def llm(self) -> LLM:
        return self._llm

    def stats(self) -> Dict:
        with self._lock:
            st = self._llm.stats()
            st["in_flight"] = len(self._handles)
            if self._busy_s > 0:
                # the loop thread owns the crank, so the facade's
                # per-drain metrics never fire — report the loop's own
                st["executor"] = "batcher(async)"
                st["tokens_per_s"] = self._tokens_done / self._busy_s
            return st

    def close(self, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop the loop (idempotent).  ``drain=True`` lets in-flight
        requests finish first; ``drain=False`` abandons them — their
        handles raise ``RuntimeError`` from ``result()``/iteration.
        With a ``timeout``, raises ``TimeoutError`` if the drain did not
        finish in time — and leaves the backend open rather than tearing
        it down under the still-stepping loop thread."""
        with self._work:
            if not drain and self._handles:
                err = RuntimeError(
                    "AsyncLLM closed with requests in flight")
                for h in self._handles.values():
                    h._finish(error=err)
                self._handles.clear()
            self._closed = True
            self._work.notify_all()
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError(
                "AsyncLLM close timed out with requests still draining; "
                "retry close() or close(drain=False)")
        if self._own_llm:
            self._llm.close()

    def __enter__(self) -> "AsyncLLM":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
