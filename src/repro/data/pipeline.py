"""Data pipeline: tokenizer, synthetic corpus, packing, host-side prefetch.

Everything the training examples need, built in-repo (the container is
offline):

* :class:`ByteTokenizer` — reversible byte-level vocabulary (256 + specials)
* :func:`synthetic_corpus` — seeded documents with learnable structure
  (repeated n-gram motifs), so tiny-model training demonstrably reduces
  loss below the uniform floor
* :class:`PackedLMDataset` — documents packed into fixed (B, S) batches with
  next-token labels, deterministic given (seed, step)
* :class:`Prefetcher` — background thread keeping ``depth`` batches ready so
  host input never stalls the device step (the single-host analogue of a
  per-host input pipeline)
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, List, Optional

import numpy as np


class ByteTokenizer:
    PAD, BOS, EOS = 256, 257, 258

    @property
    def vocab_size(self) -> int:
        return 259

    def encode(self, text: str) -> List[int]:
        return [self.BOS] + list(text.encode("utf-8")) + [self.EOS]

    def decode(self, ids) -> str:
        bs = bytes(i for i in ids if i < 256)
        return bs.decode("utf-8", errors="replace")


def synthetic_corpus(n_docs: int, *, vocab: int, seed: int = 0,
                     min_len: int = 64, max_len: int = 512,
                     motif_len: int = 8, n_motifs: int = 32
                     ) -> List[np.ndarray]:
    """Documents built from a shared motif bank: the next token is highly
    predictable within a motif, so cross entropy can drop well below
    log(vocab)."""
    rng = np.random.default_rng(seed)
    motifs = rng.integers(0, vocab, (n_motifs, motif_len))
    docs = []
    for _ in range(n_docs):
        length = int(rng.integers(min_len, max_len))
        out: List[int] = []
        while len(out) < length:
            m = motifs[int(rng.integers(0, n_motifs))]
            out.extend(m.tolist())
        docs.append(np.asarray(out[:length], np.int32))
    return docs


class PackedLMDataset:
    """Packs documents into (B, S) token blocks with next-token labels."""

    def __init__(self, docs: List[np.ndarray], *, batch: int, seq: int,
                 seed: int = 0, pad_id: int = 0):
        self.batch, self.seq = batch, seq
        stream = np.concatenate(docs)
        self.rng = np.random.default_rng(seed)
        n_tokens = batch * (seq + 1)
        reps = max(1, -(-n_tokens * 4 // len(stream)))
        self.stream = np.concatenate([stream] * reps)
        self.pos = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        need = self.batch * (self.seq + 1)
        if self.pos + need > len(self.stream):
            self.pos = 0
        chunk = self.stream[self.pos:self.pos + need]
        self.pos += need
        block = chunk.reshape(self.batch, self.seq + 1)
        return {"tokens": np.ascontiguousarray(block[:, :-1]),
                "labels": np.ascontiguousarray(block[:, 1:])}


class Prefetcher:
    """Thread that keeps up to ``depth`` batches materialized ahead."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.it = it
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.done = False
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self.it:
                if self.done:
                    return
                self.q.put(item)
        except Exception as e:            # propagate through the queue
            self.q.put(e)
        finally:
            self.q.put(StopIteration())

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if isinstance(item, StopIteration):
            raise StopIteration
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self.done = True


def make_training_data(cfg, *, batch: int, seq: int, seed: int = 0,
                       prefetch: int = 2):
    docs = synthetic_corpus(256, vocab=cfg.vocab_size, seed=seed)
    ds = PackedLMDataset(docs, batch=batch, seq=seq, seed=seed)
    return Prefetcher(iter(ds), depth=prefetch)
