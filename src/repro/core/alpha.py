"""HeteGen's computation-distribution law (paper §3.2 and §4.2).

``alpha`` is the fraction of a linear module's weight computed **on the
accelerator** (with its weights streamed over the link); ``1 - alpha`` is
computed on the host CPU, concurrently.  The paper derives (Eq. 4):

    (1-a) W / V_cpu  =  a W / V_gpu  +  a W / V_com

i.e. host compute time balances (device compute + weight transfer), giving
(Eq. 5):

    a = 1 / ( V_cpu/V_com + V_cpu/V_gpu + 1 )

With device compute negligible relative to the link (Eq. 6):

    a ≈ V_com / (V_com + V_cpu)

and in measured-time form (Eq. 7), with T'_x the time for the *whole*
operator on resource x:

    a ≈ T'_cpu / (T'_cpu + T'_com)

The hybrid strategy (paper Fig. 5c) splits communication into pin||transfer
(Eq. 8-9):

    T_cpu = T_gpu + max(T_pin, T_trans)
    a ≈ T'_cpu / (T'_cpu + max(T'_pin, T'_trans))

All functions are pure and unit-free (any consistent speed/time units).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

DEFAULT_PREFILL_TOKENS = 128   # prompt-length prior when none was observed
DEFAULT_VERIFY_TOKENS = 8      # draft-run prior (k + 1) when none observed


def alpha_analytic(v_cpu: float, v_gpu: float, v_com: float) -> float:
    """Exact distribution ratio, paper Eq. 5."""
    if v_cpu <= 0:
        return 1.0  # no host compute available: everything on the device
    if v_gpu <= 0 or v_com <= 0:
        return 0.0  # no device or no link: everything stays on the host
    return 1.0 / (v_cpu / v_com + v_cpu / v_gpu + 1.0)


def alpha_for_batch(hw, batch: int) -> float:
    """Batch-aware analytic ratio (paper §4.1): decode at batch ``b`` runs
    ~``b`` FLOPs per parameter byte, so compute-bound resources derate and
    the optimal split shifts with the serving batch size.

    ``hw`` is any speed provider with ``v_cpu(intensity)`` /
    ``v_gpu(intensity)`` / ``v_com()`` (duck-typed
    :class:`repro.core.hw.HardwareSpec`).
    """
    intensity = float(max(batch, 1))
    return alpha_analytic(hw.v_cpu(intensity), hw.v_gpu(intensity),
                          hw.v_com())


def resolve_phase_tokens(phase: str,
                         tokens_per_seq: Optional[int] = None) -> int:
    """Per-sequence tokens of one step for a serving phase — THE place
    the phase -> intensity rule lives (alpha law and policy builder both
    call it): 1 for decode, the prompt length for prefill
    (:data:`DEFAULT_PREFILL_TOKENS` when unobserved), and the draft run
    length k + 1 for the speculative "verify" phase
    (:data:`DEFAULT_VERIFY_TOKENS` when unobserved) — verification scores
    batch x (k + 1) positions against one weight stream, so alpha tuning
    must see it as the prefill-like workload it is, not as decode."""
    if phase not in ("prefill", "decode", "verify"):
        raise ValueError(f"unknown phase {phase!r}")
    if tokens_per_seq is None:
        tokens_per_seq = {"prefill": DEFAULT_PREFILL_TOKENS,
                          "verify": DEFAULT_VERIFY_TOKENS,
                          "decode": 1}[phase]
    return max(int(tokens_per_seq), 1)


def alpha_for_phase(hw, batch: int, phase: str = "decode",
                    tokens_per_seq: Optional[int] = None) -> float:
    """Phase-aware analytic ratio (paper §4.1).

    Decode moves every parameter byte per step but computes only ``batch``
    token positions, so its arithmetic intensity is ~``batch`` FLOPs per
    parameter byte and the link/host usually dominate (small alpha).
    Prefill computes ``batch * prompt_len`` positions against the same
    weight traffic, so intensity scales with the prompt: the host GEMM
    derates by orders of magnitude and the optimal split pushes toward
    the accelerator (alpha -> 1).
    """
    intensity = float(max(batch, 1)
                      * resolve_phase_tokens(phase, tokens_per_seq))
    return alpha_analytic(hw.v_cpu(intensity), hw.v_gpu(intensity),
                          hw.v_com())


def effective_link_speed(v_com: float, wire_ratio: float) -> float:
    """Link speed in *compute* bytes/s when the wire format compresses.

    Streaming ``wire_ratio`` wire bytes per compute byte (int8 + scales
    over fp32 gives r ~= 1/4) makes the link look ``1/r`` times faster to
    the alpha law: substituting T_com -> r * T_com in Eq. 4 yields

        a = 1 / ( r * V_cpu/V_com + V_cpu/V_gpu + 1 )

    which is exactly :func:`alpha_analytic` evaluated at ``v_com / r``
    (derivation in docs/ANALYSIS.md).  Monotone: r < 1 => larger alpha.
    """
    if wire_ratio <= 0:
        raise ValueError("wire_ratio must be positive")
    return v_com / wire_ratio


def alpha_approx(v_cpu: float, v_com: float) -> float:
    """Approximate ratio ignoring device compute time, paper Eq. 6."""
    if v_cpu <= 0:
        return 1.0
    if v_com <= 0:
        return 0.0
    return v_com / (v_com + v_cpu)


def alpha_from_times(t_cpu: float, t_com: float) -> float:
    """Measured-time form, paper Eq. 7.

    ``t_cpu``/``t_com``: time to run / transfer the WHOLE operator on the
    host / over the link.
    """
    if t_cpu <= 0:
        return 0.0
    if t_com <= 0:
        return 1.0
    return t_cpu / (t_cpu + t_com)


def alpha_hybrid(t_cpu: float, t_pin: float, t_trans: float) -> float:
    """Hybrid pin||transfer form, paper Eq. 9."""
    return alpha_from_times(t_cpu, max(t_pin, t_trans))


def balance_residual(alpha: float, v_cpu: float, v_gpu: float,
                     v_com: float) -> float:
    """Signed imbalance of Eq. 4 at a given alpha (0 at the optimum).

    Positive means the host side is slower (alpha too small).
    """
    t_host = (1.0 - alpha) / v_cpu if v_cpu > 0 else float("inf")
    t_dev = alpha / v_gpu + alpha / v_com
    return t_host - t_dev


def quantize_alpha(alpha: float, n_out: int, tile: int = 128) -> float:
    """Round alpha to a whole number of MXU-aligned output-column tiles.

    TPU adaptation (DESIGN.md §2): the device-side fraction of a split
    linear is laid out in ``tile``-wide column blocks so the streamed matmul
    hits the 128x128 systolic array without re-layout.  Returns the achieved
    fraction ``k*tile/n_out`` closest to ``alpha`` (clamped to [0, 1]).
    """
    if n_out <= 0:
        raise ValueError("n_out must be positive")
    alpha = min(max(alpha, 0.0), 1.0)
    n_tiles = max(1, -(-n_out // tile))  # ceil
    k = round(alpha * n_out / tile)
    k = min(max(k, 0), n_tiles)
    cols = min(k * tile, n_out)
    return cols / n_out


def split_columns(alpha: float, n_out: int, tile: int = 128) -> int:
    """Number of output columns assigned to the device (tile-aligned)."""
    return int(round(quantize_alpha(alpha, n_out, tile) * n_out))


@dataclasses.dataclass(frozen=True)
class AlphaDecision:
    """A resolved distribution for one module."""

    alpha: float                 # achieved (tile-quantized) fraction
    device_cols: int             # output columns on the device
    host_cols: int               # output columns on the host
    t_cpu: float                 # predicted host time at this alpha
    t_com: float                 # predicted link time at this alpha

    @property
    def predicted_latency(self) -> float:
        return max(self.t_cpu, self.t_com)


def decide(n_out: int, bytes_total: float, *, v_cpu: float, v_gpu: float,
           v_com: float, v_pin: float | None = None,
           tile: int = 128) -> AlphaDecision:
    """End-to-end alpha decision for a module with ``n_out`` output columns.

    Uses the hybrid law when ``v_pin`` is given (communication limited by
    max(pin, transfer) — paper Eq. 9), else the exact analytic law (Eq. 5).
    """
    if v_pin is not None:
        # effective link speed under pin||transfer parallelism
        v_eff = min(v_com, v_pin) if v_pin < v_com else v_com
        a = alpha_analytic(v_cpu, v_gpu, v_eff)
    else:
        a = alpha_analytic(v_cpu, v_gpu, v_com)
    a_q = quantize_alpha(a, n_out, tile)
    dev_cols = split_columns(a, n_out, tile)
    t_cpu = (1 - a_q) * bytes_total / v_cpu if v_cpu > 0 else float("inf")
    t_com = a_q * bytes_total / v_com if v_com > 0 else float("inf")
    return AlphaDecision(alpha=a_q, device_cols=dev_cols,
                         host_cols=n_out - dev_cols, t_cpu=t_cpu, t_com=t_com)
