"""Alpha benchmark — measurement-refined distribution ratios (paper §4.4).

CPU GEMM time and link time are *not* exactly proportional to the parameter
fraction alpha (cache effects, per-call overheads, DMA setup), and one-shot
benchmarks are noisy.  The paper therefore refines the analytic alpha:

  1. start from the prior ``alpha0`` (Eq. 9),
  2. probe alphas in ``[alpha0 - gamma, alpha0 + gamma]`` in steps ``lambda``,
  3. measure T'_cpu(a) and max(T'_pin, T'_trans)(a) at each probe,
  4. fit polynomials  F_cpu(a), F_com(a)  to the measurements,
  5. solve  F_cpu(ā) = F_com(ā)   (paper Eq. 10-12).

The solver works on any pair of measurement callables, so the same code
refines (a) real wall-clock measurements on this host, (b) the discrete-event
simulator, and (c) unit-test stubs.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core import alpha as alpha_lib


@dataclasses.dataclass
class FitResult:
    alpha: float                    # refined ā
    alpha0: float                   # analytic prior
    probes: np.ndarray              # probed alpha values
    t_cpu: np.ndarray               # measured host times at probes
    t_com: np.ndarray               # measured max(pin, trans) at probes
    coef_cpu: np.ndarray            # polynomial coefficients (np.polyfit order)
    coef_com: np.ndarray
    predicted_time: float           # F_cpu(ā) (= F_com(ā) at the solution)


def _fit_poly(x: np.ndarray, y: np.ndarray, degree: int) -> np.ndarray:
    degree = min(degree, len(x) - 1)
    return np.polyfit(x, y, degree)


def refine_alpha(
    time_cpu: Callable[[float], float],
    time_com: Callable[[float], float],
    alpha0: float,
    *,
    gamma: float = 0.08,
    lam: float = 0.02,
    degree: int = 2,
    repeats: int = 1,
) -> FitResult:
    """Refine ``alpha0`` by probing and polynomial fitting (paper Eq. 10-12).

    ``time_cpu(a)``   — measured host time when the host computes (1-a).
    ``time_com(a)``   — measured max(T_pin, T_trans) when the device gets a.
    """
    lo = max(0.0, alpha0 - gamma)
    hi = min(1.0, alpha0 + gamma)
    n = max(3, int(round((hi - lo) / max(lam, 1e-6))) + 1)
    probes = np.linspace(lo, hi, n)

    t_cpu = np.array([
        min(time_cpu(float(a)) for _ in range(repeats)) for a in probes])
    t_com = np.array([
        min(time_com(float(a)) for _ in range(repeats)) for a in probes])

    coef_cpu = _fit_poly(probes, t_cpu, degree)
    coef_com = _fit_poly(probes, t_com, degree)

    # Solve F_cpu(a) - F_com(a) = 0 on [lo, hi]; fall back to the probe with
    # the smallest |difference| if no real root lands in range.
    diff = np.polysub(coef_cpu, coef_com)
    candidates = []
    if len(diff) > 1:
        for r in np.roots(diff):
            if abs(r.imag) < 1e-9 and lo - 1e-9 <= r.real <= hi + 1e-9:
                candidates.append(float(r.real))
    if candidates:
        a_bar = min(candidates, key=lambda a: abs(a - alpha0))
    else:
        a_bar = float(probes[np.argmin(np.abs(t_cpu - t_com))])
    a_bar = float(min(max(a_bar, 0.0), 1.0))
    predicted = float(np.polyval(coef_cpu, a_bar))
    return FitResult(alpha=a_bar, alpha0=alpha0, probes=probes, t_cpu=t_cpu,
                     t_com=t_com, coef_cpu=coef_cpu, coef_com=coef_com,
                     predicted_time=predicted)


# ---------------------------------------------------------------------------
# Real measurement helpers (used by examples/alpha_tuning.py on this host).
# ---------------------------------------------------------------------------

def measure_host_linear(n_in: int, n_out: int, *, batch: int = 1,
                        dtype=np.float32, iters: int = 3) -> float:
    """Wall-clock seconds for one (batch, n_in) @ (n_in, n_out) on the host."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((batch, n_in)).astype(dtype)
    w = rng.standard_normal((n_in, n_out)).astype(dtype)
    x @ w  # warmup
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        x @ w
        best = min(best, time.perf_counter() - t0)
    return best


def measure_staging_copy(nbytes: int, *, iters: int = 3) -> float:
    """Wall-clock seconds to stage ``nbytes`` into a pre-allocated buffer.

    This is the 'pin' analogue on a TPU host: a memcpy into the DMA-able
    staging ring (DESIGN.md §2).
    """
    src = np.ones(nbytes, dtype=np.uint8)
    dst = np.empty_like(src)
    np.copyto(dst, src)  # warmup
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        best = min(best, time.perf_counter() - t0)
    return best


def calibrated_speeds(n_in: int = 4096, n_out: int = 4096,
                      *, link_bw: float | None = None) -> dict:
    """Measure this host's v_cpu / v_pin; take v_com from the hardware model.

    Returns a dict compatible with :func:`repro.core.alpha.decide` kwargs.
    There is no accelerator in this container, so v_gpu/v_com come from the
    hardware spec (TPU_V5E by default).
    """
    from repro.core.hw import TPU_V5E

    nbytes = n_in * n_out * 4
    t_cpu = measure_host_linear(n_in, n_out)
    t_pin = measure_staging_copy(nbytes)
    return {
        "v_cpu": nbytes / max(t_cpu, 1e-9),
        "v_pin": nbytes / max(t_pin, 1e-9),
        "v_com": link_bw if link_bw is not None else TPU_V5E.link_bw,
        "v_gpu": TPU_V5E.accel_mem_bw,
    }


def probe_schedule(alpha0: float, gamma: float, lam: float) -> Sequence[float]:
    """The probe points the paper's benchmark visits (exposed for tests)."""
    lo = max(0.0, alpha0 - gamma)
    hi = min(1.0, alpha0 + gamma)
    n = max(3, int(round((hi - lo) / max(lam, 1e-6))) + 1)
    return list(np.linspace(lo, hi, n))


def analytic_prior(v_cpu: float, v_gpu: float, v_com: float,
                   v_pin: float | None = None) -> float:
    """Convenience: the Eq. 5/9 prior used as the center of the probe window."""
    if v_pin is not None and v_pin < v_com:
        return alpha_lib.alpha_analytic(v_cpu, v_gpu, v_pin)
    return alpha_lib.alpha_analytic(v_cpu, v_gpu, v_com)
