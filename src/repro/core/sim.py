"""Discrete-event simulator of heterogeneous offloaded decoding.

This container has no accelerator, so the paper's *performance* results
(Fig. 8 throughput curves, Table 2 stream-utilization breakdown, Table 3
ablations) are reproduced under a simulated clock.  The simulator models the
four hardware streams HeteGen schedules:

    cpu    — host GEMM on the (1-alpha) share of each linear
    pin    — staging copies into the DMA-able ring ("pin memory")
    trans  — host->device DMA ("transfer")
    dev    — accelerator compute

with the true data dependencies of a transformer decode step:

  * activations are sequential: module i+1 cannot *compute* before module i
    finished (both its host and device halves);
  * weights are not: pinning/transfer for later modules may run arbitrarily
    far ahead, limited only by ring-buffer capacity (the asynchronous
    parameter manager, paper §4.3) and a device-side prefetch window;
  * the hybrid strategy (paper Fig. 5c) runs pin || transfer on separate
    streams; the non-hybrid variant (Fig. 5b) lets pinning block both the
    link and the host ("pinning memory blocks both communication and CPU
    computation").

Strategies simulated (see DESIGN.md §1 and benchmarks/):

    resident            everything in accelerator memory (no offload)
    naive_offload       Accelerate/DeepSpeed-style: stream everything from
                        pageable memory, no overlap, no host compute
    sync_offload        FlexGen-style: pinned transfers overlapped with the
                        previous module's device compute; attention on CPU;
                        no weight-split host compute
    hetegen_basic       Fig. 5a: alpha-split, unpinned async transfer
    hetegen_pinned      Fig. 5b: + pinning, but pin blocks cpu & link
    hetegen             Fig. 5c: hybrid pin||transfer + async manager

The same module schedule drives the real threaded engine
(:mod:`repro.core.engine`); the simulator only supplies the clock.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core.hw import HardwareSpec
from repro.core import alpha as alpha_lib


# ---------------------------------------------------------------------------
# Workload description
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SimModule:
    """One schedulable module of the decode step."""

    name: str
    kind: str                 # "linear" | "attn_core" | "small"
    nbytes: int               # weight bytes (0 for attn_core)
    n_out: int                # output columns (alpha tile quantization)
    group: str                # async-manager size group ("attn" | "mlp" | ...)
    flops: float              # FLOPs for this module at the sim batch size
    cache_bytes: int = 0      # KV-cache bytes touched (attn_core only)
    calls: int = 1            # invocations per step (e.g. shared blocks)
    wire_bytes: Optional[int] = None   # streamed-format bytes; None => fp

    @property
    def link_bytes(self) -> int:
        """Bytes the full module would move across pin/DMA (wire format)."""
        return self.nbytes if self.wire_bytes is None else self.wire_bytes


@dataclasses.dataclass
class Placement:
    """Resolved policy for one module."""

    mode: str                 # "resident" | "hetegen" | "stream"
    alpha: float = 1.0        # device fraction (hetegen); 1.0 for stream


@dataclasses.dataclass
class SimResult:
    step_time: float                        # seconds per decode step
    busy: Dict[str, float]                  # per-stream busy seconds
    utilization: Dict[str, float]           # busy / step_time
    tokens_per_s: float
    device_bytes: float                     # resident + peak streamed bytes
    timeline: List[tuple]                   # (stream, start, end, module)

    def throughput(self, batch: int) -> float:
        return batch * self.tokens_per_s


# ---------------------------------------------------------------------------
# Core event loop
# ---------------------------------------------------------------------------

_STREAMS = ("cpu", "pin", "trans", "dev")


class _Clock:
    def __init__(self):
        self.free = {s: 0.0 for s in _STREAMS}
        self.busy = {s: 0.0 for s in _STREAMS}
        self.timeline: List[tuple] = []

    def run(self, stream: str, earliest: float, dur: float, tag: str) -> float:
        """Schedule ``dur`` seconds on ``stream`` no earlier than ``earliest``."""
        if dur <= 0:
            return max(earliest, self.free[stream])
        start = max(earliest, self.free[stream])
        end = start + dur
        self.free[stream] = end
        self.busy[stream] += dur
        self.timeline.append((stream, start, end, tag))
        return end


def _device_time(m: SimModule, hw: HardwareSpec, frac: float,
                 batch: int, mem_bytes: Optional[int] = None) -> float:
    """Device time for ``frac`` of module ``m`` (roofline of HBM vs MXU).

    ``mem_bytes`` overrides the weight bytes the memory term reads — a
    streamed q8 share holds (and re-reads) only the wire-format payload.
    """
    wb = m.nbytes if mem_bytes is None else mem_bytes
    t_mem = frac * (wb + m.cache_bytes) / hw.accel_mem_bw
    t_flops = frac * m.flops / hw.accel_flops
    return max(t_mem, t_flops)


def _host_time(m: SimModule, hw: HardwareSpec, frac: float) -> float:
    t_mem = frac * (m.nbytes + m.cache_bytes) / hw.host_mem_bw
    t_flops = frac * m.flops / hw.host_flops
    return max(t_mem, t_flops)


def simulate_step(
    modules: Sequence[SimModule],
    placements: Dict[str, Placement],
    hw: HardwareSpec,
    *,
    batch: int = 1,
    hybrid_comm: bool = True,
    async_manager: bool = True,
    prefetch_window: int = 2,
    pinned: bool = True,
    prepinned: bool = False,
) -> SimResult:
    """Simulate one decode step.

    ``hybrid_comm=False`` reproduces Fig. 5b (pinning blocks cpu+link).
    ``async_manager=False`` pins each module just-in-time, serializing
    pin -> transfer on the critical path (no cross-module prefetch).
    ``pinned=False`` transfers from pageable memory (Fig. 5a / naive).
    """
    clock = _Clock()
    ready = 0.0                        # when the previous module's output exists
    module_done: List[float] = []      # completion time per module index
    trans_done: Dict[int, float] = {}  # per-index transfer completion
    pin_done: Dict[int, float] = {}
    # ring-buffer state per group: completion time at which the slot frees
    ring_free: Dict[str, List[float]] = {}
    group_seq: Dict[str, int] = {}     # per-group streamed-module counter

    link_bw = hw.link_bw if pinned else hw.link_bw_unpinned
    device_bytes = 0.0
    peak_stream_bytes = 0.0

    mods = list(modules)
    for i, m in enumerate(mods):
        pl = placements.get(m.name, Placement("resident"))
        for _ in range(m.calls):
            if pl.mode == "resident" or m.kind in ("small",):
                t = _device_time(m, hw, 1.0, batch)
                end = clock.run("dev", ready, t, m.name)
                ready = end
                if m.kind == "linear":
                    device_bytes += m.nbytes
                continue

            if m.kind == "attn_core":
                # FlexGen-style strategies compute attention on the host to
                # avoid shipping the KV cache; hetegen keeps it on device.
                if pl.mode == "stream" and pl.alpha >= 1.0:
                    t = _host_time(m, hw, 1.0)
                    end = clock.run("cpu", ready, t, m.name)
                else:
                    t = _device_time(m, hw, 1.0, batch)
                    end = clock.run("dev", ready, t, m.name)
                ready = end
                continue

            # --- streamed / heterogeneous linear ---
            a = 1.0 if pl.mode == "stream" else pl.alpha
            a = alpha_lib.quantize_alpha(a, m.n_out)
            # bytes that cross pin/DMA: the wire format (compressed when
            # wire_bytes < nbytes); host compute still sees fp bytes
            dev_bytes = a * m.link_bytes
            peak_stream_bytes = max(peak_stream_bytes, dev_bytes)

            # pin stage
            seq = group_seq.get(m.group, 0)
            group_seq[m.group] = seq + 1
            if prepinned:
                # FlexGen-style: weights pinned once at load time (costs a
                # full extra copy of the weights in host RAM — the paper's
                # dynamic-range critique); no per-step pin stage
                pin_done[i] = 0.0
            elif pinned and dev_bytes > 0:
                t_pin = dev_bytes / hw.pin_bw
                if not hybrid_comm:
                    # Fig. 5b: pinning blocks both host compute and the link.
                    start = max(ready, clock.free["cpu"], clock.free["trans"])
                    end_pin = clock.run("pin", start, t_pin, m.name + "/pin")
                    clock.free["cpu"] = max(clock.free["cpu"], end_pin)
                    clock.free["trans"] = max(clock.free["trans"], end_pin)
                    pin_done[i] = end_pin
                elif async_manager:
                    # paper §4.3: the ring holds <=1 spare pinned buffer per
                    # group; pin of the group's seq-th module waits on the
                    # slot freed by the transfer of the (seq-2)-th.
                    ring = ring_free.setdefault(m.group, [0.0, 0.0])
                    slot_free = ring[seq % 2]
                    end_pin = clock.run("pin", slot_free, t_pin,
                                        m.name + "/pin")
                    pin_done[i] = end_pin
                else:
                    # just-in-time pinning: cannot start before the module is
                    # reached (no prefetch) — serializes pin -> transfer.
                    end_pin = clock.run("pin", ready, t_pin, m.name + "/pin")
                    pin_done[i] = end_pin
            else:
                pin_done[i] = 0.0

            # transfer stage (weights have no activation dependency; may run
            # ahead, limited by the device-side prefetch window)
            if dev_bytes > 0:
                t_trans = dev_bytes / link_bw
                window_gate = 0.0
                j = i - prefetch_window
                if j >= 0 and j < len(module_done):
                    window_gate = module_done[j]
                start = max(pin_done[i], window_gate)
                end_trans = clock.run("trans", start, t_trans,
                                      m.name + "/trans")
                trans_done[i] = end_trans
                if async_manager and hybrid_comm and pinned:
                    ring = ring_free.setdefault(m.group, [0.0, 0.0])
                    ring[seq % 2] = end_trans
            else:
                trans_done[i] = 0.0

            # host share
            cpu_end = ready
            if a < 1.0:
                t_cpu = _host_time(m, hw, 1.0 - a)
                cpu_end = clock.run("cpu", ready, t_cpu, m.name + "/cpu")

            # device share
            dev_end = ready
            if a > 0.0:
                t_dev = _device_time(m, hw, a, batch,
                                     mem_bytes=m.link_bytes)
                dev_end = clock.run("dev", max(ready, trans_done[i]), t_dev,
                                    m.name + "/dev")

            ready = max(cpu_end, dev_end)
        module_done.append(ready)

    step_time = ready if ready > 0 else 1e-12
    util = {s: clock.busy[s] / step_time for s in _STREAMS}
    return SimResult(
        step_time=step_time,
        busy=dict(clock.busy),
        utilization=util,
        tokens_per_s=1.0 / step_time,
        device_bytes=device_bytes + peak_stream_bytes * 2,  # double buffer
        timeline=clock.timeline,
    )


# ---------------------------------------------------------------------------
# Strategy frontends
# ---------------------------------------------------------------------------

def make_placements(
    modules: Sequence[SimModule],
    strategy: str,
    hw: HardwareSpec,
    *,
    gpu_mem_budget: Optional[float] = None,
    use_alpha_benchmark: bool = True,
    use_module_scheduler: bool = True,
    alpha_bias: float = 0.25,
    batch: int = 1,
) -> Dict[str, Placement]:
    """Resolve per-module placements for a named strategy.

    ``alpha_bias`` models the error of skipping the alpha benchmark (paper
    §4.4 / Table 3 row 'no alpha benchmark'): the analytic prior is computed
    from a host speed misestimated by +bias.
    """
    from repro.core.module_scheduler import ModuleInfo, schedule

    placements: Dict[str, Placement] = {}
    if strategy == "resident":
        for m in modules:
            placements[m.name] = Placement("resident")
        return placements

    if strategy in ("naive_offload", "sync_offload"):
        # FlexGen-style percentage placement: first weights up to the
        # budget live on the accelerator, the rest stream (no gain
        # ranking, no split — that is HeteGen's contribution)
        budget = gpu_mem_budget or 0.0
        used = 0.0
        for m in modules:
            if m.kind == "linear":
                if strategy == "sync_offload" and \
                        used + m.nbytes <= budget:
                    placements[m.name] = Placement("resident")
                    used += m.nbytes
                else:
                    placements[m.name] = Placement("stream", 1.0)
            elif m.kind == "attn_core" and strategy == "sync_offload":
                placements[m.name] = Placement("stream", 1.0)  # attn on CPU
            else:
                placements[m.name] = Placement("resident")
        return placements

    if not strategy.startswith("hetegen"):
        raise ValueError(f"unknown strategy {strategy!r}")

    # intensity: decode GEMV does ~batch FLOPs per weight byte (bf16)
    intensity = max(batch, 1)
    v_cpu = hw.v_cpu(intensity)
    v_gpu = hw.v_gpu(intensity)
    v_com = hw.v_com()
    if not use_alpha_benchmark:
        v_cpu = v_cpu * (1.0 + alpha_bias)  # misestimated prior
    linears = [m for m in modules if m.kind == "linear"]
    wire_ratio = 1.0
    if linears:
        big = max(linears, key=lambda m: m.nbytes)
        if big.nbytes > 0:
            wire_ratio = big.link_bytes / big.nbytes
    a = alpha_lib.alpha_analytic(
        v_cpu, v_gpu, alpha_lib.effective_link_speed(v_com, wire_ratio))

    if use_alpha_benchmark:
        # refine against end-to-end simulated step time (the paper probes
        # alpha0 +- gamma against real measurements — the sim IS our
        # measurement here), so the refined alpha is never worse than the
        # analytic prior at the probed granularity
        from repro.core.alpha_benchmark import probe_schedule

        def step_time_at(alpha):
            pl = {m.name: (Placement("hetegen", alpha)
                           if m.kind == "linear" else Placement("resident"))
                  for m in modules}
            return simulate_step(modules, pl, hw, batch=batch).step_time

        probes = list(probe_schedule(a, gamma=0.08, lam=0.02)) + [a]
        a = min(probes, key=step_time_at)

    for m in modules:
        if m.kind == "linear":
            placements[m.name] = Placement("hetegen", a)
        else:
            placements[m.name] = Placement("resident")

    # module scheduler: promote high-gain modules to residency (paper §4.5)
    if use_module_scheduler and gpu_mem_budget is not None:
        infos = [ModuleInfo(name=m.name, mem_bytes=m.nbytes,
                            t_cpu=_host_time(m, hw, 1.0), calls=m.calls)
                 for m in modules if m.kind == "linear"]
        # budget available for promotions = budget minus streaming buffers
        # (sized to the wire format actually staged)
        stream_buf = 2 * max((a * m.link_bytes for m in modules
                              if m.kind == "linear"), default=0)
        plan = schedule(infos, max(0.0, gpu_mem_budget - stream_buf))
        for name in plan.resident:
            placements[name] = Placement("resident")
    return placements


def run_strategy(
    modules: Sequence[SimModule],
    strategy: str,
    hw: HardwareSpec,
    *,
    batch: int = 1,
    gpu_mem_budget: Optional[float] = None,
    **toggles,
) -> SimResult:
    """Resolve placements for ``strategy`` and simulate one decode step."""
    sim_kw = {}
    if strategy == "naive_offload":
        sim_kw = dict(pinned=False, async_manager=False, hybrid_comm=False,
                      prefetch_window=0)
    elif strategy == "sync_offload":
        sim_kw = dict(pinned=True, async_manager=False, hybrid_comm=False,
                      prefetch_window=2, prepinned=True)
    elif strategy == "hetegen_basic":      # Fig. 5a
        sim_kw = dict(pinned=False, async_manager=False, hybrid_comm=True)
    elif strategy == "hetegen_pinned":     # Fig. 5b
        sim_kw = dict(pinned=True, hybrid_comm=False)
    elif strategy in ("hetegen", "resident"):
        sim_kw = dict(pinned=True, hybrid_comm=True, async_manager=True)
    for k in ("hybrid_comm", "async_manager", "pinned", "prefetch_window"):
        if k in toggles:
            sim_kw[k] = toggles.pop(k)
    placements = make_placements(modules, strategy, hw, batch=batch,
                                 gpu_mem_budget=gpu_mem_budget, **toggles)
    return simulate_step(modules, placements, hw, batch=batch, **sim_kw)
