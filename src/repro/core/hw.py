"""Hardware models for heterogeneous (accelerator + host + interconnect) systems.

HeteGen's distribution law (paper Eq. 4-9) is parameterized entirely by the
speeds of three resources:

  * the accelerator           (fast compute, small memory)
  * the host CPU              (slow compute, large memory)
  * the host<->device link    (the offloading bottleneck)

plus, for the *hybrid* strategy (paper Fig. 5c), the staging ("pin")
bandwidth, since communication is split into pin || transfer.

Two concrete systems are modeled:

  * ``PAPER_A10``  — the paper's evaluation rig (NVIDIA A10 + Intel Xeon
    @2.30GHz + PCIe 30 GB/s, Table 1).  Used by the paper-reproduction
    benchmarks so Fig. 8 / Table 2 / Table 3 are comparable to the paper.
  * ``TPU_V5E``    — the TPU-native target this framework is built for.
    Accelerator constants match the roofline constants used in
    EXPERIMENTS.md (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).

Decode-phase (batch≈1) linear layers are memory-bandwidth bound on every
resource, so "speed" for the alpha law is expressed in *parameter bytes per
second* — the same convention as the paper's Fig. 1 ("parameter size divided
by processing time").  For compute-bound phases (prefill / large batch) the
speeds are derated by an arithmetic-intensity-aware effective rate, computed
in :func:`effective_speeds`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Speeds/capacities of one heterogeneous node.

    All bandwidths are bytes/second, flops are FLOP/s, capacities bytes.
    """

    name: str
    # Accelerator ("GPU" in the paper; a TPU chip here).
    accel_flops: float              # dense matmul peak (bf16/fp16)
    accel_mem_bw: float             # HBM bandwidth
    accel_mem_bytes: float          # HBM capacity
    # Host ("CPU" in the paper).
    host_flops: float               # practical CPU GEMM peak
    host_mem_bw: float              # host DRAM bandwidth usable by GEMV
    host_mem_bytes: float           # host DRAM capacity
    # Interconnect.
    link_bw: float                  # host->device DMA (pinned/staged source)
    link_bw_unpinned: float         # host->device from pageable memory
    pin_bw: float                   # host memcpy into the staging/pinned ring
    # Multi-chip fabric (used by the roofline, not by the alpha law).
    ici_bw: Optional[float] = None  # per-link inter-chip interconnect
    dcn_bw: Optional[float] = None  # per-host data-center network

    # ----- speeds for the alpha law (bytes of parameters per second) -----

    def v_gpu(self, intensity: float = 1.0) -> float:
        """Accelerator speed in param-bytes/s at a given arithmetic intensity.

        ``intensity`` is FLOPs per parameter *byte* (2/bytes_per_param for
        batch-1 GEMV, scaled by batch for larger batches).  The device is
        memory-bound below the roofline ridge and compute-bound above it.
        """
        mem_rate = self.accel_mem_bw
        compute_rate = self.accel_flops / max(intensity, 1e-30)
        return min(mem_rate, compute_rate)

    def v_cpu(self, intensity: float = 1.0) -> float:
        mem_rate = self.host_mem_bw
        compute_rate = self.host_flops / max(intensity, 1e-30)
        return min(mem_rate, compute_rate)

    def v_com(self) -> float:
        return self.link_bw

    def v_pin(self) -> float:
        return self.pin_bw


def effective_speeds(hw: HardwareSpec, *, flops_per_byte: float
                     ) -> tuple[float, float, float, float]:
    """(v_cpu, v_gpu, v_com, v_pin) at a given arithmetic intensity.

    ``flops_per_byte`` — FLOPs executed per parameter byte moved/processed.
    Decode with batch b and 2-byte params has intensity b (2*b flops per
    2-byte weight element).
    """
    return (hw.v_cpu(flops_per_byte), hw.v_gpu(flops_per_byte),
            hw.v_com(), hw.v_pin())


# ---------------------------------------------------------------------------
# The paper's evaluation hardware (Table 1): A10 24GB + Xeon 2.30GHz + PCIe.
# CPU GEMV bandwidth ~6 channels DDR4-2933 derated; the paper caps CPU use at
# 16 cores.  pin_bw chosen so that T_pin/T_trans ~= 0.72/0.97 (Table 2).
# ---------------------------------------------------------------------------
PAPER_A10 = HardwareSpec(
    name="a10-xeon-pcie",
    accel_flops=125e12,            # A10 FP16 tensor-core dense
    accel_mem_bw=600e9,            # A10 HBM
    accel_mem_bytes=24e9,
    host_flops=1.2e12,             # 16 Xeon cores, AVX-512 fp32 GEMM
    host_mem_bw=120e9,             # measured-class DDR4 GEMV bandwidth
    host_mem_bytes=512e9,
    link_bw=30e9,                  # Table 1: PCIe 30 GB/s (pinned)
    link_bw_unpinned=9e9,          # pageable-source PCIe (what naive offload gets)
    pin_bw=40e9,                   # host memcpy into pinned ring
)

# ---------------------------------------------------------------------------
# TPU v5e host — the deployment target.  Roofline constants per the task
# sheet: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s per ICI link.  Host side:
# a v5e host exposes ~PCIe gen4-class DMA to its chips and a server-class
# DRAM subsystem.
# ---------------------------------------------------------------------------
TPU_V5E = HardwareSpec(
    name="tpu-v5e-host",
    accel_flops=197e12,
    accel_mem_bw=819e9,
    accel_mem_bytes=16e9,
    host_flops=2.0e12,
    host_mem_bw=150e9,
    host_mem_bytes=256e9,
    link_bw=32e9,
    link_bw_unpinned=10e9,
    pin_bw=45e9,
    ici_bw=50e9,
    dcn_bw=25e9,
)

# Registry for CLI flags (--hw).
HARDWARE = {h.name: h for h in (PAPER_A10, TPU_V5E)}
HARDWARE["a10"] = PAPER_A10
HARDWARE["v5e"] = TPU_V5E
