"""Asynchronous parameter manager (paper §4.3, Fig. 6).

Hybrid heterogeneous parallelism needs every streamed module's weights to be
*pinned* (staged into a DMA-able buffer) before its transfer starts.  The
manager guarantees:

  * asynchrony — pinning of the *next* module in a size group overlaps the
    current module's compute/transfer (the preceding module "prepares the
    pinned weights for the subsequent parameters");
  * bounded memory — at most one spare pinned parameter per group: each
    group owns a ring of two fixed slots (consume one while staging the
    other), sized to the group's largest member.  Groups exist because
    within a group module sizes are uniform, so pin times are uniform and
    no bubbles form (paper: linears-in-attention vs linears-in-MLP).

On a TPU host "pinning" is the staging memcpy into the DMA ring
(DESIGN.md §2); here it is a real ``np.copyto`` into a preallocated buffer,
executed by a dedicated pin thread, so overlap and ordering are real even
though the container is CPU-only.

A module's entry may be a single array or a **tuple of arrays** (the
quantized wire format streams an int8 payload plus its fp32 per-column
scales): tuple parts are packed sequentially into one slot and come back
as typed views, so rings are sized to the *wire* bytes actually staged —
compressed formats shrink the pinned footprint for free.  Pin spans carry
those wire bytes (plus ``fp_bytes``, the uncompressed equivalent, when
the owner supplies it) and a per-module ``seq`` counter that the engine
re-stamps on the matching transfer/device spans, so the trace shows which
pin fed which transfer (docs/OBSERVABILITY.md).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.telemetry.tracer import NULL_TRACER, Tracer

# one staged entry: a host array, or parts packed into one slot
Entry = Union[np.ndarray, Tuple[np.ndarray, ...]]

_ALIGN = 64      # part offsets inside a slot (keeps typed views aligned)


def entry_parts(entry: Entry) -> Tuple[np.ndarray, ...]:
    return tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)


def entry_wire_bytes(entry: Entry) -> int:
    """Bytes this entry moves over pin/DMA — the sum of its parts."""
    return sum(p.nbytes for p in entry_parts(entry))


def entry_slot_bytes(entry: Entry) -> int:
    """Staging bytes the entry occupies (parts padded to alignment)."""
    off = 0
    for p in entry_parts(entry):
        off = -(-off // _ALIGN) * _ALIGN + p.nbytes
    return off


@dataclasses.dataclass
class PinSlot:
    buffer: np.ndarray                    # preallocated staging memory
    name: Optional[str] = None            # module currently staged
    ready: Optional[Future] = None        # resolves when staging completes
    in_use: bool = False                  # acquired and not yet released
    seq: int = -1                         # per-module pin sequence number


class GroupRing:
    """Two-slot staging ring for one size group."""

    def __init__(self, group: str, slot_bytes: int):
        self.group = group
        self.slot_bytes = slot_bytes
        self.slots = [PinSlot(np.empty(slot_bytes, dtype=np.uint8))
                      for _ in range(2)]
        self.lock = threading.Condition()

    def slot_for(self, name: str) -> Optional[PinSlot]:
        for s in self.slots:
            if s.name == name:
                return s
        return None

    def free_slot(self) -> Optional[PinSlot]:
        for s in self.slots:
            if not s.in_use and s.ready is None:
                return s
        return None


class AsyncParamManager:
    """Stages module weights into pinned rings ahead of use.

    Typical engine driving pattern (paper Fig. 6)::

        mgr.prefetch(first_module_of_each_group)
        for module in plan:
            mgr.prefetch(next_same_group_module(module))   # stage ahead
            buf = mgr.acquire(module)                      # wait if needed
            ... transfer buf, compute ...
            mgr.release(module)
    """

    def __init__(self, weights: Dict[str, Entry],
                 groups: Dict[str, str], *,
                 tracer: Tracer = NULL_TRACER,
                 trace_phase: Optional[str] = None,
                 fp_bytes: Optional[Dict[str, int]] = None):
        """``weights``: host arrays (or part tuples) per module;
        ``groups``: module -> group.  ``fp_bytes`` optionally maps a
        module to the uncompressed byte count its entry represents —
        stamped on pin spans so trace consumers can relate wire traffic
        back to compute bytes."""
        self.weights = weights
        self.groups = groups
        self.tracer = tracer
        self.trace_phase = trace_phase
        self.fp_bytes = fp_bytes or {}
        by_group: Dict[str, List[str]] = {}
        for name, g in groups.items():
            by_group.setdefault(g, []).append(name)
        self.rings: Dict[str, GroupRing] = {}
        for g, names in by_group.items():
            slot_bytes = max(entry_slot_bytes(weights[n]) for n in names)
            self.rings[g] = GroupRing(g, slot_bytes)
        self._seq: Dict[str, int] = {}    # per-module pin counter
        self._pinner = ThreadPoolExecutor(max_workers=1,
                                          thread_name_prefix="pin")
        self.events: List[tuple] = []     # (op, module, t) for tests/metrics
        self._events_lock = threading.Lock()
        # accumulated by the pin thread, read/reset by the engine thread —
        # guarded the same way HeteGenEngine.stats is
        self._pin_lock = threading.Lock()
        self._pin_seconds = 0.0

    # ------------------------------------------------------------------
    def _log(self, op: str, name: str) -> None:
        with self._events_lock:
            self.events.append((op, name, time.perf_counter()))

    def _do_pin(self, slot: PinSlot, name: str, seq: int) -> Entry:
        src = self.weights[name]
        parts = entry_parts(src)
        attrs = dict(bytes=entry_wire_bytes(src), module=name,
                     phase=self.trace_phase, seq=seq)
        fp = self.fp_bytes.get(name)
        if fp is not None:
            attrs["fp_bytes"] = int(fp)
        with self.tracer.span(name, track="pin", **attrs):
            t0 = time.perf_counter()
            views: List[np.ndarray] = []
            off = 0
            for p in parts:
                off = -(-off // _ALIGN) * _ALIGN
                flat = p.reshape(-1).view(np.uint8)
                dst = slot.buffer[off: off + flat.nbytes]
                np.copyto(dst, flat)
                views.append(dst.view(p.dtype).reshape(p.shape))
                off += flat.nbytes
            dt = time.perf_counter() - t0
            with self._pin_lock:
                self._pin_seconds += dt
        self._log("pinned", name)
        return tuple(views) if isinstance(src, (tuple, list)) else views[0]

    def _submit_pin(self, slot: PinSlot, name: str) -> None:
        """Assign the next per-module seq and start the staging copy.
        Caller must hold the ring lock."""
        seq = self._seq.get(name, -1) + 1
        self._seq[name] = seq
        slot.name = name
        slot.seq = seq
        slot.ready = self._pinner.submit(self._do_pin, slot, name, seq)

    def seq_of(self, name: str) -> Optional[int]:
        """Pin sequence number of the currently staged copy of ``name``
        (None when nothing is staged) — the link attribute the engine
        stamps on the transfer/device spans this pin feeds."""
        ring = self.rings[self.groups[name]]
        with ring.lock:
            slot = ring.slot_for(name)
            return None if slot is None else slot.seq

    @property
    def pin_seconds(self) -> float:
        with self._pin_lock:
            return self._pin_seconds

    def reset_pin_seconds(self) -> None:
        with self._pin_lock:
            self._pin_seconds = 0.0

    # ------------------------------------------------------------------
    def prefetch(self, name: Optional[str]) -> bool:
        """Begin staging ``name`` if a slot is free.  Non-blocking.

        Returns True if staging was started (or already staged/running).
        """
        if name is None:
            return False
        ring = self.rings[self.groups[name]]
        with ring.lock:
            if ring.slot_for(name) is not None:
                return True
            slot = ring.free_slot()
            if slot is None:
                return False          # ring full: caller retries after release
            self._submit_pin(slot, name)
            self._log("pin_start", name)
            return True

    def acquire(self, name: str) -> Entry:
        """Return the staged weights for ``name``.

        Pins synchronously if the prefetch never happened (the non-async
        ablation path).  If the ring is clogged by prefetched-but-unconsumed
        entries (out-of-order access), the least-relevant staged slot is
        evicted — ``acquire`` always makes progress unless both slots are
        simultaneously *in use*, which the engine's prompt ``release`` rules
        out.
        """
        ring = self.rings[self.groups[name]]
        with ring.lock:
            slot = ring.slot_for(name)
            if slot is None:
                slot = ring.free_slot()
                if slot is None:
                    # evict a staged, not-in-use slot
                    deadline = time.monotonic() + 30.0
                    while slot is None:
                        for s in ring.slots:
                            if not s.in_use and s.name != name:
                                slot = s
                                break
                        if slot is None:
                            if not ring.lock.wait(timeout=0.5) and \
                                    time.monotonic() > deadline:
                                raise RuntimeError(
                                    f"pin ring wedged acquiring {name!r}: "
                                    f"both slots in use")
                    if slot.ready is not None:
                        slot.ready.result()   # drain in-flight pin first
                        self._log("evicted", slot.name or "?")
                self._submit_pin(slot, name)
                self._log("pin_start_sync", name)
            slot.in_use = True
        arr = slot.ready.result()
        self._log("acquired", name)
        return arr

    def release(self, name: str) -> None:
        """Mark ``name``'s slot reusable (its transfer has consumed it)."""
        ring = self.rings[self.groups[name]]
        with ring.lock:
            slot = ring.slot_for(name)
            if slot is not None:
                slot.name = None
                slot.ready = None
                slot.in_use = False
                ring.lock.notify_all()
        self._log("released", name)

    # ------------------------------------------------------------------
    def pinned_overhead_bytes(self) -> int:
        """Total staging memory — paper bound: <= 2 slots per group."""
        return sum(2 * r.slot_bytes for r in self.rings.values())

    def shutdown(self) -> None:
        self._pinner.shutdown(wait=True)


def plan_prefetch_order(plan: Sequence[str], groups: Dict[str, str]
                        ) -> Dict[str, Optional[str]]:
    """next-same-group module for each module, wrapping to the next step.

    Implements Fig. 6: "the preceding heterogeneous module prepares the
    pinned weights for the subsequent parameters ... if it is the last
    module within a layer, it proceeds to the first parameter in the
    following layer" (and the last module of the step wraps to the first of
    the next step).
    """
    nxt: Dict[str, Optional[str]] = {}
    by_group: Dict[str, List[str]] = {}
    for name in plan:
        by_group.setdefault(groups[name], []).append(name)
    for g, names in by_group.items():
        for i, name in enumerate(names):
            nxt[name] = names[(i + 1) % len(names)] if len(names) > 1 else None
    return nxt
