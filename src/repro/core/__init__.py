"""HeteGen core — the paper's contribution as composable JAX/host modules.

Public surface:

    alpha            — the computation-distribution law (Eq. 4-9)
    alpha_benchmark  — measurement-refined alpha (Eq. 10-12)
    module_scheduler — gain-ranked residency promotion (Eq. 13)
    param_manager    — asynchronous pinned-ring staging (§4.3)
    engine           — threaded hybrid heterogeneous runtime (§4.2)
    policy           — scheduler stage gluing the above (Fig. 4)
    sim              — discrete-event performance model (Figs. 5/8, Tables 2/3)
    hw               — hardware constants (paper's A10 rig; TPU v5e target)
"""

from repro.core.alpha import (  # noqa: F401
    AlphaDecision,
    alpha_analytic,
    alpha_approx,
    alpha_from_times,
    alpha_hybrid,
    decide,
    quantize_alpha,
    split_columns,
)
from repro.core.engine import HeteGenEngine, ModulePlan, StreamStats  # noqa: F401
from repro.core.hw import HARDWARE, PAPER_A10, TPU_V5E, HardwareSpec  # noqa: F401
from repro.core.module_scheduler import ModuleInfo, SchedulePlan, schedule  # noqa: F401
from repro.core.param_manager import AsyncParamManager  # noqa: F401
from repro.core.policy import LinearSpec, PolicyResult, build_policy  # noqa: F401
