"""Scheduler stage — turn a model's linear inventory into a placement plan.

Implements the paper's Fig. 4 scheduling pipeline:

    alpha benchmark  ->  per-module alpha        (§4.4, Eq. 9-12)
    value function   ->  residency promotion     (§4.5, Eq. 13)
    plan             ->  ModulePlan list for the runtime engine

The same planner feeds both the real threaded engine
(:mod:`repro.core.engine`) and the simulator (:mod:`repro.core.sim`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core import alpha as alpha_lib
from repro.core.engine import ModulePlan
from repro.core.hw import HardwareSpec
from repro.core.module_scheduler import ModuleInfo, SchedulePlan, schedule


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    """Static description of one linear module in a model."""

    name: str
    n_in: int
    n_out: int
    group: str                  # "attn" | "mlp" | ... (pin-ring size group)
    dtype_bytes: int = 4
    calls: int = 1              # invocations per decode step (shared blocks)

    @property
    def nbytes(self) -> int:
        return self.n_in * self.n_out * self.dtype_bytes


@dataclasses.dataclass
class PolicyResult:
    plan: List[ModulePlan]
    alpha: float                       # resolved streaming alpha
    schedule: Optional[SchedulePlan]   # residency plan (None if no budget)
    predicted_step_time: float         # sum of per-module critical paths
    resident_bytes: int = 0            # accelerator bytes held by residents
    batch: int = 1                     # batch the plan was tuned for
    phase: str = "decode"              # "prefill" | "decode" (paper §4.1)
    tokens_per_seq: int = 1            # step tokens per sequence (prompt
    #                                    length for prefill, 1 for decode)

    @property
    def intensity(self) -> int:
        """FLOPs per parameter byte the plan was tuned for."""
        return self.batch * self.tokens_per_seq


def build_policy(
    linears: Sequence[LinearSpec],
    hw: HardwareSpec,
    *,
    budget_bytes: Optional[float] = None,
    batch: int = 1,
    phase: str = "decode",
    tokens_per_seq: Optional[int] = None,
    use_alpha_benchmark: bool = True,
    use_module_scheduler: bool = True,
    tile: int = 128,
) -> PolicyResult:
    """Resolve alpha + residency for a model's linears (paper Fig. 4).

    ``budget_bytes`` — accelerator memory available for weights (None means
    'only the streaming ring fits': fully offloaded operation).

    ``phase`` — the serving phase the plan targets (§4.1): decode steps run
    ~``batch`` FLOPs per weight byte (link/host bound, small alpha), while
    prefill runs ``batch * tokens_per_seq`` (compute bound, alpha -> 1).
    ``tokens_per_seq`` defaults to 1 for decode and
    :data:`repro.core.alpha.DEFAULT_PREFILL_TOKENS` for prefill.
    """
    tokens_per_seq = alpha_lib.resolve_phase_tokens(phase, tokens_per_seq)
    batch = max(batch, 1)
    intensity = batch * tokens_per_seq  # FLOPs per weight byte this phase
    v_cpu = hw.v_cpu(intensity)
    v_gpu = hw.v_gpu(intensity)
    v_com = hw.v_com()
    v_pin = hw.v_pin()

    # == alpha_lib.alpha_for_batch(hw, batch), on the speeds computed above
    a0 = alpha_lib.alpha_analytic(v_cpu, v_gpu, v_com)
    a = a0
    if use_alpha_benchmark:
        from repro.core.alpha_benchmark import refine_alpha

        probe = max(linears, key=lambda s: s.nbytes)

        def t_cpu_fn(x: float) -> float:
            return (1.0 - x) * probe.nbytes / v_cpu

        def t_com_fn(x: float) -> float:
            dev = x * probe.nbytes
            return max(dev / v_pin, dev / v_com)

        a = refine_alpha(t_cpu_fn, t_com_fn, a0).alpha

    # Residency promotion (Eq. 13).
    plan_map: Dict[str, str] = {s.name: "hetegen" for s in linears}
    sched = None
    if use_module_scheduler and budget_bytes is not None:
        infos = [ModuleInfo(name=s.name, mem_bytes=s.nbytes,
                            t_cpu=(1.0 - a) * s.nbytes / v_cpu,
                            calls=s.calls) for s in linears]
        ring = 2 * max((alpha_lib.quantize_alpha(a, s.n_out, tile) * s.nbytes
                        for s in linears), default=0.0)
        sched = schedule(infos, max(0.0, (budget_bytes or 0.0) - ring))
        for name in sched.resident:
            plan_map[name] = "resident"

    plan: List[ModulePlan] = []
    t_pred = 0.0
    resident_bytes = 0
    for s in linears:
        mode = plan_map[s.name]
        if mode == "resident":
            plan.append(ModulePlan(s.name, s.group, "resident"))
            t_pred += s.calls * s.nbytes / hw.accel_mem_bw
            resident_bytes += s.nbytes
        else:
            aq = alpha_lib.quantize_alpha(a, s.n_out, tile)
            plan.append(ModulePlan(s.name, s.group, "hetegen", aq))
            t_cpu = (1.0 - aq) * s.nbytes / v_cpu
            t_com = max(aq * s.nbytes / v_com, aq * s.nbytes / v_pin)
            t_pred += s.calls * max(t_cpu, t_com)
    return PolicyResult(plan=plan, alpha=a, schedule=sched,
                        predicted_step_time=t_pred,
                        resident_bytes=resident_bytes,
                        batch=batch, phase=phase,
                        tokens_per_seq=tokens_per_seq)
