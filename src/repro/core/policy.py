"""Scheduler stage — turn a model's linear inventory into a placement plan.

Implements the paper's Fig. 4 scheduling pipeline:

    alpha benchmark  ->  per-module alpha        (§4.4, Eq. 9-12)
    value function   ->  residency promotion     (§4.5, Eq. 13)
    plan             ->  ModulePlan list for the runtime engine

The same planner feeds both the real threaded engine
(:mod:`repro.core.engine`) and the simulator (:mod:`repro.core.sim`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core import alpha as alpha_lib
from repro.core.engine import ModulePlan
from repro.core.hw import HardwareSpec
from repro.core.module_scheduler import ModuleInfo, SchedulePlan, schedule


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    """Static description of one linear module in a model."""

    name: str
    n_in: int
    n_out: int
    group: str                  # "attn" | "mlp" | ... (pin-ring size group)
    dtype_bytes: int = 4
    calls: int = 1              # invocations per decode step (shared blocks)
    wire: str = "fp"            # streamed format: "fp" | "q8" (int8+scales)

    @property
    def nbytes(self) -> int:
        """Compute bytes: what the host GEMM and device matmul touch."""
        return self.n_in * self.n_out * self.dtype_bytes

    @property
    def wire_bytes(self) -> int:
        """Bytes that actually cross pin/DMA per full stream of the module.
        Distinct from :attr:`nbytes` when the wire format compresses —
        q8 moves an int8 payload plus one fp32 scale per output column."""
        if self.wire == "q8":
            return self.n_in * self.n_out + 4 * self.n_out
        return self.nbytes


@dataclasses.dataclass
class PolicyResult:
    plan: List[ModulePlan]
    alpha: float                       # resolved streaming alpha
    schedule: Optional[SchedulePlan]   # residency plan (None if no budget)
    predicted_step_time: float         # sum of per-module critical paths
    resident_bytes: int = 0            # accelerator bytes held by residents
    batch: int = 1                     # batch the plan was tuned for
    phase: str = "decode"              # "prefill" | "decode" (paper §4.1)
    tokens_per_seq: int = 1            # step tokens per sequence (prompt
    #                                    length for prefill, 1 for decode)
    wstream: str = "fp"                # wire format the plan was priced for

    @property
    def intensity(self) -> int:
        """FLOPs per parameter byte the plan was tuned for."""
        return self.batch * self.tokens_per_seq


def build_policy(
    linears: Sequence[LinearSpec],
    hw: HardwareSpec,
    *,
    budget_bytes: Optional[float] = None,
    batch: int = 1,
    phase: str = "decode",
    tokens_per_seq: Optional[int] = None,
    use_alpha_benchmark: bool = True,
    use_module_scheduler: bool = True,
    tile: int = 128,
) -> PolicyResult:
    """Resolve alpha + residency for a model's linears (paper Fig. 4).

    ``budget_bytes`` — accelerator memory available for weights (None means
    'only the streaming ring fits': fully offloaded operation).

    ``phase`` — the serving phase the plan targets (§4.1): decode steps run
    ~``batch`` FLOPs per weight byte (link/host bound, small alpha), while
    prefill runs ``batch * tokens_per_seq`` (compute bound, alpha -> 1).
    ``tokens_per_seq`` defaults to 1 for decode and
    :data:`repro.core.alpha.DEFAULT_PREFILL_TOKENS` for prefill.
    """
    tokens_per_seq = alpha_lib.resolve_phase_tokens(phase, tokens_per_seq)
    batch = max(batch, 1)
    intensity = batch * tokens_per_seq  # FLOPs per weight byte this phase
    v_cpu = hw.v_cpu(intensity)
    v_gpu = hw.v_gpu(intensity)
    v_com = hw.v_com()
    v_pin = hw.v_pin()

    # == alpha_lib.alpha_for_batch(hw, batch), on the speeds computed above,
    # with the link derated/boosted by the wire format: compressed streaming
    # moves wire_bytes per nbytes of compute, so the link looks 1/r faster
    # (docs/ANALYSIS.md) and the equilibrium shifts toward the device.
    probe = max(linears, key=lambda s: s.nbytes)
    wire_ratio = probe.wire_bytes / probe.nbytes
    a0 = alpha_lib.alpha_analytic(
        v_cpu, v_gpu, alpha_lib.effective_link_speed(v_com, wire_ratio))
    a = a0
    if use_alpha_benchmark:
        from repro.core.alpha_benchmark import refine_alpha

        def t_cpu_fn(x: float) -> float:
            # host share computes fp weights — compute bytes, not wire
            return (1.0 - x) * probe.nbytes / v_cpu

        def t_com_fn(x: float) -> float:
            # pin and DMA both move the wire format
            dev = x * probe.wire_bytes
            return max(dev / v_pin, dev / v_com)

        a = refine_alpha(t_cpu_fn, t_com_fn, a0).alpha

    # Residency promotion (Eq. 13).
    plan_map: Dict[str, str] = {s.name: "hetegen" for s in linears}
    sched = None
    if use_module_scheduler and budget_bytes is not None:
        infos = [ModuleInfo(name=s.name, mem_bytes=s.nbytes,
                            t_cpu=(1.0 - a) * s.nbytes / v_cpu,
                            calls=s.calls) for s in linears]
        # pin rings hold the wire format, so a compressed stream frees
        # budget for residency promotion
        ring = 2 * max((alpha_lib.quantize_alpha(a, s.n_out, tile)
                        * s.wire_bytes for s in linears), default=0.0)
        sched = schedule(infos, max(0.0, (budget_bytes or 0.0) - ring))
        for name in sched.resident:
            plan_map[name] = "resident"

    plan: List[ModulePlan] = []
    t_pred = 0.0
    resident_bytes = 0
    for s in linears:
        mode = plan_map[s.name]
        if mode == "resident":
            plan.append(ModulePlan(s.name, s.group, "resident"))
            t_pred += s.calls * s.nbytes / hw.accel_mem_bw
            resident_bytes += s.nbytes
        else:
            aq = alpha_lib.quantize_alpha(a, s.n_out, tile)
            plan.append(ModulePlan(s.name, s.group, "hetegen", aq))
            t_cpu = (1.0 - aq) * s.nbytes / v_cpu
            t_com = max(aq * s.wire_bytes / v_com,
                        aq * s.wire_bytes / v_pin)
            t_pred += s.calls * max(t_cpu, t_com)
    wstreams = {s.wire for s in linears}
    return PolicyResult(plan=plan, alpha=a, schedule=sched,
                        predicted_step_time=t_pred,
                        resident_bytes=resident_bytes,
                        batch=batch, phase=phase,
                        tokens_per_seq=tokens_per_seq,
                        wstream=("q8" if wstreams == {"q8"} else "fp"))
