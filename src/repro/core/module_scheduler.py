"""Heterogeneous module scheduler (paper §4.5, Eq. 13).

When accelerator memory is not exhausted by the minimal streaming buffers,
whole modules are promoted to *resident* accelerator memory, removing their
host-compute and link cost entirely.  The paper ranks candidates by the gain

    g = T̄_cpu / Mem        (time saved per byte of accelerator memory)

and promotes greedily until the memory budget is reached.  Modules invoked
multiple times per step (e.g. zamba2's shared attention block) save
``calls * T̄_cpu``, which the gain reflects — reuse makes residency more
valuable (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence


@dataclasses.dataclass(frozen=True)
class ModuleInfo:
    name: str
    mem_bytes: float            # accelerator bytes if promoted
    t_cpu: float                # benchmarked host time per invocation (T̄_cpu)
    calls: int = 1              # invocations per step

    @property
    def gain(self) -> float:
        """Paper Eq. 13 (scaled by per-step reuse)."""
        if self.mem_bytes <= 0:
            return float("inf")
        return (self.t_cpu * self.calls) / self.mem_bytes


@dataclasses.dataclass
class SchedulePlan:
    resident: List[str]
    offloaded: List[str]
    used_bytes: float
    budget_bytes: float
    time_saved: float

    @property
    def resident_fraction(self) -> float:
        total = self.used_bytes + sum(0 for _ in ())  # placeholder for mypy
        return 0.0 if self.budget_bytes <= 0 else self.used_bytes / self.budget_bytes


def schedule(modules: Sequence[ModuleInfo], budget_bytes: float
             ) -> SchedulePlan:
    """Greedy promotion by descending gain g until the budget is exhausted.

    Deterministic: ties broken by (name) for reproducibility.  A module is
    skipped (not promoted) if it alone exceeds the remaining budget; later,
    smaller modules may still fit — this matches the paper's per-layer
    migration loop and gives the wide dynamic range of Fig. 8.
    """
    ranked = sorted(modules, key=lambda m: (-m.gain, m.name))
    resident: List[str] = []
    offloaded: List[str] = []
    used = 0.0
    saved = 0.0
    for m in ranked:
        if m.mem_bytes <= budget_bytes - used:
            resident.append(m.name)
            used += m.mem_bytes
            saved += m.t_cpu * m.calls
        else:
            offloaded.append(m.name)
    return SchedulePlan(resident=resident, offloaded=offloaded,
                        used_bytes=used, budget_bytes=budget_bytes,
                        time_saved=saved)


def dynamic_range(modules: Sequence[ModuleInfo], *, overhead_bytes: float,
                  total_bytes: float | None = None) -> Dict[str, float]:
    """Min/max accelerator-memory operating points (cf. paper §5.1).

    min — nothing resident, only streaming buffers + non-linear modules
          (``overhead_bytes``);
    max — everything resident.
    Returned as fractions of ``total_bytes`` (defaults to sum of modules +
    overhead), comparable to the paper's '6.5% .. 88.7%' span for OPT-30B.
    """
    weights = sum(m.mem_bytes for m in modules)
    total = total_bytes if total_bytes is not None else weights + overhead_bytes
    return {
        "min_fraction": overhead_bytes / total,
        "max_fraction": (weights + overhead_bytes) / total,
    }
