"""HeteGen runtime engine — threaded hybrid heterogeneous parallelism (§4.2).

Executes the linear modules of a model under a per-module placement plan:

    resident  — weights live in accelerator memory; plain device matmul.
    hetegen   — weights live in host memory; the output dimension is split
                at an MXU-tile-aligned column ``alpha``-fraction: the device
                part is staged (pin) || transferred (DMA) || the host part is
                computed by a host GEMM thread, all concurrently; results are
                concatenated (exact — column blocks of a matmul are
                independent).
    stream    — alpha = 1: pure weight streaming (FlexGen-style baseline).
    host      — alpha = 0: pure host compute (CPU-only baseline).

``wstream`` picks the wire format of the streamed device shards:

    "fp"      — stream the shard as-is (full precision).
    "q8"      — quantize each shard once at load to int8 + fp32 per-column
                scales (:func:`repro.kernels.q8_matmul.quantize_weights_np`)
                and stream the ``(q, scale)`` pair; the device share runs
                through :func:`repro.kernels.ops.q8_matmul`, dequantizing
                inside the matmul, so no fp copy of a streamed weight ever
                exists in device memory.  The host partition keeps its fp
                weights (it never crosses the link).  Pin/transfer spans
                carry the wire bytes (plus ``fp_bytes``, the uncompressed
                equivalent) so telemetry stays honest under compression.

Four real executors provide the four streams of the paper's Fig. 5c: the
host GEMM pool, the manager's pin thread, the transfer thread, and the
device queue (JAX async dispatch).  On this CPU-only container the "device"
is jax's CpuDevice, so wall-clock overlap is bounded by the single core, but
the *mechanism* — ordering, ring reuse, prefetch, correctness — is identical
to the TPU deployment, and per-stream busy seconds are measured for the
Table-2 style breakdown.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alpha as alpha_lib
from repro.core.param_manager import (AsyncParamManager, Entry,
                                      plan_prefetch_order)
from repro.kernels import ops as kernel_ops
from repro.kernels.q8_matmul import quantize_weights_np
from repro.telemetry.tracer import NULL_TRACER, Tracer


@dataclasses.dataclass(frozen=True)
class ModulePlan:
    name: str
    group: str                 # size group for the pinned ring ("attn"/"mlp")
    mode: str                  # "resident" | "hetegen" | "stream" | "host"
    alpha: float = 1.0         # device fraction for hetegen


@dataclasses.dataclass
class StreamStats:
    cpu: float = 0.0           # host GEMM seconds
    pin: float = 0.0           # staging seconds
    trans: float = 0.0         # host->device transfer seconds
    dev: float = 0.0           # device matmul seconds
    wall: float = 0.0          # end-to-end engine-active seconds

    def utilization(self) -> Dict[str, float]:
        w = max(self.wall, 1e-12)
        return {"cpu": self.cpu / w, "pin": self.pin / w,
                "trans": self.trans / w, "dev": self.dev / w}

    def __add__(self, other: "StreamStats") -> "StreamStats":
        """Aggregate busy seconds across engines (e.g. the per-phase
        partitions of a phase-aware backend).  Wall takes the max: the
        engines share one serving timeline, they don't extend it."""
        return StreamStats(cpu=self.cpu + other.cpu,
                           pin=self.pin + other.pin,
                           trans=self.trans + other.trans,
                           dev=self.dev + other.dev,
                           wall=max(self.wall, other.wall))


class HeteGenEngine:
    """Executes named linears under a placement plan with async overlap."""

    def __init__(self, weights: Dict[str, np.ndarray],
                 plan: Sequence[ModulePlan], *,
                 biases: Optional[Dict[str, np.ndarray]] = None,
                 tile: int = 128,
                 device: Optional[jax.Device] = None,
                 resident_store: Optional[Dict[str, jax.Array]] = None,
                 tracer: Tracer = NULL_TRACER,
                 trace_phase: Optional[str] = None,
                 wstream: str = "fp"):
        if wstream not in ("fp", "q8"):
            raise ValueError(f"unknown wire format {wstream!r} "
                             "(expected 'fp' or 'q8')")
        self.plan = {p.name: p for p in plan}
        self.order = [p.name for p in plan]
        self.tile = tile
        self.device = device or jax.devices()[0]
        self.biases = {k: jnp.asarray(v) for k, v in (biases or {}).items()}
        self.stats = StreamStats()
        self._lock = threading.Lock()
        self.tracer = tracer
        self.trace_phase = trace_phase
        self.wstream = wstream

        # Partition every weight once, ahead of time.  ``resident_store``
        # lets a phase-aware backend run several engines (one partition per
        # serving phase) without holding duplicate device copies of the
        # modules both plans promote to residency.
        self._resident: Dict[str, jax.Array] = {}
        self._host_part: Dict[str, np.ndarray] = {}
        self._dev_cols: Dict[str, int] = {}
        self._fp_shard_bytes: Dict[str, int] = {}   # uncompressed shard size
        stage_src: Dict[str, Entry] = {}
        groups: Dict[str, str] = {}
        for p in plan:
            w = weights[p.name]
            if p.mode == "resident":
                if resident_store is not None and p.name in resident_store:
                    self._resident[p.name] = resident_store[p.name]
                else:
                    self._resident[p.name] = jax.device_put(w, self.device)
                    if resident_store is not None:
                        resident_store[p.name] = self._resident[p.name]
                continue
            if p.mode == "host":
                self._host_part[p.name] = w
                self._dev_cols[p.name] = 0
                continue
            a = 1.0 if p.mode == "stream" else p.alpha
            cols = alpha_lib.split_columns(a, w.shape[-1], tile)
            self._dev_cols[p.name] = cols
            if cols > 0:
                # contiguous copy so staging is a single memcpy
                shard = np.ascontiguousarray(w[..., :cols])
                self._fp_shard_bytes[p.name] = shard.nbytes
                if wstream == "q8" and shard.ndim == 2:
                    # one-time load cost: the shard streams as int8
                    # payload + fp32 per-column scales from here on
                    stage_src[p.name] = quantize_weights_np(shard)
                else:
                    stage_src[p.name] = shard
                groups[p.name] = p.group
            if cols < w.shape[-1]:
                self._host_part[p.name] = np.ascontiguousarray(w[..., cols:])

        self.manager = (AsyncParamManager(stage_src, groups,
                                          tracer=tracer,
                                          trace_phase=trace_phase,
                                          fp_bytes=self._fp_shard_bytes)
                        if stage_src else None)
        self._next_in_group = plan_prefetch_order(
            [n for n in self.order if n in stage_src], groups)

        self._cpu_pool = ThreadPoolExecutor(max_workers=1,
                                            thread_name_prefix="hostgemm")
        self._trans_pool = ThreadPoolExecutor(max_workers=1,
                                              thread_name_prefix="transfer")

        self._matmul = jax.jit(lambda x, w: x @ w)

        def _q8_matmul(x, q, s):
            # prefill activations are (B, S, K); the kernel wants 2D
            y = kernel_ops.q8_matmul(x.reshape((-1, x.shape[-1])), q, s)
            return y.reshape(x.shape[:-1] + (q.shape[-1],))

        self._q8_matmul = jax.jit(_q8_matmul)
        self._t_start = time.perf_counter()

    # ------------------------------------------------------------------
    def warm_prefetch(self) -> None:
        """Stage the first module of each group before the step begins."""
        if self.manager is None:
            return
        seen = set()
        for name in self.order:
            p = self.plan[name]
            if name in self._dev_cols and self._dev_cols[name] > 0 \
                    and p.mode in ("hetegen", "stream"):
                if p.group not in seen:
                    self.manager.prefetch(name)
                    seen.add(p.group)

    def _host_matmul(self, x_np: np.ndarray, name: str) -> np.ndarray:
        w = self._host_part[name]
        with self.tracer.span(name, track="cpu_gemm", bytes=w.nbytes,
                              module=name, phase=self.trace_phase):
            t0 = time.perf_counter()
            y = x_np @ w
            with self._lock:
                self.stats.cpu += time.perf_counter() - t0
        return y

    def _transfer(self, buf: Entry, name: str,
                  seq: Optional[int]) -> Entry:
        parts = buf if isinstance(buf, tuple) else (buf,)
        wire = sum(p.nbytes for p in parts)
        attrs = dict(bytes=wire, module=name, phase=self.trace_phase)
        if seq is not None:
            attrs["seq"] = seq
        fp = self._fp_shard_bytes.get(name)
        if fp is not None:
            attrs["fp_bytes"] = fp
        with self.tracer.span(name, track="transfer", **attrs):
            t0 = time.perf_counter()
            arrs = tuple(jax.device_put(p, self.device) for p in parts)
            for a in arrs:
                # lint: allow[hot-path-sync] transfer-stream timing: the sync
                # is the measurement (trans busy-seconds feed the alpha law),
                # and it runs on the dedicated transfer thread, not the
                # dispatch thread
                a.block_until_ready()
            with self._lock:
                self.stats.trans += time.perf_counter() - t0
        return arrs if isinstance(buf, tuple) else arrs[0]

    # ------------------------------------------------------------------
    def linear(self, x: jax.Array, name: str) -> jax.Array:
        """y = x @ W[name] (+ bias), executed per the placement plan."""
        p = self.plan[name]
        if p.mode == "resident":
            with self.tracer.span(name, track="device", module=name,
                                  phase=self.trace_phase):
                t0 = time.perf_counter()
                y = self._matmul(x, self._resident[name])
                # lint: allow[hot-path-sync] device-stream timing: dev
                # busy-seconds are the alpha controller's input signal
                y.block_until_ready()
                with self._lock:
                    self.stats.dev += time.perf_counter() - t0
        else:
            cols = self._dev_cols[name]
            has_host = name in self._host_part

            # 1. stage-ahead: kick the pin of the next same-group module
            if self.manager is not None and cols > 0:
                nxt = self._next_in_group.get(name)
                if nxt is not None:
                    self.manager.prefetch(nxt)

            # 2. host share on the GEMM thread (x moves device->host first,
            #    as in the paper: "transmitting activation from the GPU")
            host_fut = None
            if has_host:
                # lint: allow[hot-path-sync] the paper's §4.2 activation
                # move: the host GEMM share needs x on the CPU, and this
                # transfer is what the alpha split already budgets for
                x_np = np.asarray(x)
                host_fut = self._cpu_pool.submit(self._host_matmul, x_np, name)

            # 3. device share: acquire pinned buffer, DMA, matmul.  The slot
            # is released only after the device matmul finished: on a real
            # TPU the DMA copy would suffice, but jax's CPU backend
            # zero-copies device_put, so the device read must complete
            # before the slot can be re-staged.
            y_dev = None
            if cols > 0:
                buf = self.manager.acquire(name)
                seq = self.manager.seq_of(name)
                w_fut = self._trans_pool.submit(self._transfer, buf, name,
                                                seq)
                w_dev = w_fut.result()
                with self.tracer.span(name, track="device", module=name,
                                      phase=self.trace_phase, seq=seq):
                    t0 = time.perf_counter()
                    y_dev = (self._q8_matmul(x, *w_dev)
                             if isinstance(w_dev, tuple)
                             else self._matmul(x, w_dev))
                    # lint: allow[hot-path-sync] ring-slot release ordering:
                    # jax's CPU backend zero-copies device_put, so the read
                    # must finish before the slot is re-staged (see above)
                    y_dev.block_until_ready()
                    with self._lock:
                        self.stats.dev += time.perf_counter() - t0
                self.manager.release(name)

            # 4. combine
            if y_dev is None:
                y = jnp.asarray(host_fut.result())
            elif host_fut is None:
                y = y_dev
            else:
                y_host = jnp.asarray(host_fut.result())
                y = jnp.concatenate([y_dev, y_host], axis=-1)

        if name in self.biases:
            y = y + self.biases[name]
        return y

    # ------------------------------------------------------------------
    def set_tracer(self, tracer: Tracer,
                   trace_phase: Optional[str] = None) -> None:
        """Swap the tracer (and phase label) on a live engine — used when
        tracing is enabled after the engine was built."""
        self.tracer = tracer
        if trace_phase is not None:
            self.trace_phase = trace_phase
        if self.manager is not None:
            self.manager.tracer = tracer
            self.manager.trace_phase = self.trace_phase

    def finish_stats(self) -> StreamStats:
        with self._lock:
            self.stats.wall = time.perf_counter() - self._t_start
            if self.manager is not None:
                self.stats.pin = self.manager.pin_seconds
            return self.stats

    def reset_stats(self) -> None:
        with self._lock:
            self.stats = StreamStats()
            self._t_start = time.perf_counter()
        if self.manager is not None:
            self.manager.reset_pin_seconds()

    def device_resident_bytes(self) -> int:
        return sum(int(np.prod(w.shape)) * w.dtype.itemsize
                   for w in self._resident.values())

    def pinned_overhead_bytes(self) -> int:
        return 0 if self.manager is None else self.manager.pinned_overhead_bytes()

    def close(self) -> None:
        self._cpu_pool.shutdown(wait=True)
        self._trans_pool.shutdown(wait=True)
        if self.manager is not None:
            self.manager.shutdown()
