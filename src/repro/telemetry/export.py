"""Chrome/Perfetto trace export + schema validation.

The tracer's spans serialize to the Chrome Trace Event Format (the JSON
``chrome://tracing`` / Perfetto's legacy importer reads): complete
events (``ph: "X"``) with microsecond timestamps relative to the trace
origin, one ``tid`` per logical track, and ``thread_name`` metadata so
the UI labels rows ``pin`` / ``transfer`` / ``cpu_gemm`` / ``device``
instead of thread ids.  Instant events become ``ph: "i"``.

:func:`validate_chrome_trace` is the CI gate (tools/ci.sh): it checks
the structural schema *and* the two physical invariants our tracks
promise — timestamps are monotone non-negative, and spans on one track
never overlap (each stream is serial: single-worker pools in the
engine, the driver thread for step/phase tracks).
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence

from repro.telemetry.tracer import Event, Span, Tracer

_PID = 0


def _track_ids(names: Sequence[str]) -> Dict[str, int]:
    # stable order: first-seen, so step/phase tracks land on low tids
    ids: Dict[str, int] = {}
    for n in names:
        if n not in ids:
            ids[n] = len(ids)
    return ids


def to_chrome_trace(spans: Sequence[Span],
                    events: Sequence[Event] = (),
                    *, t_origin: Optional[float] = None) -> Dict[str, Any]:
    """Build the Chrome Trace Event JSON object (not yet serialized)."""
    if t_origin is None:
        starts = [s.t0 for s in spans] + [e.t for e in events]
        t_origin = min(starts) if starts else 0.0
    tids = _track_ids([s.track for s in spans] + [e.track for e in events])

    trace_events: List[Dict[str, Any]] = []
    for track, tid in tids.items():
        trace_events.append({
            "ph": "M", "pid": _PID, "tid": tid,
            "name": "thread_name", "args": {"name": track}})
    for s in spans:
        ev: Dict[str, Any] = {
            "ph": "X", "pid": _PID, "tid": tids[s.track], "name": s.name,
            "ts": (s.t0 - t_origin) * 1e6, "dur": s.dur * 1e6,
            "cat": s.track}
        if s.attrs:
            ev["args"] = dict(s.attrs)
        trace_events.append(ev)
    for e in events:
        ev = {"ph": "i", "pid": _PID, "tid": tids[e.track], "name": e.name,
              "ts": (e.t - t_origin) * 1e6, "s": "t", "cat": e.track}
        if e.attrs:
            ev["args"] = dict(e.attrs)
        trace_events.append(ev)
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, tracer: Tracer) -> Dict[str, Any]:
    """Dump a tracer's full buffer to ``path`` as Chrome trace JSON."""
    doc = to_chrome_trace(tracer.spans(), tracer.events_list(),
                          t_origin=tracer.t_origin)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def validate_chrome_trace(doc: Dict[str, Any]) -> List[str]:
    """Schema + invariant check; returns a list of problems (empty ==
    valid).  Checked: required keys per event kind, non-negative
    monotone timestamps, non-negative durations, and **no overlapping
    spans within one (pid, tid) track** — the serial-stream guarantee
    the overlap math relies on."""
    problems: List[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]

    by_track: Dict[Any, List[Any]] = {}
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        if "pid" not in ev or "tid" not in ev or "name" not in ev:
            problems.append(f"event {i}: missing pid/tid/name")
            continue
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: bad dur {dur!r}")
                continue
            by_track.setdefault((ev["pid"], ev["tid"]), []).append(
                (ts, ts + dur, ev["name"]))

    # per-track: spans sorted by start must not overlap.  Tolerance is
    # 1 ns — perf_counter deltas are exact doubles but serialization
    # may round.
    for key, spans in by_track.items():
        spans.sort()
        for (a0, a1, an), (b0, b1, bn) in zip(spans, spans[1:]):
            if b0 < a1 - 1e-3:  # µs units: 1e-3 µs = 1 ns slack
                problems.append(
                    f"track {key}: span {bn!r} (ts={b0:.3f}) overlaps "
                    f"{an!r} (ends {a1:.3f})")
    return problems
