"""Metrics registry — one snapshot interface over the serving counters.

PR 1-7 each grew an ad-hoc ``stats()`` dict (engine stream seconds,
scheduler counters, kv allocator gauges, speculative acceptance).  This
registry supersedes them behind one typed surface:

* :class:`Counter` — monotonically increasing totals (steps, tokens,
  preemptions).
* :class:`Gauge` — last-written point-in-time values (mapped pages,
  current alpha).
* :class:`Histogram` — fixed-bucket distributions (step latency).
  Buckets are cumulative-free plain counts per edge interval plus
  count/sum, so recording is O(#buckets) worst case and allocation-free.

Everything is host-side arithmetic — no device arrays, no syncs (the
``telemetry-no-sync`` lint rule walks these paths).  Thread safety is a
single registry lock taken per record; the serving hot path records a
handful of instruments per *step* (not per token or per linear), so the
lock is never contended enough to matter.

The legacy dicts stay readable during the deprecation window:
:meth:`MetricsRegistry.absorb` maps a nested ``stats()`` dict into
namespaced gauges/counters (``kv.free_pages``, ``scheduler.preemptions``,
``stream.cpu_s``, ...), and ``LLM.metrics()`` returns the merged
snapshot — tests assert key-for-key equivalence
(tests/test_telemetry.py).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

_DEFAULT_EDGES = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0)


class Counter:
    """A monotonically increasing total."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, by: float = 1.0) -> None:
        if by < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self.value += by


class Gauge:
    """A point-in-time value (last write wins)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Fixed-bucket distribution: counts per ``(edge[i-1], edge[i]]``
    interval plus an overflow bucket, with running count/sum/min/max."""

    __slots__ = ("name", "edges", "buckets", "count", "sum", "min", "max")

    def __init__(self, name: str,
                 edges: Sequence[float] = _DEFAULT_EDGES):
        self.name = name
        self.edges = tuple(float(e) for e in edges)
        if list(self.edges) != sorted(set(self.edges)):
            raise ValueError(f"histogram {name}: edges must be strictly "
                             f"increasing")
        self.buckets = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        value = float(value)
        i = 0
        for e in self.edges:
            if value <= e:
                break
            i += 1
        self.buckets[i] += 1
        self.count += 1
        self.sum += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "edges": list(self.edges),
                "buckets": list(self.buckets)}


class MetricsRegistry:
    """Named instruments behind one snapshot.

    ::

        m = MetricsRegistry()
        m.counter("serve.steps").inc()
        m.gauge("kv.free_pages").set(31)
        m.histogram("serve.step_s").observe(0.012)
        m.snapshot()  # {"serve.steps": 1.0, "kv.free_pages": 31.0,
                      #  "serve.step_s": {...}}

    Instrument creation is get-or-create by name; asking for an existing
    name with a different type raises.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, Any] = {}

    def _get(self, name: str, cls, *args):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, *args)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  edges: Sequence[float] = _DEFAULT_EDGES) -> Histogram:
        return self._get(name, Histogram, edges)

    # -- legacy-stats absorption ---------------------------------------
    def absorb(self, stats: Dict[str, Any], prefix: str = "") -> None:
        """Map a legacy nested ``stats()`` dict into namespaced gauges.

        Numeric leaves become gauges ``<prefix><path.to.leaf>``; nested
        dicts recurse with a dotted prefix; non-numeric leaves (policy
        names, executor labels) are skipped — they are identity, not
        measurement.  Idempotent per key: re-absorbing overwrites the
        gauge, matching point-in-time semantics.
        """
        for key, val in stats.items():
            name = f"{prefix}{key}"
            if isinstance(val, dict):
                self.absorb(val, prefix=f"{name}.")
            elif isinstance(val, bool):
                self.gauge(name).set(1.0 if val else 0.0)
            elif isinstance(val, (int, float)):
                self.gauge(name).set(float(val))
            elif hasattr(val, "cpu") and hasattr(val, "wall"):
                # a StreamStats-shaped object: busy seconds per stream
                self.absorb({"cpu_s": val.cpu, "pin_s": val.pin,
                             "trans_s": val.trans, "dev_s": val.dev,
                             "wall_s": val.wall}, prefix=f"{name}.")

    def snapshot(self) -> Dict[str, Any]:
        """One flat dict: counters/gauges as floats, histograms as
        dicts.  Safe to call from any thread."""
        with self._lock:
            out: Dict[str, Any] = {}
            for name, inst in sorted(self._instruments.items()):
                if isinstance(inst, Histogram):
                    out[name] = inst.as_dict()
                else:
                    out[name] = inst.value
            return out
