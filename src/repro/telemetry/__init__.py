"""repro.telemetry — zero-sync tracing, metrics, and trace-driven tuning.

The observability layer for the heterogeneous runtime (docs/OBSERVABILITY.md):

* :mod:`tracer` — ring-buffered spans/events on host ``perf_counter``,
  never touching a device array.
* :mod:`metrics` — counters/gauges/histograms superseding the ad-hoc
  ``stats()`` dicts behind one snapshot.
* :mod:`export` — Chrome/Perfetto ``trace.json`` writer + validator.
* :mod:`overlap` — per-step I/O-hidden fraction, stream utilization,
  critical-path breakdown (paper Fig. 5c, Table 2).
* :mod:`recalibrate` — measured stream speeds → ``refine_alpha``.
"""

from repro.telemetry.export import (to_chrome_trace, validate_chrome_trace,
                                    write_chrome_trace)
from repro.telemetry.metrics import (Counter, Gauge, Histogram,
                                     MetricsRegistry)
from repro.telemetry.overlap import (OverlapReport, WindowReport,
                                     compute_overlap)
from repro.telemetry.recalibrate import (SpeedEstimate, measured_speeds,
                                         recalibrate_alpha)
from repro.telemetry.tracer import (NULL_TRACER, Event, Span, Tracer,
                                    as_tracer)

__all__ = [
    "Counter", "Event", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_TRACER", "OverlapReport", "Span", "SpeedEstimate", "Tracer",
    "WindowReport", "as_tracer", "compute_overlap", "measured_speeds",
    "recalibrate_alpha", "to_chrome_trace", "validate_chrome_trace",
    "write_chrome_trace",
]
