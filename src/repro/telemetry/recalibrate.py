"""Trace-driven alpha recalibration (paper §4.4 on measured spans).

``alpha_benchmark.refine_alpha`` refines the analytic alpha by probing
synthetic workloads.  Once a traced run exists we can do better: the
engine's spans carry the *actual* bytes each stream moved or computed,
so effective per-stream speeds fall out of the trace —

    v_cpu = Σ host-shard bytes / Σ cpu_gemm busy seconds
    v_pin = Σ device-shard bytes / Σ pin busy seconds
    v_com = Σ device-shard bytes / Σ transfer busy seconds

— and the probe callables the solver needs are linear projections from
those speeds:

    T_cpu(a) = (1 - a) · B / v_cpu
    T_com(a) = max(a · B / v_pin,  a · B / v_com)

The crossing F_cpu(ā) = F_com(ā) is scale-invariant in B, so the
refined alpha depends only on measured speed ratios; B (bytes per step)
just sets ``predicted_time``'s units.  Under a compressed wire format
(``wstream="q8"``) pin/transfer spans carry wire bytes plus an
``fp_bytes`` attr; v_pin/v_com come out in wire bytes/s and the link
term is scaled by the measured wire ratio r = Σwire/Σfp, i.e.
T_com(a) = a·B·r / v, matching the shifted law in docs/ANALYSIS.md.  The same ``refine_alpha``
machinery (probe window, polynomial fit, root solve, hysteresis at the
caller) applies unchanged — tests check the fit matches a direct
``refine_alpha`` call on the synthesized callables to tight tolerance.

Consumed by ``HeteGenBackend(recalibrate=...)``: at a safe point (start
of a decode step, engines idle) the backend snapshots recent spans,
recalibrates, and re-plans the phase if the refined alpha drifted past
the threshold.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

from repro.core.alpha_benchmark import FitResult, refine_alpha
from repro.telemetry.tracer import Span

# engine/param-manager tracks that speed estimation reads
_CPU_TRACK = "cpu_gemm"
_PIN_TRACK = "pin"
_TRANS_TRACK = "transfer"


@dataclasses.dataclass(frozen=True)
class SpeedEstimate:
    """Effective stream speeds (bytes/s) measured from a trace.

    ``v_pin``/``v_com`` are *wire* bytes/s — under a compressed stream
    (``wstream="q8"``) the pin/transfer spans carry the bytes that
    actually moved.  ``pin_fp_bytes``/``trans_fp_bytes`` accumulate the
    spans' ``fp_bytes`` attr (uncompressed equivalent; defaults to the
    wire bytes on fp traces), so :attr:`wire_ratio` recovers the
    compression factor r the alpha law needs.
    """

    v_cpu: float
    v_pin: float
    v_com: float
    cpu_bytes: int
    pin_bytes: int
    trans_bytes: int
    cpu_s: float
    pin_s: float
    trans_s: float
    n_spans: int
    pin_fp_bytes: int = 0
    trans_fp_bytes: int = 0

    @property
    def wire_ratio(self) -> float:
        """Wire bytes per compute byte on the transfer stream (r <= 1
        under compression, exactly 1.0 on fp traces)."""
        if self.trans_fp_bytes <= 0:
            return 1.0
        return self.trans_bytes / self.trans_fp_bytes

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["wire_ratio"] = self.wire_ratio
        return d


def _tally(spans: Sequence[Span], track: str,
           phase: Optional[str]) -> tuple:
    """(wire_bytes, fp_bytes, secs, n) for byte-carrying spans of a track.
    ``fp_bytes`` falls back to the wire bytes when a span has no
    ``fp_bytes`` attr (fp streams: wire == compute)."""
    nbytes, fp_bytes, secs, n = 0, 0, 0.0, 0
    for s in spans:
        if s.track != track:
            continue
        attrs = s.attrs or {}
        if phase is not None and attrs.get("phase") not in (None, phase):
            continue
        b = attrs.get("bytes")
        if not b or s.dur <= 0.0:
            continue
        nbytes += int(b)
        fp_bytes += int(attrs.get("fp_bytes", b))
        secs += s.dur
        n += 1
    return nbytes, fp_bytes, secs, n


def measured_speeds(spans: Sequence[Span], *,
                    phase: Optional[str] = None) -> SpeedEstimate:
    """Effective v_cpu / v_pin / v_com from a traced run.

    Only spans carrying a ``bytes`` attr count (the engine and param
    manager attach it).  ``phase`` restricts to spans tagged with that
    phase attr (untagged spans always count).  Raises ``ValueError``
    when a stream has no measurable spans — an all-device or all-host
    plan cannot calibrate the streams it never exercised.
    """
    cpu_b, _, cpu_s, n_cpu = _tally(spans, _CPU_TRACK, phase)
    pin_b, pin_fp, pin_s, n_pin = _tally(spans, _PIN_TRACK, phase)
    trn_b, trn_fp, trn_s, n_trn = _tally(spans, _TRANS_TRACK, phase)
    missing = [name for name, n in
               [(_CPU_TRACK, n_cpu), (_PIN_TRACK, n_pin),
                (_TRANS_TRACK, n_trn)] if n == 0]
    if missing:
        raise ValueError(
            f"cannot estimate stream speeds: no byte-carrying spans on "
            f"{missing} (phase={phase!r})")
    return SpeedEstimate(
        v_cpu=cpu_b / cpu_s, v_pin=pin_b / pin_s, v_com=trn_b / trn_s,
        cpu_bytes=cpu_b, pin_bytes=pin_b, trans_bytes=trn_b,
        cpu_s=cpu_s, pin_s=pin_s, trans_s=trn_s,
        n_spans=n_cpu + n_pin + n_trn,
        pin_fp_bytes=pin_fp, trans_fp_bytes=trn_fp)


def recalibrate_alpha(
    spans: Sequence[Span],
    alpha0: float,
    *,
    phase: Optional[str] = None,
    bytes_per_step: Optional[float] = None,
    gamma: float = 0.08,
    lam: float = 0.02,
    degree: int = 2,
) -> FitResult:
    """Refine ``alpha0`` from a recorded trace.

    Measures stream speeds with :func:`measured_speeds`, synthesizes the
    probe callables above, and hands them to the existing
    ``refine_alpha`` solver.  ``bytes_per_step`` scales
    ``predicted_time`` to real seconds; when omitted the measured total
    device+host bytes are used (the refined alpha itself is
    scale-invariant either way).
    """
    est = measured_speeds(spans, phase=phase)
    # B counts *compute* bytes (the alpha split partitions the fp weight);
    # the link only carries r·B wire bytes of it.  On fp traces r == 1 and
    # fp tallies equal wire tallies, so this reduces to the original form.
    B = float(bytes_per_step) if bytes_per_step is not None else float(
        est.cpu_bytes + max(est.pin_fp_bytes, est.trans_fp_bytes))
    B = max(B, 1.0)
    r = est.wire_ratio

    def time_cpu(a: float) -> float:
        return (1.0 - a) * B / est.v_cpu

    def time_com(a: float) -> float:
        wire = a * B * r
        return max(wire / est.v_pin, wire / est.v_com)

    return refine_alpha(time_cpu, time_com, alpha0,
                        gamma=gamma, lam=lam, degree=degree)
