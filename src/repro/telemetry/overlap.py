"""Overlap analysis — did the I/O actually hide? (paper §4.3, Fig. 5c).

HeteGen's speedup comes from running pin ‖ transfer ‖ host GEMM ‖ device
compute concurrently.  :class:`repro.core.engine.StreamStats` totals say
how busy each stream was; this module consumes the tracer's timeline to
answer the question the totals cannot: *while I/O was in flight, was
compute also in flight?*

Definitions (all on the host ``perf_counter`` clock):

* A stream's **busy set** is the interval union of its spans — self
  overlap within one stream (which cannot happen on the single-worker
  pools, but defensively) collapses.
* **io** = union(pin, transfer); **compute** = union(cpu_gemm, device).
* **I/O-hidden fraction** = |io ∩ compute| / |io| — the share of I/O
  wall-time during which some compute was also running.  1.0 means the
  paper's overlap story holds perfectly; ≈0 means the streams ran
  serially (the forced-serial regression test pins this).
* **critical path** per window: the component with the largest busy
  time inside the window — the stream to optimize next.
* **utilization** per stream: busy / window wall, same definition as
  ``StreamStats.utilization`` so the two reports cross-check.

Per-step breakdowns slice the same math by the batcher's ``step`` spans
("step" track); phase attribution uses the span's ``phase`` attr when
present.  Pure host arithmetic over recorded floats — no jax imports.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.telemetry.tracer import Span

# the engine's stream tracks, in report order
IO_TRACKS = ("pin", "transfer")
COMPUTE_TRACKS = ("cpu_gemm", "device")
STREAM_TRACKS = IO_TRACKS + COMPUTE_TRACKS
SAMPLE_TRACK = "sample"

Interval = Tuple[float, float]


def union_intervals(intervals: Iterable[Interval]) -> List[Interval]:
    """Merge intervals into a disjoint, sorted union.  Zero-duration
    intervals vanish (they carry no busy time)."""
    ivs = sorted((t0, t1) for t0, t1 in intervals if t1 > t0)
    out: List[Interval] = []
    for t0, t1 in ivs:
        if out and t0 <= out[-1][1]:
            if t1 > out[-1][1]:
                out[-1] = (out[-1][0], t1)
        else:
            out.append((t0, t1))
    return out


def intersect_unions(a: Sequence[Interval],
                     b: Sequence[Interval]) -> List[Interval]:
    """Intersection of two disjoint sorted unions (two-pointer sweep)."""
    out: List[Interval] = []
    i = j = 0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            out.append((lo, hi))
        if a[i][1] <= b[j][1]:
            i += 1
        else:
            j += 1
    return out


def clip_union(ivs: Sequence[Interval], t0: float,
               t1: float) -> List[Interval]:
    """Restrict a disjoint union to the window [t0, t1]."""
    out = []
    for a, b in ivs:
        lo, hi = max(a, t0), min(b, t1)
        if hi > lo:
            out.append((lo, hi))
    return out


def total(ivs: Sequence[Interval]) -> float:
    return sum(t1 - t0 for t0, t1 in ivs)


@dataclasses.dataclass
class WindowReport:
    """Overlap numbers for one time window (a step, a phase, or the
    whole trace)."""

    label: str
    t0: float
    t1: float
    busy: Dict[str, float]            # track -> busy seconds in window
    io_busy: float                    # |union(pin, transfer)|
    compute_busy: float               # |union(cpu_gemm, device)|
    io_hidden: float                  # |io ∩ compute|
    phase: Optional[str] = None

    @property
    def wall(self) -> float:
        return self.t1 - self.t0

    @property
    def io_hidden_frac(self) -> float:
        """Fraction of I/O wall-time with concurrent compute, in [0, 1].
        Windows with no I/O report 1.0 — nothing needed hiding."""
        if self.io_busy <= 0.0:
            return 1.0
        return min(1.0, max(0.0, self.io_hidden / self.io_busy))

    @property
    def critical_path(self) -> str:
        """The busiest *physical* component in the window (pin /
        transfer / cpu_gemm / device / sample; tie → report order).
        Envelope tracks (step, phase) would trivially win — they wrap
        the streams — so they only count when no stream recorded."""
        cand = {k: v for k, v in self.busy.items()
                if k in STREAM_TRACKS or k == SAMPLE_TRACK} or self.busy
        if not cand or all(v <= 0.0 for v in cand.values()):
            return "idle"
        return max(cand, key=lambda k: (cand[k],))

    def utilization(self) -> Dict[str, float]:
        w = self.wall
        if w <= 0.0:
            return {k: 0.0 for k in self.busy}
        return {k: v / w for k, v in self.busy.items()}


@dataclasses.dataclass
class OverlapReport:
    """Whole-trace + per-step overlap breakdown."""

    overall: WindowReport
    steps: List[WindowReport]

    @property
    def io_hidden_frac(self) -> float:
        return self.overall.io_hidden_frac

    def as_dict(self) -> Dict[str, Any]:
        def win(w: WindowReport) -> Dict[str, Any]:
            return {"label": w.label, "wall_s": w.wall,
                    "phase": w.phase,
                    "busy_s": dict(w.busy),
                    "utilization": w.utilization(),
                    "io_busy_s": w.io_busy,
                    "compute_busy_s": w.compute_busy,
                    "io_hidden_frac": w.io_hidden_frac,
                    "critical_path": w.critical_path}
        return {"overall": win(self.overall),
                "steps": [win(w) for w in self.steps]}

    def render(self) -> str:
        """Human-readable text report (the ``--overlap-report`` output)."""
        o = self.overall
        lines = ["overlap report",
                 "=" * 64,
                 f"window           {o.wall * 1e3:10.3f} ms",
                 f"io hidden        {o.io_hidden_frac:10.3f}   "
                 f"(io busy {o.io_busy * 1e3:.3f} ms, "
                 f"compute busy {o.compute_busy * 1e3:.3f} ms)",
                 f"critical path    {o.critical_path:>10s}",
                 "stream utilization:"]
        util = o.utilization()
        for trk in (*STREAM_TRACKS, SAMPLE_TRACK):
            if trk in o.busy:
                lines.append(f"  {trk:<12s} {util[trk]:6.3f}   "
                             f"({o.busy[trk] * 1e3:.3f} ms busy)")
        if self.steps:
            lines.append("")
            lines.append(f"{'step':<16s} {'phase':<8s} {'wall ms':>9s} "
                         f"{'io hidden':>9s}  critical")
            for w in self.steps:
                lines.append(
                    f"{w.label:<16s} {(w.phase or '-'):<8s} "
                    f"{w.wall * 1e3:9.3f} {w.io_hidden_frac:9.3f}  "
                    f"{w.critical_path}")
        return "\n".join(lines)


def _window_report(label: str, t0: float, t1: float,
                   by_track: Dict[str, List[Interval]],
                   phase: Optional[str] = None) -> WindowReport:
    clipped = {trk: clip_union(ivs, t0, t1)
               for trk, ivs in by_track.items()}
    io = union_intervals(
        iv for trk in IO_TRACKS for iv in clipped.get(trk, ()))
    comp = union_intervals(
        iv for trk in COMPUTE_TRACKS for iv in clipped.get(trk, ()))
    return WindowReport(
        label=label, t0=t0, t1=t1,
        busy={trk: total(ivs) for trk, ivs in clipped.items()},
        io_busy=total(io), compute_busy=total(comp),
        io_hidden=total(intersect_unions(io, comp)), phase=phase)


def compute_overlap(spans: Sequence[Span], *,
                    step_track: str = "step") -> OverlapReport:
    """Build the overlap report from a span list.

    Spans on ``step_track`` define per-step windows (their ``phase``
    attr, if any, labels the row); every other track contributes busy
    intervals.  An empty trace yields a zero-width overall window.
    """
    by_track: Dict[str, List[Interval]] = {}
    step_spans: List[Span] = []
    for s in spans:
        if s.track == step_track:
            step_spans.append(s)
        else:
            by_track.setdefault(s.track, []).append((s.t0, s.t1))
    by_track = {trk: union_intervals(ivs) for trk, ivs in by_track.items()}

    if spans:
        t0 = min(s.t0 for s in spans)
        t1 = max(s.t1 for s in spans)
    else:
        t0 = t1 = 0.0
    overall = _window_report("overall", t0, t1, by_track)

    steps = []
    for s in sorted(step_spans, key=lambda s: s.t0):
        phase = (s.attrs or {}).get("phase")
        steps.append(_window_report(s.name, s.t0, s.t1, by_track,
                                    phase=phase))
    return OverlapReport(overall=overall, steps=steps)
