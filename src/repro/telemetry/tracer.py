"""Zero-device-sync tracing for the heterogeneous streams (paper Fig. 5c).

HeteGen's throughput claim is an *overlap* claim: pin ‖ transfer ‖ host
GEMM ‖ device compute must run concurrently or the I/O bottleneck is not
hidden.  `StreamStats` can only say how busy each stream was in total;
this tracer records *when* each piece of work ran, so the overlap report
(:mod:`repro.telemetry.overlap`) can compute the I/O-hidden fraction and
critical path per step, and the Chrome exporter
(:mod:`repro.telemetry.export`) can render the timeline.

Design constraints, in order:

* **No device synchronization, ever.**  Timestamps are host
  ``time.perf_counter()`` only.  The tracer never touches a jax array —
  a tracer that calls ``.item()`` or ``block_until_ready`` would
  serialize the very streams it measures (enforced statically by the
  ``telemetry-no-sync`` lint rule, docs/ANALYSIS.md).
* **Thread-safe without a hot-path lock.**  Every thread appends to its
  own ring buffer (a bounded ``deque`` owned by that thread; the shared
  registry of buffers is locked only on a thread's *first* span).  The
  engine's pin / transfer / host-GEMM threads and the driver thread
  never contend.
* **Negligible overhead when disabled.**  A disabled tracer's ``span``
  returns a shared no-op context manager and ``event`` returns
  immediately — no allocation, no timestamp, no branch beyond one
  attribute check.  Serving code therefore instruments unconditionally
  and leaves the tracer off in production-critical paths.

Tracks are logical streams, not threads: a span lands on its explicit
``track=`` when given, else on the calling thread's default track
(:meth:`Tracer.set_track`), else on the thread's name.  The engine uses
explicit tracks (``pin`` / ``transfer`` / ``cpu_gemm`` / ``device``) so
the report's stream identities are stable regardless of which thread
pool executes the work.  Within one track spans never overlap as long as
the track's work is serial (single-worker pools here) — the property the
Chrome-trace validator checks.

Ring capacity bounds memory: when a thread's buffer is full the oldest
spans drop (counted — :meth:`Tracer.dropped`), never the newest; a
trace's tail is always intact.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Span:
    """One completed interval of work on a track.  Times are host
    ``perf_counter`` seconds (shared origin within one process)."""

    name: str
    track: str
    t0: float
    t1: float
    attrs: Optional[Dict[str, Any]] = None

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass(frozen=True)
class Event:
    """One instant marker (admission, preemption, prefetch, ...)."""

    name: str
    track: str
    t: float
    attrs: Optional[Dict[str, Any]] = None


class _NullSpan:
    """Shared no-op context manager — the disabled tracer's span."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    """Context manager that records one span on exit."""

    __slots__ = ("_buf", "name", "track", "attrs", "t0")

    def __init__(self, buf: "_ThreadBuf", name: str, track: str,
                 attrs: Optional[Dict[str, Any]]):
        self._buf = buf
        self.name = name
        self.track = track
        self.attrs = attrs

    def __enter__(self) -> "_LiveSpan":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self._buf.add_span(self.name, self.track, self.t0,
                           time.perf_counter(), self.attrs)
        return False

    def set(self, **attrs) -> None:
        """Attach/override attrs before the span closes (e.g. a step
        span learning its phase only after the work ran)."""
        self.attrs = {**(self.attrs or {}), **attrs}


class _ThreadBuf:
    """One thread's ring of spans + events.  Appended to only by its
    owning thread; snapshots copy under the GIL (deque iteration is
    atomic enough for our read-mostly snapshot: the worst case is
    missing the very newest record, never corruption)."""

    __slots__ = ("spans", "events", "n_spans", "n_events", "track")

    def __init__(self, capacity: int, track: str):
        self.spans: deque = deque(maxlen=capacity)
        self.events: deque = deque(maxlen=capacity)
        self.n_spans = 0          # total appended (drops = n - len)
        self.n_events = 0
        self.track = track        # thread-default track

    def add_span(self, name, track, t0, t1, attrs) -> None:
        self.spans.append((name, track, t0, t1, attrs))
        self.n_spans += 1

    def add_event(self, name, track, t, attrs) -> None:
        self.events.append((name, track, t, attrs))
        self.n_events += 1


class Tracer:
    """Ring-buffered span/event recorder for the serving hot path.

    ::

        tr = Tracer()
        with tr.span("blk0.wq", track="cpu_gemm", bytes=1 << 20):
            y = x @ w_host
        tr.event("preempt", track="sched", rid=3)

    ``capacity`` bounds each *thread's* buffer (oldest spans drop first).
    A tracer constructed with ``enabled=False`` — or the module's
    :data:`NULL_TRACER` — is a no-op whose ``span`` returns a shared
    null context manager.
    """

    def __init__(self, capacity: int = 65536, *, enabled: bool = True):
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self.t_origin = time.perf_counter()
        # a list, not a dict keyed by thread ident: the OS recycles
        # idents, and a recycled key would silently drop a finished
        # thread's buffer (pool threads come and go across retunes)
        self._bufs: List[_ThreadBuf] = []
        self._lock = threading.Lock()       # guards the buffer registry
        self._local = threading.local()     # fast path: this thread's buf

    # -- recording ------------------------------------------------------
    def _buf(self) -> _ThreadBuf:
        buf = getattr(self._local, "buf", None)
        if buf is None:
            th = threading.current_thread()
            buf = _ThreadBuf(self.capacity, th.name)
            with self._lock:
                self._bufs.append(buf)
            self._local.buf = buf
        return buf

    def span(self, name: str, track: Optional[str] = None, **attrs):
        """Context manager timing one interval.  ``track`` pins the span
        to a logical stream; default is the thread's track."""
        if not self.enabled:
            return _NULL_SPAN
        buf = self._buf()
        return _LiveSpan(buf, name, track or buf.track, attrs or None)

    def event(self, name: str, track: Optional[str] = None,
              **attrs) -> None:
        """Record one instant marker."""
        if not self.enabled:
            return
        buf = self._buf()
        buf.add_event(name, track or buf.track, time.perf_counter(),
                      attrs or None)

    def set_track(self, track: str) -> None:
        """Set the calling thread's default track name."""
        if self.enabled:
            self._buf().track = track

    def mark(self) -> float:
        """Host timestamp on the tracer's clock — pair with the
        ``since=`` filters to scope a snapshot to recent work."""
        return time.perf_counter()

    # -- snapshots ------------------------------------------------------
    def _all_bufs(self) -> List[_ThreadBuf]:
        with self._lock:
            return list(self._bufs)

    def spans(self, since: Optional[float] = None,
              track: Optional[str] = None) -> List[Span]:
        """All recorded spans, sorted by start time.  ``since`` keeps
        spans that *end* after the mark; ``track`` filters exactly."""
        out: List[Span] = []
        for buf in self._all_bufs():
            for name, trk, t0, t1, attrs in list(buf.spans):
                if since is not None and t1 <= since:
                    continue
                if track is not None and trk != track:
                    continue
                out.append(Span(name, trk, t0, t1, attrs))
        out.sort(key=lambda s: (s.t0, s.t1))
        return out

    def events_list(self, since: Optional[float] = None,
                    track: Optional[str] = None) -> List[Event]:
        out: List[Event] = []
        for buf in self._all_bufs():
            for name, trk, t, attrs in list(buf.events):
                if since is not None and t <= since:
                    continue
                if track is not None and trk != track:
                    continue
                out.append(Event(name, trk, t, attrs))
        out.sort(key=lambda e: e.t)
        return out

    def dropped(self) -> int:
        """Spans+events lost to ring wrap since construction/clear."""
        n = 0
        for buf in self._all_bufs():
            n += (buf.n_spans - len(buf.spans)) \
                + (buf.n_events - len(buf.events))
        return n

    def clear(self) -> None:
        for buf in self._all_bufs():
            buf.spans.clear()
            buf.events.clear()
            buf.n_spans = 0
            buf.n_events = 0

    def __bool__(self) -> bool:
        return self.enabled


NULL_TRACER = Tracer(capacity=1, enabled=False)
"""The shared disabled tracer — instrument against this by default so
call sites never branch on ``tracer is None``."""


def as_tracer(trace) -> Tracer:
    """Normalize a user-facing ``trace=`` knob: ``True`` builds a fresh
    tracer, a :class:`Tracer` passes through, falsy yields the shared
    no-op tracer."""
    if isinstance(trace, Tracer):
        return trace
    if trace:
        return Tracer()
    return NULL_TRACER
