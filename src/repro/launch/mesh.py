"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because
the dry-run forces 512 host devices via XLA_FLAGS before any jax import,
while tests/benches must keep seeing 1 device.

Topology:
    single-pod:  (16, 16)    ("data", "model")   = 256 chips (one v5e pod)
    multi-pod:   (2, 16, 16) ("pod", "data", "model") = 512 chips; the
                 leading "pod" axis crosses the DCN and carries only data
                 parallelism (gradient all-reduce / batch sharding), never
                 tensor collectives.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Optional[Tuple[str, ...]] = None):
    """Arbitrary mesh for tests (e.g. (2, 2) on 4 host devices)."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):]
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(shape))
