"""Training launcher.

Single-host (real execution, any reduced/tiny/OPT config):

    PYTHONPATH=src python -m repro.launch.train --arch tiny --steps 50

Production meshes exist only as the dry-run in this container; pass
``--dryrun`` to lower/compile the train step for an assigned architecture
on the production mesh instead of executing (delegates to
:mod:`repro.launch.dryrun`).
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant of --arch")
    ap.add_argument("--dryrun", action="store_true",
                    help="lower/compile train_4k on the production mesh")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    args = ap.parse_args()

    if args.dryrun:
        # must re-exec through dryrun so XLA_FLAGS precede the jax import
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", "train_4k",
               "--mesh", args.mesh]
        raise SystemExit(subprocess.call(cmd))

    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.data.pipeline import make_training_data
    from repro.train.loop import TrainConfig, Trainer
    from repro.train.optimizer import OptimizerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    tcfg = TrainConfig(accum_steps=args.accum,
                       optimizer=OptimizerConfig(name=cfg.optimizer,
                                                 lr=args.lr),
                       warmup=min(20, args.steps // 5 + 1),
                       total_steps=args.steps)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"batch {args.batch} x seq {args.seq}, accum {args.accum}")
    data = make_training_data(cfg, batch=args.batch, seq=args.seq)
    batches = ({"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])} for b in data)
    tr = Trainer(cfg, tcfg, checkpoint_dir=args.ckpt_dir,
                 checkpoint_every=args.ckpt_every)
    last = tr.run(batches, args.steps)
    first = tr.metrics_log[0]["loss"] if tr.metrics_log else float("nan")
    print(f"done: loss {first:.3f} -> {last.get('loss', float('nan')):.3f} "
          f"at step {tr.step}")


if __name__ == "__main__":
    main()
