"""Serving launcher — the paper's deployment shape.

Every mode is flag parsing over ONE front door,
:class:`repro.serving.api.LLM` (docs/SERVING.md):

    resident       jitted one-shot generation, weights on device
    offload        HeteGen: weights in host memory, alpha-split linears,
                   pinned-ring streaming (`--budget-frac` sets the device
                   memory available for residency promotion); the backend
                   holds per-phase placement plans — compute-bound
                   prefill (alpha -> 1) and link-bound decode
    batch          continuous batching over N synthetic requests
    batch-offload  continuous batching over HeteGen-offloaded weights

The modes differ only in which backend is handed to the facade and
whether requests arrive together (one-shot executor) or staggered
(continuous batcher).  Scheduling is two more flags over the same door:
``--policy fcfs|priority|fair_share`` picks the admission/preemption
policy (with ``priority``, request i carries priority ``i %% 2`` so the
preemption path is actually exercised), ``--async`` serves through the
event-loop :class:`repro.serving.api.AsyncLLM` (no caller-driven
``step()``), and ``--n-pages`` shrinks the paged pool to provoke
optimistic-paging preemption.  ``--paged`` swaps the batch modes to the
paged KV cache; ``--sampler`` picks the per-request sampling (requests
carry their own :class:`repro.serving.sampling.SamplingParams`, so paged
and dense decode stay token-identical even stochastically); ``--stream``
prints the first request's tokens as they decode.  ``--spec
ngram|model`` turns on heterogeneous speculative decoding (CPU-side
drafting, batched GPU verification — docs/SERVING.md) with ``--spec-k``
draft tokens per step and ``--spec-adaptive`` per-request k control.

    PYTHONPATH=src python -m repro.launch.serve --arch opt-125m \\
        --mode offload --budget-frac 0.25 --requests 4
    PYTHONPATH=src python -m repro.launch.serve --mode batch --paged \\
        --policy priority --n-pages 24 --async

``--dryrun`` lowers/compiles the serve step for an assigned architecture
on the production mesh (delegates to :mod:`repro.launch.dryrun`).
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--mode", choices=("resident", "offload", "batch",
                                       "batch-offload"),
                    default="offload")
    ap.add_argument("--budget-frac", type=float, default=0.0)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache for the batch modes")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--policy", choices=("fcfs", "priority", "fair_share"),
                    default="fcfs", help="scheduler admission/preemption "
                    "policy for the batch modes")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="serve through the event-loop AsyncLLM "
                    "(no caller-driven step())")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="shrink the paged pool to provoke preemption")
    ap.add_argument("--selfcheck", action="store_true",
                    help="paged-allocator runtime self-check: validate "
                    "free-list/ref-count/block-table invariants every "
                    "step and audit for leaked pages at close")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="chunked prefill: admit long prompts at most "
                    "this many tokens per step so a long admission "
                    "cannot stall decode tenants")
    ap.add_argument("--no-prefix-dedupe", action="store_true",
                    help="disable admission-time page-aligned prompt "
                    "prefix sharing (paged mode only)")
    ap.add_argument("--spec", choices=("ngram", "model"), default=None,
                    help="speculative decoding: CPU-side drafting "
                    "(prompt-lookup ngrams or a draft model) with "
                    "batched verification on the target")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per speculative step")
    ap.add_argument("--spec-adaptive", action="store_true",
                    help="adapt k per request from acceptance history")
    ap.add_argument("--sampler", choices=("greedy", "temperature", "topk",
                                          "topp"), default="greedy")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--stream", action="store_true",
                    help="stream the first request token by token")
    ap.add_argument("--hw", default="a10", help="hardware model for the "
                    "alpha law (a10 | v5e)")
    ap.add_argument("--wstream", choices=("fp", "q8"), default="fp",
                    help="wire format of streamed weights in the offload "
                    "modes: fp streams shards as-is, q8 streams int8 + "
                    "per-column fp32 scales (~4x fewer link bytes; the "
                    "plan's alpha shifts toward the device, "
                    "docs/SERVING.md)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="record zero-sync spans across the run and dump "
                    "a Chrome/Perfetto trace JSON (docs/OBSERVABILITY.md)")
    ap.add_argument("--overlap-report", action="store_true",
                    help="print the per-step I/O-hidden fraction, stream "
                    "utilization, and critical-path breakdown computed "
                    "from the recorded trace (implies tracing)")
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    args = ap.parse_args()

    if args.dryrun:
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape,
               "--mesh", args.mesh]
        raise SystemExit(subprocess.call(cmd))

    import jax
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.core.hw import HARDWARE
    from repro.models import model as M
    from repro.serving.api import LLM
    from repro.serving.sampling import SamplingParams

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    if args.spec is not None:
        # repetitive prompts give the prompt-lookup drafter something to
        # look up (real text has this structure; random tokens do not)
        motif = [list(rng.integers(0, cfg.vocab_size, 4))
                 for _ in range(args.requests)]
        prompts = [(m * args.prompt_len)[:args.prompt_len] for m in motif]
    else:
        prompts = [list(rng.integers(0, cfg.vocab_size, args.prompt_len))
                   for _ in range(args.requests)]
    sampling = SamplingParams(
        kind=args.sampler, temperature=args.temperature,
        top_k=40 if args.sampler == "topk" else 0,
        top_p=0.9 if args.sampler == "topp" else 1.0)
    print(f"serving {cfg.name} ({cfg.param_count()/1e6:.1f}M) "
          f"mode={args.mode} sampler={args.sampler}")

    # the one divergence between modes: which backend the facade drives.
    # slots = the decode width the facade schedules, and therefore the
    # batch the offload plan is built for — matching them up front avoids
    # throwaway engine partitions (the batcher re-tunes to its slot count)
    slots = args.requests if args.mode == "offload" else 4
    backend = None
    if args.mode in ("offload", "batch-offload"):
        from repro.serving.backends import HeteGenBackend, enumerate_linears
        total = sum(s.nbytes for s in enumerate_linears(cfg))
        backend = HeteGenBackend(cfg, params, hw=HARDWARE[args.hw],
                                 batch=slots,
                                 budget_bytes=args.budget_frac * total,
                                 wstream=args.wstream)
        if args.wstream == "q8":
            pol = backend.policy
            print(f"  wstream=q8: int8+scale wire format, "
                  f"decode alpha={pol.alpha:.3f}")

    spec = None
    if args.spec is not None:
        from repro.serving.speculative import (ModelDrafter, NgramDrafter,
                                               SpecConfig)
        drafter = NgramDrafter() if args.spec == "ngram" else \
            ModelDrafter(cfg, params,
                         max_len=args.prompt_len + args.max_new + 8)
        spec = SpecConfig(drafter=drafter, k=args.spec_k,
                          adaptive=args.spec_adaptive)

    tracing = bool(args.trace or args.overlap_report)
    llm_kw = dict(sampling=sampling, max_slots=slots,
                  max_len=args.prompt_len + args.max_new + 8,
                  paged=args.paged, page_size=args.page_size,
                  n_pages=args.n_pages, policy=args.policy,
                  chunk_tokens=args.chunk_tokens,
                  prefix_dedupe=False if args.no_prefix_dedupe else None,
                  spec=spec, selfcheck=args.selfcheck, trace=tracing)
    # give the priority policy something to schedule: alternate priorities
    prio = (lambda i: i % 2) if args.policy == "priority" else (lambda i: 0)

    facade = None
    if args.use_async:
        # the event loop owns the step() crank: submit/stream only
        from repro.serving.api import AsyncLLM
        with AsyncLLM(cfg, params, backend=backend, own_backend=True,
                      **llm_kw) as allm:
            facade = allm._llm
            if args.stream:
                for tok in allm.stream(prompts[0], args.max_new):
                    print(f"  stream> {tok}", flush=True)
                prompts = prompts[1:]
            handles = [allm.submit(p, args.max_new, priority=prio(i))
                       for i, p in enumerate(prompts)]
            outs = [h.result() for h in handles]
            st = allm.stats()
    else:
        with LLM(cfg, params, backend=backend, own_backend=True,
                 **llm_kw) as llm:
            facade = llm
            if args.stream:
                for tok in llm.stream(prompts[0], args.max_new):
                    print(f"  stream> {tok}", flush=True)
                prompts = prompts[1:]

            if args.mode in ("resident", "offload"):
                # requests arrive together: the facade runs them one-shot
                outs = llm.generate(prompts, args.max_new) \
                    if prompts else []
            else:
                # staggered arrivals: continuous batching
                for i, p in enumerate(prompts):
                    llm.submit(p, args.max_new, priority=prio(i))
                outs = list(llm.drain().values())
            st = llm.stats()

    total_toks = sum(len(o.tokens) for o in outs)
    print(f"{len(outs)} requests, {total_toks} tokens "
          f"via executor={st['executor']}, "
          f"{st.get('tokens_per_s', 0.0):.1f} tok/s")
    if "scheduler" in st:
        sc = st["scheduler"]
        print(f"scheduler: policy={sc['policy']} "
              f"preemptions={sc['preemptions']} "
              f"chunks={sc['chunks_planned']} "
              f"dedupe_hits={sc['dedupe_hits']} "
              f"(+{sc['dedupe_tokens']} tokens shared)")
    if "phase_alpha" in st:
        al = st["phase_alpha"]
        print("phase plans: " + "  ".join(
            f"{ph}: alpha={a:.3f}" for ph, a in sorted(al.items())))
        print(f"resident={st['resident_bytes']/1e6:.0f}MB")
    if "stream" in st:
        s = st["stream"]
        print(f"stream busy (s): cpu={s.cpu:.3f} pin={s.pin:.3f} "
              f"trans={s.trans:.3f} dev={s.dev:.3f}")
    if "paged" in st:
        pg = st["paged"]
        print(f"paged KV: page_size={pg['page_size']} "
              f"pool={pg['pool_pages']} pages, "
              f"{pg['mapped_pages']} still mapped")
    if "spec" in st:
        sp = st["spec"]
        print(f"speculative: drafter={args.spec} k={args.spec_k} "
              f"drafted={sp['drafted']} accepted={sp['accepted']} "
              f"rolled_back={sp['rolled_back']} "
              f"(acceptance {sp['acceptance_rate']:.2f})")
    if tracing:
        # the tracer's ring buffers are plain host memory — they outlive
        # the facade's close(), so export after teardown is safe
        if args.trace:
            doc = facade.write_trace(args.trace)
            print(f"trace: {args.trace} "
                  f"({len(doc['traceEvents'])} events)")
        if args.overlap_report:
            print(facade.overlap_report().render())


if __name__ == "__main__":
    main()
