"""Serving launcher — the paper's deployment shape.

Modes:

    resident       jitted generator, weights on device
    offload        HeteGen: weights in host memory, alpha-split linears,
                   pinned-ring streaming (`--budget-frac` sets the device
                   memory available for residency promotion); the placement
                   plan is tuned for the request batch size
    batch          continuous batching demo over N synthetic requests
    batch-offload  continuous batching over HeteGen-offloaded weights
                   (slot-based scheduling, host-resident parameters)

``--paged`` switches the batch modes to the paged KV cache
(:mod:`repro.serving.kv_cache`): slot admit/release maps/unmaps
fixed-size pages through block tables instead of copying cache slices —
token-identical to the dense path under greedy sampling (stochastic
samplers only match in distribution: paged decode compacts the batch,
which renumbers the rows a per-step key is consumed by).

    PYTHONPATH=src python -m repro.launch.serve --arch opt-125m \\
        --mode offload --budget-frac 0.25 --requests 4

``--dryrun`` lowers/compiles the serve step for an assigned architecture
on the production mesh (delegates to :mod:`repro.launch.dryrun`).
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--mode", choices=("resident", "offload", "batch",
                                       "batch-offload"),
                    default="offload")
    ap.add_argument("--budget-frac", type=float, default=0.0)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache for the batch modes")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--hw", default="a10", help="hardware model for the "
                    "alpha law (a10 | v5e)")
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    args = ap.parse_args()

    if args.dryrun:
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape,
               "--mesh", args.mesh]
        raise SystemExit(subprocess.call(cmd))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.core.hw import HARDWARE
    from repro.models import model as M

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size,
                          (args.requests, args.prompt_len)).astype(np.int32)
    print(f"serving {cfg.name} ({cfg.param_count()/1e6:.1f}M) "
          f"mode={args.mode}")

    if args.mode == "resident":
        from repro.serving.engine import Generator
        r = Generator(cfg, params).generate({"tokens": jnp.asarray(prompt)},
                                            args.max_new)
        print(f"{args.requests} x {args.max_new} tokens, "
              f"{r.tokens_per_s:.1f} tok/s decode")
    elif args.mode == "offload":
        from repro.serving.offload_runtime import (OffloadGenerator,
                                                   enumerate_linears)
        hw = HARDWARE[args.hw]
        total = sum(s.nbytes for s in enumerate_linears(cfg))
        off = OffloadGenerator(cfg, params, hw=hw,
                               budget_bytes=args.budget_frac * total)
        res = off.generate(prompt, args.max_new)
        st = res["stream_stats"]
        print(f"alpha={res['alpha']:.3f} resident="
              f"{res['resident_bytes']/1e6:.0f}MB/"
              f"{total/1e6:.0f}MB  {res['tokens_per_s']:.1f} tok/s")
        print(f"stream busy (s): cpu={st.cpu:.3f} pin={st.pin:.3f} "
              f"trans={st.trans:.3f} dev={st.dev:.3f}")
        off.close()
    else:
        from repro.serving.batcher import ContinuousBatcher
        backend = None
        max_slots = 4
        if args.mode == "batch-offload":
            from repro.serving.backends import HeteGenBackend
            from repro.serving.offload_runtime import enumerate_linears
            total = sum(s.nbytes for s in enumerate_linears(cfg))
            backend = HeteGenBackend(
                cfg, params, hw=HARDWARE[args.hw], batch=max_slots,
                budget_bytes=args.budget_frac * total)
            print(f"offload backend: alpha={backend.policy.alpha:.3f} "
                  f"plan tuned for batch={backend.policy.batch}")
        if args.paged and backend is None:
            # the scan-stacked default cache is not pageable; the paged
            # resident path runs through the per-layer backend cache
            from repro.serving.backends import ResidentBackend
            backend = ResidentBackend(cfg, params)
        b = ContinuousBatcher(cfg, params, backend=backend,
                              max_slots=max_slots,
                              max_len=args.prompt_len + args.max_new + 8,
                              paged=args.paged, page_size=args.page_size)
        for i in range(args.requests):
            b.submit(list(prompt[i]), args.max_new)
        outs = b.run_until_done()
        total_toks = sum(len(v) for v in outs.values())
        print(f"continuous batching: {len(outs)} requests, "
              f"{total_toks} tokens generated")
        if b.kv is not None:
            used = b.kv.n_pages - 1 - b.kv.free_pages
            print(f"paged KV: page_size={b.kv.page_size} "
                  f"pool={b.kv.n_pages - 1} pages, {used} still mapped")
        if backend is not None:
            backend.close()


if __name__ == "__main__":
    main()
