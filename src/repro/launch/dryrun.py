import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count on first init).  512 placeholder host devices let ``jax.make_mesh``
build the production meshes:

    single:  (16,16)    ("data","model")          — 256 chips
    multi:   (2,16,16)  ("pod","data","model")    — 512 chips

For every cell this lowers the real step function (train_step /
prefill_step / serve_step) with the production shardings, compiles it,
prints ``memory_analysis()`` (proves the per-device footprint fits a 16 GB
v5e chip) and ``cost_analysis()``, runs the trip-count-aware HLO analyzer
(:mod:`repro.analysis.hlo_cost`) and writes one JSON record per cell under
``experiments/dryrun/`` — the roofline tables in EXPERIMENTS.md are
generated from those records.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    ... --arch mistral-nemo-12b --shape decode_32k --mesh multi
    ... --no-sp            # disable sequence-parallel activations
    ... --list             # print the cell matrix and exit
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo_cost import HloCostAnalyzer
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.shapes import SHAPES, input_specs, shape_applicable
from repro.distributed import specs as SP
from repro.distributed.shardings import ShardingRules
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.serving.engine import make_prefill_step, make_serve_step
from repro.train.loop import TrainConfig, loss_fn, make_train_step
from repro.train.optimizer import OptimizerConfig

V5E = {"flops": 197e12, "hbm_bw": 819e9, "hbm_bytes": 16e9, "ici_bw": 50e9}


def _accum_for(shape_batch: int, batch_shards: int) -> int:
    """Largest accumulation count keeping micro-batch >= batch shards."""
    for a in (16, 8, 4, 2, 1):
        if shape_batch % a == 0 and shape_batch // a >= batch_shards:
            return a
    return 1


def build_cell(cfg, shape_name: str, mesh,
               *, sequence_parallel: Optional[bool] = None):
    """Returns (fn, inputs, in_shardings, out_shardings, donate, meta).

    ``sequence_parallel`` defaults per arch: on for the >=100B (fsdp)
    archs whose remat-saved activations need the model axis, off
    otherwise (SP's block-boundary all-gathers cost more than the
    activation memory they save on small models — §Perf hillclimb #2).
    """
    if sequence_parallel is None:
        sequence_parallel = cfg.fsdp
    rules = ShardingRules.for_mesh(mesh, sequence_parallel=sequence_parallel)
    shape = SHAPES[shape_name]
    ins = input_specs(cfg, shape_name)
    pspec = SP.param_specs(cfg, rules, serve=(shape.kind != "train"))
    named = lambda tree: SP.named(mesh, tree)

    if shape.kind == "train":
        batch_shards = 1
        for a in ("pod", "data"):
            batch_shards *= rules.mesh_shape.get(a, 1)
        accum = _accum_for(shape.batch, batch_shards)
        tcfg = TrainConfig(
            accum_steps=accum,
            # bf16 accumulation at accum>=8: halves the grad buffer; the
            # few-step mean keeps the rounding error ~1e-3 relative
            accum_dtype="bfloat16" if accum >= 8 else "float32",
            optimizer=OptimizerConfig(
                name=cfg.optimizer,
                moment_dtype="bfloat16" if cfg.optimizer == "adamw"
                else "float32"))
        step_fn, opt_init = make_train_step(cfg, tcfg, rules)
        params_shape = jax.eval_shape(
            lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))
        opt_shape = jax.eval_shape(opt_init, params_shape)
        ospec = SP.opt_state_specs(cfg, rules, opt_shape, pspec)
        state_spec = {"params": pspec, "opt": ospec, "step": P()}
        state_shape = {"params": params_shape, "opt": opt_shape,
                       "step": jax.ShapeDtypeStruct((), jnp.int32)}
        bspec = SP.batch_specs(cfg, rules, ins["batch"])
        metrics_shape = jax.eval_shape(step_fn, state_shape, ins["batch"])[1]
        mspec = jax.tree.map(lambda _: P(), metrics_shape)
        meta = dict(kind="train", rules=rules, accum=accum,
                    param=(params_shape, pspec), opt=(opt_shape, ospec))
        return (step_fn, (state_shape, ins["batch"]),
                (named(state_spec), named(bspec)),
                (named(state_spec), named(mspec)), (0,), meta)

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, rules)
        cspec = SP.cache_specs(cfg, rules, ins["cache"])
        bspec = SP.batch_specs(cfg, rules, ins["batch"])
        tok_spec = SP.batch_specs(
            cfg, rules, jax.ShapeDtypeStruct((shape.batch,), jnp.int32))
        meta = dict(kind="prefill", rules=rules, accum=1,
                    cache=(ins["cache"], cspec))
        return (fn, (None, ins["batch"], ins["cache"]),
                (named(pspec), named(bspec), named(cspec)),
                (named(cspec), named(tok_spec)), (2,), meta)

    # decode
    fn = make_serve_step(cfg, rules)
    cspec = SP.cache_specs(cfg, rules, ins["cache"])
    tspec = SP.batch_specs(cfg, rules, ins["token"])
    meta = dict(kind="decode", rules=rules, accum=1,
                cache=(ins["cache"], cspec))
    return (fn, (None, ins["token"], ins["cache"]),
            (named(pspec), named(tspec), named(cspec)),
            (named(cspec), named(tspec)), (2,), meta)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             sequence_parallel: Optional[bool] = None,
             kv_int8: bool = False,
             out_dir: Optional[str] = None,
             verbose: bool = True) -> Dict:
    import dataclasses as _dc
    cfg = get_config(arch)
    if kv_int8:
        cfg = _dc.replace(cfg, kv_dtype="int8")
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "sequence_parallel": sequence_parallel,
                 "kv_dtype": cfg.kv_dtype}
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
        return _finish(rec, out_dir, verbose)

    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        rec["mesh_shape"] = dict(zip(mesh.axis_names,
                                     [int(x) for x in mesh.devices.shape]))
        fn, inputs, in_sh, out_sh, donate, meta = build_cell(
            cfg, shape_name, mesh, sequence_parallel=sequence_parallel)
        rec["accum_steps"] = meta["accum"]

        if inputs[0] is None:          # serve/prefill: params first
            params_shape = jax.eval_shape(
                lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))
            args = (params_shape,) + tuple(inputs[1:])
            rules = meta["rules"]
            meta["param"] = (params_shape,
                             SP.param_specs(cfg, rules, serve=True))
        else:
            args = tuple(inputs)

        t0 = time.time()
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec.update(lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2))

        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        }
        mem["total_bytes"] = (mem["argument_bytes"] + mem["output_bytes"]
                              + mem["temp_bytes"] - mem["alias_bytes"])
        mem["fits_16GB_raw_cpu"] = mem["total_bytes"] <= V5E["hbm_bytes"]
        # analytic TPU-footprint estimate (analysis/memory_model.py):
        # the raw CPU numbers include fp32 weight shadows and loop-widened
        # buffers that the TPU lowering does not materialize
        from repro.analysis import memory_model as MM
        shp = SHAPES[shape_name]
        est_kw = dict(kind=meta["kind"], batch=shp.batch, seq=shp.seq,
                      rules=meta["rules"], accum=meta["accum"],
                      accum_dtype_bytes=2 if meta["accum"] >= 8 else 4)
        if "param" in meta:
            est_kw.update(param_shapes=meta["param"][0],
                          param_spec=meta["param"][1])
        if "opt" in meta:
            est_kw.update(opt_shapes=meta["opt"][0], opt_spec=meta["opt"][1])
        if "cache" in meta:
            est_kw.update(cache_shapes=meta["cache"][0],
                          cache_spec=meta["cache"][1])
        est = MM.estimate(cfg, **est_kw)
        mem["analytic"] = {k: (float(v) if not isinstance(v, bool) else v)
                           for k, v in est.items()}
        mem["fits_16GB"] = bool(est["fits_16GB"])
        rec["memory"] = mem
        if verbose:
            print(f"  memory_analysis: {ma}")
            print(f"  analytic_tpu_est: "
                  f"{ {k: round(v/2**30, 2) if isinstance(v, float) else v
                       for k, v in est.items()} } GiB")

        ca = compiled.cost_analysis() or {}
        rec["xla_cost"] = {k: float(v) for k, v in ca.items()
                           if isinstance(v, (int, float))
                           and k in ("flops", "bytes accessed",
                                     "transcendentals")}
        if verbose:
            print(f"  cost_analysis: {rec['xla_cost']}")

        cap = 2 if cfg.dtype == "bfloat16" else None
        an = HloCostAnalyzer(compiled.as_text(), max_bytes_per_elem=cap)
        rep = an.entry_cost()
        rec["hlo"] = {
            "flops_per_device": rep.flops,
            "bytes_per_device": rep.bytes,
            "collective_bytes": dict(rep.collective_bytes),
            "collective_wire_bytes_total": rep.total_collective_bytes,
            "collective_count": rep.collective_count,
            "dtype_cap_bytes": cap,
        }
        # the three roofline terms (seconds, per chip)
        rec["roofline"] = {
            "compute_s": rep.flops / V5E["flops"],
            "memory_s": rep.bytes / V5E["hbm_bw"],
            "collective_s": rep.total_collective_bytes / V5E["ici_bw"],
        }
        rec["roofline"]["dominant"] = max(
            ("compute_s", "memory_s", "collective_s"),
            key=lambda k: rec["roofline"][k])
        rec["status"] = "ok"
    except Exception as e:                                    # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return _finish(rec, out_dir, verbose)


def _finish(rec: Dict, out_dir: Optional[str], verbose: bool) -> Dict:
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        s = rec["status"].upper()
        extra = ""
        if rec["status"] == "ok":
            gb = rec["memory"]["total_bytes"] / 2**30
            extra = (f" mem/dev={gb:.2f}GiB"
                     f" fits={rec['memory']['fits_16GB']}"
                     f" colls={rec['hlo']['collective_count']}")
        elif rec["status"] == "error":
            extra = " " + rec["error"][:160]
        elif rec["status"] == "skipped":
            extra = " (" + rec["reason"][:60] + ")"
        print(f"[{s}] {rec['arch']} x {rec['shape']} x {rec['mesh']}{extra}",
              flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=list(ASSIGNED_ARCHS))
    ap.add_argument("--shape", nargs="*", default=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel activation sharding")
    ap.add_argument("--int8-kv", action="store_true",
                    help="quantized int8 KV cache (beyond-paper opt)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    cells = [(a, s, m) for a in args.arch for s in args.shape
             for m in meshes]
    if args.list:
        for c in cells:
            print(*c)
        return

    n_ok = n_err = n_skip = 0
    t0 = time.time()
    for arch, shape, mesh_kind in cells:
        rec = run_cell(arch, shape, mesh_kind,
                       sequence_parallel=False if args.no_sp else None,
                       kv_int8=args.int8_kv,
                       out_dir=args.out, verbose=True)
        n_ok += rec["status"] == "ok"
        n_err += rec["status"] == "error"
        n_skip += rec["status"] == "skipped"
    print(f"\ndone in {time.time()-t0:.0f}s: {n_ok} ok, {n_skip} skipped "
          f"(documented), {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
