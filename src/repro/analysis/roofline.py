"""Roofline table builder — reads dry-run JSON records, emits Markdown.

Per (arch x shape x mesh) cell:

    compute_s    = HLO_FLOPs_per_device / 197 TF/s
    memory_s     = HLO_bytes_per_device / 819 GB/s
    collective_s = collective_wire_bytes_per_device / 50 GB/s per link

(sources: the trip-count-aware HLO analyzer over ``compiled.as_text()``;
methodology caveats documented in EXPERIMENTS.md §Roofline).

Also derived:
    MODEL_FLOPS  = 6*N*D for train (N = params — active params for MoE),
                   2*N*D for prefill, 2*N*batch for one decode step
    useful ratio = MODEL_FLOPS / (HLO_FLOPs_per_device * chips)
    roofline fraction = dominant_term / sum-of-terms (how balanced) and
    bound = the dominant term
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    s = SHAPES[shape]
    n = cfg.active_param_count()
    if s.kind == "train":
        return 6.0 * n * s.batch * s.seq
    if s.kind == "prefill":
        return 2.0 * n * s.batch * s.seq
    return 2.0 * n * s.batch            # one decode step


def ideal_seconds(arch: str, shape: str, chips: int) -> Dict[str, float]:
    """Irreducible per-chip time: the roofline floor for this cell.

    compute: MODEL_FLOPS at MXU peak.
    memory:  the bytes the algorithm MUST move per step —
      decode:  params (weights read once) + KV cache read
      prefill: params + 2x cache (compute + write K/V)
      train:   3x params (fwd read, bwd read, update write) + grad buffer
               r/w + 2x remat-saved activations (write fwd, read bwd)
    The roofline fraction reported in EXPERIMENTS.md is
    max(ideal_compute, ideal_memory) / dominant_term — 100% means the
    dominant term is at its floor.
    """
    from repro.models.config import kv_cache_bytes
    cfg = get_config(arch)
    s = SHAPES[shape]
    dt = cfg.dtype_bytes()
    p_bytes = cfg.param_count() * dt
    if s.kind == "decode":
        cache = kv_cache_bytes(cfg, s.batch, s.seq)
        mem = p_bytes + cache
    elif s.kind == "prefill":
        cache = kv_cache_bytes(cfg, s.batch, s.seq)
        mem = p_bytes + 2 * cache
    else:
        tokens = s.batch * s.seq
        saved = cfg.n_layers * tokens * cfg.d_model * dt
        mem = 3 * p_bytes + 2 * cfg.param_count() * 4 + 2 * saved
    comp = model_flops(arch, shape) / (chips * PEAK_FLOPS)
    return {"compute": comp, "memory": mem / (chips * HBM_BW),
            "floor": max(comp, mem / (chips * HBM_BW))}


def load_records(out_dir: str) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def enrich(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = 1
    for v in rec["mesh_shape"].values():
        chips *= v
    hlo = rec["hlo"]
    terms = {
        "compute_s": hlo["flops_per_device"] / PEAK_FLOPS,
        "memory_s": hlo["bytes_per_device"] / HBM_BW,
        "collective_s": hlo["collective_wire_bytes_total"] / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    total = sum(terms.values())
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total_flops = hlo["flops_per_device"] * chips
    step_bound_s = max(terms.values())
    ideal = ideal_seconds(rec["arch"], rec["shape"], chips)
    return {
        **rec,
        "chips": chips,
        "terms": terms,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(hlo_total_flops, 1e-30),
        "ideal": ideal,
        "roofline_fraction": ideal["floor"] / max(step_bound_s, 1e-30),
        "bound_s": step_bound_s,
        "balance": step_bound_s / max(total, 1e-30),
    }


_FIX_HINTS = {
    ("memory_s", "decode"): "decode is HBM-bound as expected; int8 KV/"
        "weights or larger batch raise arithmetic intensity",
    ("memory_s", "train"): "fuse/remat to cut activation re-reads; check "
        "redundant layout changes in the HLO",
    ("memory_s", "prefill"): "larger attention chunk or flash kernel to cut "
        "score-tensor traffic",
    ("compute_s", "train"): "compute-bound — good; raise MFU via larger "
        "microbatch or kernel fusion",
    ("compute_s", "prefill"): "compute-bound — good; MXU-aligned tiles",
    ("compute_s", "decode"): "unusual for decode: look for dense recompute "
        "of unused logits or capacity-padded MoE",
    ("collective_s", "train"): "shift TP collectives to reduce-scatter/"
        "all-gather (SP), overlap with compute, or rebalance TP vs DP",
    ("collective_s", "prefill"): "sequence-parallel attention or fewer "
        "all-gathers of KV",
    ("collective_s", "decode"): "TP all-reduces dominate tiny decode "
        "matmuls: batch heads per collective / widen DP",
}


def fix_hint(dominant: str, shape: str) -> str:
    kind = SHAPES[shape].kind
    return _FIX_HINTS.get((dominant, kind), "")


def markdown_table(recs: List[Dict], mesh: str = "single") -> str:
    rows = []
    head = ("| arch | shape | compute_s | memory_s | collective_s | "
            "dominant | MODEL_FLOPS/HLO | roofline-frac | note |")
    sep = "|" + "---|" * 9
    rows.append(head)
    rows.append(sep)
    for r in recs:
        e = enrich(r) if r.get("status") == "ok" else None
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                        f"| — | skipped: {r['reason'][:50]} |")
            continue
        if e is None:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                        f"| — | ERROR |")
            continue
        t = e["terms"]
        rows.append(
            f"| {e['arch']} | {e['shape']} "
            f"| {t['compute_s']*1e3:.2f}ms | {t['memory_s']*1e3:.2f}ms "
            f"| {t['collective_s']*1e3:.2f}ms "
            f"| {e['dominant'].replace('_s','')} "
            f"| {e['useful_flops_ratio']:.2f} "
            f"| {e['roofline_fraction']:.2%} "
            f"| {fix_hint(e['dominant'], e['shape'])[:60]} |")
    return "\n".join(rows)


def dryrun_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | status | mem/dev (analytic) | fits "
            "| colls | compile_s |", "|" + "---|" * 8]
    for r in recs:
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skip | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | — | — | — | — |")
            continue
        an = r["memory"].get("analytic", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {an.get('total', 0)/2**30:.2f} GiB "
            f"| {'yes' if r['memory'].get('fits_16GB') else 'NO'} "
            f"| {r['hlo']['collective_count']} | {r.get('compile_s')} |")
    return "\n".join(rows)
