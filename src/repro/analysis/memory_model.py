"""Analytic per-device TPU HBM footprint for a (config, shape, mesh) cell.

``compiled.memory_analysis()`` on the CPU backend structurally overstates
the TPU footprint of the same program: XLA:CPU (a) materializes fp32
shadows of every bf16 weight/cache (no native bf16 GEMM) and (b) "widens"
loop-local buffers across iterations (``wide.*`` computations), e.g.
stacking all grad-accum microbatches' remat buffers.  Neither transform
exists in the TPU lowering, so the dry-run records BOTH the raw CPU
numbers and this analytic estimate (formula below, fully determined by
config + sharding specs):

  train:   params + grads(fp32, param-sharded) + optimizer moments
           + remat-saved layer inputs (one per scanned layer, microbatch
             tokens, sharded per the activation rules) x 2 (double buffer)
           + attention workspace (fp32 score chunk x 2)
           + logits buffer (micro tokens x vocab shard, fp32 x 2)
  serve:   params + cache + attention workspace + logits
  all:     x 1.25 slack for fragmentation/fusion temporaries

Exactness: parameter/optimizer/cache terms are exact (leaf-by-leaf bytes
divided by their PartitionSpec shard factors); activation terms are a
model, cross-checked against small-config compiled footprints in
tests/test_roofline.py.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import specs as SP
from repro.distributed.shardings import ShardingRules
from repro.models.config import ModelConfig, kv_cache_bytes


def _shard_factor(spec: P, rules: ShardingRules) -> int:
    f = 1
    for part in spec:
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        for a in axes:
            f *= rules.mesh_shape.get(a, 1)
    return f


def tree_bytes_per_device(shapes, specs, rules: ShardingRules) -> int:
    flat_s = jax.tree_util.tree_leaves(shapes)
    flat_p = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    total = 0
    for leaf, spec in zip(flat_s, flat_p):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n * leaf.dtype.itemsize // max(_shard_factor(spec, rules), 1)
    return total


def estimate(cfg: ModelConfig, *, kind: str, batch: int, seq: int,
             rules: ShardingRules, accum: int = 1, accum_dtype_bytes: int = 4,
             param_shapes=None, param_spec=None,
             opt_shapes=None, opt_spec=None,
             cache_shapes=None, cache_spec=None) -> Dict[str, float]:
    ms = rules.mesh_shape.get("model", 1)
    batch_shards = 1
    for a in ("pod", "data"):
        batch_shards *= rules.mesh_shape.get(a, 1)
    dt = cfg.dtype_bytes()

    out: Dict[str, float] = {}
    if param_shapes is not None:
        out["params"] = tree_bytes_per_device(param_shapes, param_spec, rules)
    if opt_shapes is not None:
        out["optimizer"] = tree_bytes_per_device(opt_shapes, opt_spec, rules)
    if cache_shapes is not None:
        out["cache"] = tree_bytes_per_device(cache_shapes, cache_spec, rules)

    d, v = cfg.d_model, cfg.vocab_size
    hq_loc = max(cfg.n_heads // ms, 1) if cfg.n_heads else 1
    v_loc = v // ms if v % ms == 0 else v

    if kind == "train":
        micro_rows = max(batch // max(accum, 1), 1)
        rows_loc = max(micro_rows // batch_shards, 1)
        seq_shards = ms if (rules.table.get("seq") and seq % ms == 0) else 1
        tok_loc = rows_loc * (seq // seq_shards)
        n_saved = cfg.n_layers
        saved = n_saved * tok_loc * d * dt * 2          # x2 double buffer
        out["grads_accum"] = out.get("params", 0) * (accum_dtype_bytes / dt)
        chunk_q = min(1024, seq)
        attn_ws = rows_loc * hq_loc * chunk_q * seq * 4 * 2
        logits = tok_loc * v_loc * 4 * 2
        # per-layer live set during bwd: x, normed h, ff activations
        ff_loc = max(cfg.d_ff // ms, 1) if cfg.d_ff else cfg.d_inner // ms \
            if cfg.ssm_state else d
        layer_live = tok_loc * (3 * d + 2 * ff_loc) * 4
        out["activations"] = saved + attn_ws + logits + layer_live
    else:
        rows_loc = max(batch // batch_shards, 1)
        attn_ws = rows_loc * hq_loc * min(1024, max(seq // 32, 1)) * 4 * 2 \
            if kind == "prefill" else rows_loc * hq_loc * seq * 4
        logits = rows_loc * v_loc * 4 * 2
        out["activations"] = attn_ws + logits

    # slack only on the modeled activation term; params/opt/cache/grads
    # are exact per-spec byte counts
    act = out.get("activations", 0.0)
    out["total"] = sum(v for k, v in out.items() if k != "activations") \
        + 1.5 * act
    out["activations"] = act
    out["fits_16GB"] = out["total"] <= 16e9
    return out
