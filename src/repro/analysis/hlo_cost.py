"""Trip-count-aware cost analysis of post-optimization HLO text.

XLA's built-in ``compiled.cost_analysis()`` visits a ``while`` body ONCE
(verified empirically: flops identical for scan length 7 vs 14), which
under-counts scanned-layer models by the layer count.  This analyzer parses
``compiled.as_text()`` (the SPMD-partitioned, per-device module) and:

  * counts matmul FLOPs from ``dot`` ops (2 * prod(result) * contracted),
    including dots inside fused computations and (for conv frontends)
    ``convolution`` ops;
  * approximates HBM traffic as operand+result bytes of top-level ops in
    each computation (post-fusion, each top-level op is ~one HBM
    round-trip; intra-fusion traffic is free, which is the point of
    fusion);
  * sums collective wire bytes with ring formulas on per-device shapes:
        all-reduce        2 * S * (n-1)/n
        all-gather        S_out * (n-1)/n
        reduce-scatter    S_in  * (n-1)/n
        all-to-all        S * (n-1)/n
        collective-permute S
  * multiplies every ``while`` body's cost by its trip count, extracted
    from the loop condition's comparison constant (lax.scan emits
    ``compare(iter, constant(N)), direction=LT``); nested loops multiply.

All numbers are PER DEVICE (the module is the per-device SPMD program).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str, cap: Optional[int] = None) -> int:
    """bytes of 'f32[32,256]{1,0}' or tuple '(f32[2], s32[])'.

    ``cap`` bounds bytes-per-element: XLA:CPU upcasts bf16 weights/caches
    to fp32 shadows (no native bf16 GEMM), which a TPU lowering would not
    do — analyses of bf16 models pass cap=2 so traffic reflects the
    program as designed.  (Genuinely-fp32 accumulators are then counted at
    2 B/elem; they are a rounding error next to weights/KV, and the
    uncapped number is strictly more wrong.  Methodology note in
    EXPERIMENTS.md §Roofline.)
    """
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        by = _DTYPE_BYTES[dt]
        if cap is not None and by > cap and dt in ("f32", "f64", "bf16",
                                                   "f16"):
            by = cap
        total += n * by
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    op: str
    args: List[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]


@dataclasses.dataclass
class CostReport:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_count: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def scaled(self, k: float) -> "CostReport":
        return CostReport(
            flops=self.flops * k, bytes=self.bytes * k,
            collective_bytes={kk: v * k
                              for kk, v in self.collective_bytes.items()},
            collective_count=int(self.collective_count * k))

    def add(self, other: "CostReport") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v
        self.collective_count += other.collective_count


# result type is either a tuple "(...)" — which may contain /*index=N*/
# comments and layout braces, but never nested parens — or a plain shape
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"(\([^()]*\)|[\w\[\]\{\},\s/*]+?)\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*\))?\s*->.*{",
                          stripped)
        if header and not stripped.startswith("//"):
            cur = Computation(header.group(1), [])
            comps[cur.name] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op, args, tail = m.groups()
        cur.instructions.append(Instruction(
            name=name, type_str=type_str.strip(), op=op,
            args=[a.strip().lstrip("%") for a in _split_args(args)],
            raw=line))
    return comps


def _split_args(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [a for a in (x.strip() for x in out) if a]


def _dot_flops(instr: Instruction, symtab: Dict[str, str]) -> float:
    # flops = 2 * prod(result_dims) * prod(contracted dims of lhs)
    res = _shape_elems(instr.type_str)
    mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.raw)
    lhs = instr.args[0].split(" ")[-1].lstrip("%") if instr.args else ""
    lhs_type = symtab.get(lhs, "")
    ms = _SHAPE_RE.search(lhs_type)
    if not ms or not mdims:
        return 2.0 * res            # fallback: treat as elementwise-ish
    dims = [int(d) for d in ms.group(2).split(",")] if ms.group(2) else []
    contracted = 1
    for di in (int(x) for x in mdims.group(1).split(",") if x):
        if di < len(dims):
            contracted *= dims[di]
    return 2.0 * res * contracted


def _conv_flops(instr: Instruction, symtab: Dict[str, str]) -> float:
    res = _shape_elems(instr.type_str)
    rhs = instr.args[1].split(" ")[-1].lstrip("%") if len(instr.args) > 1 \
        else ""
    k = _shape_elems(symtab.get(rhs, ""))
    return 2.0 * res * max(k, 1) ** 0.5   # rough; conv is negligible here


def _group_size(raw: str, default: int = 1) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", raw)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", raw)
    if m:
        return len(m.group(1).split(","))
    return default


def _trip_count(cond: Computation) -> int:
    consts = {}
    for ins in cond.instructions:
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*s32\[\]\s+"
                     r"constant\((\d+)\)", ins.raw)
        if m:
            consts[ins.name] = int(m.group(1))
    for ins in cond.instructions:
        if ins.op == "compare" and "direction=LT" in ins.raw:
            for a in ins.args:
                nm = a.split(" ")[-1].lstrip("%")
                if nm in consts:
                    return consts[nm]
    # fallback: any s32 constant in the condition
    if consts:
        return max(consts.values())
    return 1


class HloCostAnalyzer:
    def __init__(self, text: str, *, max_bytes_per_elem: Optional[int] = None):
        self.comps = parse_hlo(text)
        self.cap = max_bytes_per_elem
        self.symtab: Dict[str, str] = {}
        for c in self.comps.values():
            for ins in c.instructions:
                self.symtab[ins.name] = ins.type_str
        self._memo: Dict[str, CostReport] = {}
        self._memo_eff: Dict[str, Dict] = {}

    def _sb(self, type_str: str) -> int:
        return _shape_bytes(type_str, self.cap)

    _MOVEMENT_OPS = {"convert", "bitcast", "copy", "reshape", "transpose",
                     "select", "broadcast", "iota", "compare", "slice",
                     "concatenate", "pad", "tuple", "get-tuple-element",
                     "parameter", "constant", "dynamic-slice",
                     "dynamic-update-slice", "clamp", "and", "or", "not"}

    def _fusion_has_math(self, comp_name: str) -> bool:
        """False for movement-only fusions (dtype-shadow copies, layout
        shuffles, select-based in-place updates) — lowering artifacts of
        the CPU backend's aliasing/precision constraints that a TPU
        lowering of the same program performs in place.  Billed 0 bytes
        when the dtype cap is active; methodology in EXPERIMENTS.md."""
        key = "__math__" + comp_name
        if key in self._memo_eff:
            return self._memo_eff[key]
        comp = self.comps.get(comp_name)
        has = False
        if comp is not None:
            for ins in comp.instructions:
                if ins.op in ("fusion", "call"):
                    callee = self._called(ins.raw, "calls") or \
                        self._called(ins.raw, "to_apply")
                    if callee and self._fusion_has_math(callee):
                        has = True
                        break
                elif ins.op not in self._MOVEMENT_OPS:
                    # scalar index arithmetic (e.g. the s32 adds of a
                    # select-lowered in-place update) is not math traffic
                    big_res = _shape_elems(ins.type_str) > 4096
                    big_arg = any(
                        _shape_elems(self.symtab.get(
                            a.split(" ")[-1].lstrip("%"), "")) > 4096
                        for a in ins.args)
                    if big_res or big_arg:
                        has = True
                        break
        self._memo_eff[key] = has
        return has

    # ------------------------------------------------------------------
    def _called(self, raw: str, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w\.\-]+)", raw)
        return m.group(1) if m else None

    def _fusion_traffic(self, comp_name: str):
        """(result_override_bytes | None, {param_index: effective_bytes}).

        * a fusion operand whose every (convert/bitcast/copy-transparent)
          use is a ``dynamic-slice``/``gather`` only reads the sliced rows;
        * an operand that is the *target* of a root ``dynamic-update-slice``
          is updated in place: traffic = update size, and the fusion's
          result is billed at the update size too (the full-buffer result
          is aliased, not rewritten — XLA:CPU materializes an fp32 shadow
          here that a TPU lowering would not).
        """
        if comp_name in self._memo_eff:
            return self._memo_eff[comp_name]
        comp = self.comps.get(comp_name)
        result_override = None
        out: Dict[int, float] = {}
        if comp is not None:
            pname_by_idx: Dict[str, int] = {}
            for ins in comp.instructions:
                if ins.op == "parameter":
                    m = re.search(r"parameter\((\d+)\)", ins.raw)
                    if m:
                        pname_by_idx[ins.name] = int(m.group(1))
            transparent_of: Dict[str, str] = {}   # alias -> param name
            uses: Dict[str, List[Instruction]] = {}
            for ins in comp.instructions:
                srcs = set()
                for a in ins.args:
                    nm = a.split(" ")[-1].lstrip("%")
                    root = transparent_of.get(nm, nm)
                    if root in pname_by_idx:
                        srcs.add(root)
                        uses.setdefault(root, []).append(ins)
                if ins.op in ("convert", "bitcast", "copy") and len(srcs) == 1:
                    transparent_of[ins.name] = next(iter(srcs))
            root_ins = comp.instructions[-1] if comp.instructions else None
            for ins in comp.instructions:
                if "ROOT" in ins.raw:
                    root_ins = ins
            for pname, idx in pname_by_idx.items():
                us = uses.get(pname, [])
                sliced = [u for u in us
                          if u.op in ("dynamic-slice", "gather")]
                dus_target = [
                    u for u in us if u.op == "dynamic-update-slice"
                    and transparent_of.get(
                        u.args[0].split(" ")[-1].lstrip("%"),
                        u.args[0].split(" ")[-1].lstrip("%")) == pname]
                transparent_only = [u for u in us
                                    if u.op in ("convert", "bitcast", "copy")]
                other = [u for u in us if u not in sliced
                         and u not in dus_target
                         and u not in transparent_only]
                if us and not other and (sliced or dus_target):
                    eff = 0.0
                    for u in sliced:
                        eff += self._sb(u.type_str)
                    for u in dus_target:
                        upd = u.args[1].split(" ")[-1].lstrip("%") \
                            if len(u.args) > 1 else ""
                        eff += self._sb(self.symtab.get(upd, ""))
                    out[idx] = eff
            # walk back from ROOT through convert/bitcast/copy: a fused
            # in-place cache update may be wrapped in dtype converts
            defs = {i.name: i for i in comp.instructions}
            root_eff = root_ins
            seen = 0
            while root_eff is not None and \
                    root_eff.op in ("convert", "bitcast", "copy") and \
                    root_eff.args and seen < 8:
                nm = root_eff.args[0].split(" ")[-1].lstrip("%")
                root_eff = defs.get(nm)
                seen += 1
            if root_eff is not None and \
                    root_eff.op == "dynamic-update-slice":
                upd = root_eff.args[1].split(" ")[-1].lstrip("%") \
                    if len(root_eff.args) > 1 else ""
                result_override = float(self._sb(self.symtab.get(upd, "")))
        self._memo_eff[comp_name] = (result_override, out)
        return result_override, out

    def _fusion_flops(self, comp_name: str) -> float:
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        fl = 0.0
        for ins in comp.instructions:
            if ins.op == "dot":
                fl += _dot_flops(ins, self.symtab)
            elif ins.op == "convolution":
                fl += _conv_flops(ins, self.symtab)
            elif ins.op in ("fusion", "call"):
                callee = self._called(ins.raw, "calls") or \
                    self._called(ins.raw, "to_apply")
                if callee:
                    fl += self._fusion_flops(callee)
        return fl

    def cost_of(self, comp_name: str) -> CostReport:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        rep = CostReport()
        if comp is None:
            return rep
        self._memo[comp_name] = rep     # cycle guard
        skip_bytes_ops = {"parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast", "iota"}
        for ins in comp.instructions:
            if ins.op == "while":
                body = self._called(ins.raw, "body")
                cond = self._called(ins.raw, "condition")
                trips = _trip_count(self.comps[cond]) if cond in self.comps \
                    else 1
                if body:
                    inner = self.cost_of(body)
                    rep.add(inner.scaled(max(trips, 1)))
                continue
            if ins.op == "conditional":
                for branch in re.findall(r"(?:true_computation|"
                                         r"false_computation|branch_\w+)="
                                         r"%?([\w\.\-]+)", ins.raw):
                    rep.add(self.cost_of(branch))
                continue
            if ins.op in ("call", "async-start"):
                callee = self._called(ins.raw, "to_apply") or \
                    self._called(ins.raw, "calls")
                if callee:
                    rep.add(self.cost_of(callee))

            # flops
            if ins.op == "dot":
                rep.flops += _dot_flops(ins, self.symtab)
            elif ins.op == "convolution":
                rep.flops += _conv_flops(ins, self.symtab)
            elif ins.op == "fusion":
                callee = self._called(ins.raw, "calls")
                if callee:
                    rep.flops += self._fusion_flops(callee)

            # collectives (wire bytes, per device)
            opn = ins.op.replace("-start", "")
            if opn in _COLLECTIVES:
                n = _group_size(ins.raw, 1)
                if n > 1:
                    if opn == "all-reduce":
                        size = sum(self._sb(self.symtab.get(a.split(" ")
                                   [-1].lstrip("%"), "")) for a in ins.args)
                        wire = 2.0 * size * (n - 1) / n
                    elif opn == "all-gather":
                        size = self._sb(ins.type_str)
                        wire = size * (n - 1) / n
                    elif opn in ("reduce-scatter", "all-to-all"):
                        size = sum(self._sb(self.symtab.get(a.split(" ")
                                   [-1].lstrip("%"), "")) for a in ins.args)
                        wire = size * (n - 1) / n
                    else:  # collective-permute
                        size = self._sb(ins.type_str)
                        wire = float(size)
                    rep.collective_bytes[opn] = \
                        rep.collective_bytes.get(opn, 0.0) + wire
                    rep.collective_count += 1

            # memory traffic (slice-aware: in-place cache updates and
            # gathers bill only the rows they touch)
            if ins.op in skip_bytes_ops or ins.op.endswith("-done") \
                    or ins.op == "while":
                continue
            if ins.op == "dynamic-update-slice":
                upd = ins.args[1].split(" ")[-1].lstrip("%") \
                    if len(ins.args) > 1 else ""
                rep.bytes += 2.0 * self._sb(self.symtab.get(upd, ""))
                continue
            if ins.op in ("dynamic-slice", "gather"):
                rep.bytes += 2.0 * self._sb(ins.type_str)
                continue
            if self.cap is not None and ins.op in ("copy", "transpose",
                                                   "convert", "select",
                                                   "reshape"):
                continue          # movement artifact (see _fusion_has_math)
            if ins.op == "fusion":
                callee = self._called(ins.raw, "calls")
                if self.cap is not None and callee \
                        and not self._fusion_has_math(callee):
                    # movement-only fusion: bill the sliced flows only
                    _, eff_only = self._fusion_traffic(callee)
                    rep.bytes += sum(eff_only.values())
                    continue
                override, eff = self._fusion_traffic(callee) if callee \
                    else (None, {})
                b = override if override is not None \
                    else self._sb(ins.type_str)
                for i, a in enumerate(ins.args):
                    nm = a.split(" ")[-1].lstrip("%")
                    if i in eff:
                        b += eff[i]
                    else:
                        b += self._sb(self.symtab.get(nm, ""))
                rep.bytes += b
                continue
            b = self._sb(ins.type_str)
            for a in ins.args:
                nm = a.split(" ")[-1].lstrip("%")
                b += self._sb(self.symtab.get(nm, ""))
            rep.bytes += b
        return rep

    def entry_cost(self) -> CostReport:
        # ENTRY computation: jax names it e.g. 'main.123' / first parsed
        for name in self.comps:
            if name.startswith("main"):
                return self.cost_of(name)
        first = next(iter(self.comps))
        return self.cost_of(first)


def analyze_compiled(compiled, *, max_bytes_per_elem=None) -> CostReport:
    return HloCostAnalyzer(compiled.as_text(),
                           max_bytes_per_elem=max_bytes_per_elem).entry_cost()
