"""PRNG discipline lint: request-owned keys only in serving code.

PR 3's request-level sampling made paged-vs-dense decode token-identical
under stochastic sampling *because* keys are derived per request
(`sampling.request_key`) and per emitted token (`sampling.step_key`) —
never from the batch row, the step counter, or an ad-hoc
``jax.random.PRNGKey`` minted mid-path.  A raw ``PRNGKey``/``split`` in
serving code re-introduces schedule-dependent randomness: the same
request sampled through a different slot or batch shape would draw
different tokens.

This lint flags ``jax.random.PRNGKey(...)`` and ``jax.random.split(...)``
calls in ``src/repro/serving`` outside ``sampling.py`` (the key
authority).  ``fold_in`` is allowed — deriving a subkey from a
request-owned key is exactly the sanctioned pattern.  Front-door seeds
(`LLM(seed=)` creating the one base key that ``request_key`` folds
request ids into) carry `# lint: allow[prng-discipline]` suppressions.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional

from .diagnostics import Finding

RULE = "prng-discipline"

# the module allowed to mint and split keys
KEY_AUTHORITY = "sampling.py"


def scope_files(root: Path) -> List[str]:
    return sorted(
        str(p.relative_to(root).as_posix())
        for p in (root / "src/repro/serving").glob("*.py")
        if p.name != KEY_AUTHORITY)


def _random_aliases(tree: ast.Module) -> Dict[str, str]:
    """Names that refer to jax.random or its members in this module."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax.random":
                    out[a.asname or "jax"] = "jax.random"
        elif isinstance(node, ast.ImportFrom):
            if node.module == "jax":
                for a in node.names:
                    if a.name == "random":
                        out[a.asname or "random"] = "jax.random"
            elif node.module == "jax.random":
                for a in node.names:
                    if a.name in ("PRNGKey", "split"):
                        out[a.asname or a.name] = f"jax.random.{a.name}"
    return out


def _flagged_call(node: ast.Call, aliases: Dict[str, str]) -> Optional[str]:
    f = node.func
    if isinstance(f, ast.Attribute) and f.attr in ("PRNGKey", "split"):
        v = f.value
        # jax.random.X
        if isinstance(v, ast.Attribute) and v.attr == "random" and \
                isinstance(v.value, ast.Name) and v.value.id == "jax":
            return f.attr
        # jr.X where jr aliases jax.random
        if isinstance(v, ast.Name) and aliases.get(v.id) == "jax.random":
            return f.attr
        # anything.PRNGKey is distinctive enough to flag regardless
        if f.attr == "PRNGKey":
            return f.attr
    if isinstance(f, ast.Name) and \
            aliases.get(f.id, "").startswith("jax.random."):
        return aliases[f.id].rsplit(".", 1)[-1]
    return None


def check_prng(root: Path, files: Optional[List[str]] = None) \
        -> List[Finding]:
    files = files if files is not None else scope_files(root)
    findings: List[Finding] = []
    for rel in files:
        tree = ast.parse((root / rel).read_text(), filename=rel)
        aliases = _random_aliases(tree)
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                what = _flagged_call(node, aliases)
                if what:
                    findings.append(Finding(
                        RULE, rel, node.lineno,
                        f"raw jax.random.{what} in serving code — keys "
                        f"must flow from sampling.request_key/step_key "
                        f"so results are schedule-independent"))
    return findings
