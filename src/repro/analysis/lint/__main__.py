"""Driver: ``python -m repro.analysis.lint [--strict] [--json]
[--changed-only]``.

Runs every rule, applies `# lint: allow[...]` suppressions, renders
human or JSON output, and exits 0 (clean) / 1 (findings) / 2 (analyzer
crash) — the contract tools/ci.sh gates on.  ``--changed-only`` scopes
the AST lints to files changed vs HEAD (plus untracked) and skips the
kernel checker unless a kernel or analyzer file changed, keeping the
iterative loop fast.
"""

from __future__ import annotations

import argparse
import subprocess
import sys
import traceback
from pathlib import Path
from typing import List, Optional, Set

from . import hotpath, kernel_check, locks, prng, telemetry_sync
from .diagnostics import (REPO_ROOT, Finding, SuppressionIndex, exit_code,
                          render_human, render_json)


def _changed_files(root: Path) -> Set[str]:
    changed: Set[str] = set()
    try:
        diff = subprocess.run(
            ["git", "diff", "--name-only", "HEAD"],
            cwd=root, capture_output=True, text=True, check=True)
        changed |= {l.strip() for l in diff.stdout.splitlines() if l.strip()}
        status = subprocess.run(
            ["git", "status", "--porcelain"],
            cwd=root, capture_output=True, text=True, check=True)
        changed |= {l[3:].strip() for l in status.stdout.splitlines()
                    if l[:2].strip() and len(l) > 3}
    except (subprocess.CalledProcessError, FileNotFoundError):
        return set()        # not a git checkout: fall back to full scan
    return changed


def run(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis.lint")
    ap.add_argument("--strict", action="store_true",
                    help="warnings (e.g. bare suppressions) also fail")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings on stdout")
    ap.add_argument("--changed-only", action="store_true",
                    help="scope to files changed vs HEAD (git)")
    ap.add_argument("--root", type=Path, default=REPO_ROOT,
                    help="repository root (default: auto-detected)")
    ap.add_argument("--skip-kernels", action="store_true",
                    help="skip the (import-heavy) Pallas kernel checker")
    args = ap.parse_args(argv)
    root = args.root.resolve()

    changed = _changed_files(root) if args.changed_only else None

    def _scoped(files: List[str]) -> List[str]:
        if changed is None:
            return files
        return [f for f in files if f in changed]

    findings: List[Finding] = []
    try:
        run_kernels = not args.skip_kernels
        if run_kernels and changed is not None:
            run_kernels = any(
                f.startswith(("src/repro/kernels/", "src/repro/analysis/"))
                for f in changed)
        if run_kernels:
            findings += kernel_check.check_kernels()

        hp_files = _scoped(hotpath.scope_files(root))
        if changed is None or hp_files:
            # the call graph needs the full scope even when only some
            # files changed; findings are filtered to the changed set
            hp = hotpath.check_hotpath(root)
            if changed is not None:
                hp = [f for f in hp if f.path in changed]
            findings += hp
        findings += prng.check_prng(root, _scoped(prng.scope_files(root)))
        findings += locks.check_locks(root, _scoped(locks.scope_files(root)))
        ts_files = _scoped(telemetry_sync.scope_files(root))
        if changed is None or ts_files:
            # same full-scope / filtered-findings contract as hotpath
            ts = telemetry_sync.check_telemetry(root)
            if changed is not None:
                ts = [f for f in ts if f.path in changed]
            findings += ts
    except Exception:
        traceback.print_exc()
        print("lint: internal error (exit 2)", file=sys.stderr)
        return 2

    findings = SuppressionIndex(root).apply(findings)
    print(render_json(findings) if args.as_json else render_human(findings))
    return exit_code(findings, strict=args.strict)


if __name__ == "__main__":
    sys.exit(run())
