"""Static checker for the Pallas kernels in `repro.kernels`.

The Pallas invariants that end-to-end tests only probabilistically catch:

* **kernel-grid-bounds** — every `BlockSpec` index map, evaluated at every
  grid point (with the scalar-prefetch operands it dereferences, e.g. the
  paged block table), must return block indices inside the operand.  An
  off-by-one in a page index map reads another sequence's KV.
* **kernel-tile-alignment** — block shapes should fill TPU tiles: the
  lane (last) dim a multiple of 128 or the whole operand extent; the
  sublane dim 1, a multiple of the dtype's minimum sublane count
  (fp32 8, bf16 16, int8/fp8 32), or the whole extent.
* **kernel-dtype** — index maps must return integers and scalar-prefetch
  operands must be integer arrays (a float block table would silently
  truncate).
* **kernel-scalar-arity** — the kernel body's positional parameter count
  must equal num_scalar_prefetch + inputs + outputs + scratch; a drifted
  signature binds the wrong ref to the wrong operand.

Nothing here executes a kernel.  ``pl.pallas_call`` and
``pltpu.PrefetchScalarGridSpec`` are temporarily replaced with recorders:
the harnesses below call each public kernel entry with small
representative inputs (block tables are permutations that include the
maximum page id, so the full physical range is exercised), the recorder
captures (grid, specs, operands, out_shape, kernel) and returns zeros of
the declared output shape, and the checks above run on the capture.
"""

from __future__ import annotations

import functools
import inspect
import math
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from .diagnostics import Finding, relpath

RULE_BOUNDS = "kernel-grid-bounds"
RULE_ALIGN = "kernel-tile-alignment"
RULE_DTYPE = "kernel-dtype"
RULE_ARITY = "kernel-scalar-arity"

_GRID_POINT_CAP = 200_000

# minimum sublane count for a full TPU tile, by dtype itemsize
_MIN_SUBLANE = {4: 8, 2: 16, 1: 32}
_LANE = 128


@dataclass
class CapturedCall:
    kernel: Callable
    grid: Tuple[int, ...]
    in_specs: List[Any]
    out_specs: List[Any]
    out_shapes: List[Any]            # ShapeDtypeStruct leaves
    out_is_seq: bool
    scratch_shapes: List[Any]
    num_scalar_prefetch: int
    operands: Tuple[Any, ...] = ()


class _Recorder:
    """Context manager that swaps pallas entry points for recorders."""

    def __init__(self):
        self.calls: List[CapturedCall] = []

    def __enter__(self):
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu
        self._pl, self._pltpu = pl, pltpu
        self._real_call = pl.pallas_call
        self._real_grid = pltpu.PrefetchScalarGridSpec
        calls = self.calls

        class _FakeGridSpec:
            def __init__(self, *, num_scalar_prefetch=0, grid=(),
                         in_specs=None, out_specs=None, scratch_shapes=None):
                self.num_scalar_prefetch = num_scalar_prefetch
                self.grid = grid
                self.in_specs = in_specs or []
                self.out_specs = out_specs
                self.scratch_shapes = scratch_shapes or []

        def _fake_call(kernel, *, grid_spec=None, out_shape=None, grid=None,
                       in_specs=None, out_specs=None, scratch_shapes=None,
                       interpret=False, **kw):
            import jax.numpy as jnp
            if grid_spec is not None:
                grid = grid_spec.grid
                in_specs = grid_spec.in_specs
                out_specs = grid_spec.out_specs
                scratch_shapes = grid_spec.scratch_shapes
                nsp = grid_spec.num_scalar_prefetch
            else:
                nsp = 0
            out_is_seq = isinstance(out_shape, (list, tuple))
            out_leaves = list(out_shape) if out_is_seq else [out_shape]
            o_specs = (list(out_specs) if isinstance(out_specs, (list, tuple))
                       else [out_specs])
            rec = CapturedCall(
                kernel=kernel, grid=tuple(grid), in_specs=list(in_specs),
                out_specs=o_specs, out_shapes=out_leaves,
                out_is_seq=out_is_seq,
                scratch_shapes=list(scratch_shapes or []),
                num_scalar_prefetch=nsp)

            def _runner(*operands):
                rec.operands = operands
                calls.append(rec)
                outs = [jnp.zeros(s.shape, s.dtype) for s in out_leaves]
                return outs if out_is_seq else outs[0]

            return _runner

        pl.pallas_call = _fake_call
        pltpu.PrefetchScalarGridSpec = _FakeGridSpec
        return self

    def __exit__(self, *exc):
        self._pl.pallas_call = self._real_call
        self._pltpu.PrefetchScalarGridSpec = self._real_grid
        return False


# ---------------------------------------------------------------------------
# checks over one captured call
# ---------------------------------------------------------------------------

def _unwrap(fn: Callable) -> Callable:
    fn = inspect.unwrap(fn)
    while isinstance(fn, functools.partial):
        fn = inspect.unwrap(fn.func)
    return fn


def _anchor(fn: Callable) -> Tuple[str, int]:
    f = _unwrap(fn)
    code = getattr(f, "__code__", None)
    if code is None:
        return "<unknown>", 0
    return relpath(code.co_filename), code.co_firstlineno


def _is_int(v) -> bool:
    if isinstance(v, (bool, np.bool_)):
        return False
    if isinstance(v, (int, np.integer)):
        return True
    arr = np.asarray(v)
    return arr.ndim == 0 and np.issubdtype(arr.dtype, np.integer)


def _grid_points(grid: Tuple[int, ...]):
    total = math.prod(grid) if grid else 0
    if total <= _GRID_POINT_CAP:
        yield from np.ndindex(*grid)
        return
    # degenerate fallback: corners only (never hit by the repo's kernels)
    corners = [(0, g - 1) for g in grid]
    seen = set()
    for combo in np.ndindex(*([2] * len(grid))):
        pt = tuple(corners[d][c] for d, c in enumerate(combo))
        if pt not in seen:
            seen.add(pt)
            yield pt


def _spec_fields(spec) -> Tuple[Optional[Tuple], Optional[Callable]]:
    if spec is None:
        return None, None
    block = getattr(spec, "block_shape", None)
    imap = getattr(spec, "index_map", None)
    return block, imap


def _check_alignment(spec, operand_shape, dtype, label: str,
                     findings: List[Finding]) -> None:
    block, imap = _spec_fields(spec)
    if not block:
        return
    path, line = _anchor(imap) if imap is not None else ("<unknown>", 0)
    itemsize = np.dtype(dtype).itemsize
    min_sub = _MIN_SUBLANE.get(itemsize, 8)
    lane = block[-1]
    if lane is not None:
        ext = operand_shape[-1]
        if not (lane % _LANE == 0 or lane == ext):
            findings.append(Finding(
                RULE_ALIGN, path, line,
                f"{label}: lane dim {lane} of block {tuple(block)} is "
                f"neither a multiple of {_LANE} nor the operand extent "
                f"{ext} (partial lanes waste VREGs)"))
    if len(block) >= 2 and block[-2] is not None:
        sub, ext = block[-2], operand_shape[-2]
        if not (sub == 1 or sub % min_sub == 0 or sub == ext):
            findings.append(Finding(
                RULE_ALIGN, path, line,
                f"{label}: sublane dim {sub} of block {tuple(block)} is not "
                f"1, a multiple of {min_sub} ({np.dtype(dtype).name} min "
                f"sublane), or the operand extent {ext}"))


def _check_call(rec: CapturedCall) -> List[Finding]:
    findings: List[Finding] = []
    nsp = rec.num_scalar_prefetch
    kpath, kline = _anchor(rec.kernel)
    kname = getattr(_unwrap(rec.kernel), "__name__", "<kernel>")

    scalar_ops = rec.operands[:nsp]
    array_ops = rec.operands[nsp:]
    if len(array_ops) != len(rec.in_specs):
        findings.append(Finding(
            RULE_ARITY, kpath, kline,
            f"{kname}: {len(array_ops)} array operands but "
            f"{len(rec.in_specs)} in_specs"))
        return findings

    # scalar-prefetch operands must be integer arrays
    scalars_np = []
    for i, op in enumerate(scalar_ops):
        arr = np.asarray(op)
        scalars_np.append(arr)
        if not np.issubdtype(arr.dtype, np.integer):
            findings.append(Finding(
                RULE_DTYPE, kpath, kline,
                f"{kname}: scalar-prefetch operand {i} has dtype "
                f"{arr.dtype}, expected an integer type"))

    # kernel signature arity: nsp + inputs + outputs + scratch refs
    sig = inspect.signature(rec.kernel)
    n_pos = sum(1 for p in sig.parameters.values()
                if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD))
    expected = nsp + len(rec.in_specs) + len(rec.out_shapes) \
        + len(rec.scratch_shapes)
    if n_pos != expected:
        findings.append(Finding(
            RULE_ARITY, kpath, kline,
            f"{kname}: takes {n_pos} positional refs but the call binds "
            f"{expected} (= {nsp} scalar-prefetch + {len(rec.in_specs)} in "
            f"+ {len(rec.out_shapes)} out + {len(rec.scratch_shapes)} "
            f"scratch)"))

    # pair each spec with its operand (outputs use the declared out_shape)
    pairs = [(spec, np.asarray(op).shape, np.asarray(op).dtype,
              f"{kname} in_specs[{i}]")
             for i, (spec, op) in enumerate(zip(rec.in_specs, array_ops))]
    pairs += [(spec, tuple(sh.shape), np.dtype(sh.dtype),
               f"{kname} out_specs[{i}]")
              for i, (spec, sh) in enumerate(zip(rec.out_specs,
                                                 rec.out_shapes))]

    for spec, shape, dtype, label in pairs:
        _check_alignment(spec, shape, dtype, label, findings)

    # grid-bounds: evaluate every index map at every grid point
    for spec, shape, dtype, label in pairs:
        block, imap = _spec_fields(spec)
        if imap is None or not block:
            continue
        path, line = _anchor(imap)
        blk = [b if b is not None else shape[d]
               for d, b in enumerate(block)]
        nblocks = [max(1, -(-shape[d] // blk[d])) for d in range(len(blk))]
        bad_dtype_reported = False
        for pt in _grid_points(rec.grid):
            idx = imap(*pt, *scalars_np)
            if not isinstance(idx, (tuple, list)):
                idx = (idx,)
            if len(idx) != len(blk):
                findings.append(Finding(
                    RULE_BOUNDS, path, line,
                    f"{label}: index map returned {len(idx)} indices for a "
                    f"rank-{len(blk)} block at grid point {tuple(pt)}"))
                break
            if not all(_is_int(v) for v in idx):
                if not bad_dtype_reported:
                    findings.append(Finding(
                        RULE_DTYPE, path, line,
                        f"{label}: index map returned non-integer indices "
                        f"{tuple(type(v).__name__ for v in idx)} at grid "
                        f"point {tuple(pt)}"))
                    bad_dtype_reported = True
                break
            vals = [int(v) for v in idx]
            oob = [d for d, v in enumerate(vals)
                   if not 0 <= v < nblocks[d]]
            if oob:
                d = oob[0]
                findings.append(Finding(
                    RULE_BOUNDS, path, line,
                    f"{label}: index map returns block index {vals[d]} on "
                    f"dim {d} at grid point {tuple(pt)}, valid range "
                    f"[0, {nblocks[d]}) for operand shape {shape} with "
                    f"block {tuple(blk)}"))
                break
    return findings


def findings_for_callable(fn: Callable, *args, **kwargs) -> List[Finding]:
    """Run `fn` under the recorder and check every pallas_call it makes.

    The analyzer's own tests use this to check fixture kernels; the tree
    checker below uses it for each harness.
    """
    with _Recorder() as rec:
        fn(*args, **kwargs)
    out: List[Finding] = []
    for call in rec.calls:
        out.extend(_check_call(call))
    return out


# ---------------------------------------------------------------------------
# harnesses: one per kernel module, small shapes, full page-id coverage
# ---------------------------------------------------------------------------

def _h_paged_attention():
    import jax.numpy as jnp
    from repro.kernels import paged_attention as mod
    b, hq, hkv, d, ps, nb = 2, 4, 2, 64, 8, 3
    p = 1 + b * nb
    q = jnp.zeros((b, hq, d), jnp.float32)
    kp = jnp.zeros((p, hkv, ps, d), jnp.float32)
    # permutation of all non-trash pages: the map must handle page p-1
    bt = jnp.asarray(np.arange(1, p, dtype=np.int32)[::-1].reshape(b, nb))
    lens = jnp.asarray(np.array([20, 17], np.int32))
    mod.paged_decode_attention(q, kp, kp, bt, lens)
    ks = jnp.zeros((p, hkv, ps), jnp.float32)
    mod.paged_decode_attention(q, kp.astype(jnp.int8), kp.astype(jnp.int8),
                               bt, lens, k_scale=ks, v_scale=ks)


def _h_paged_prefill():
    import jax.numpy as jnp
    from repro.kernels import paged_prefill as mod
    b, hq, hkv, d, ps, nb, s = 2, 4, 2, 64, 8, 3, 16
    p = 1 + b * nb
    q = jnp.zeros((b, hq, s, d), jnp.float32)
    kp = jnp.zeros((p, hkv, ps, d), jnp.float32)
    bt = jnp.asarray(np.arange(1, p, dtype=np.int32)[::-1].reshape(b, nb))
    offs = jnp.asarray(np.array([8, 5], np.int32))
    mod.paged_prefill_attention(q, kp, kp, bt, offs, block_q=16)
    ks = jnp.zeros((p, hkv, ps), jnp.float32)
    mod.paged_prefill_attention(q, kp.astype(jnp.int8), kp.astype(jnp.int8),
                                bt, offs, block_q=16, k_scale=ks, v_scale=ks)


def _h_decode_attention():
    import jax.numpy as jnp
    from repro.kernels import decode_attention as mod
    b, hq, hkv, s, d = 2, 4, 2, 256, 64
    q = jnp.zeros((b, hq, d), jnp.float32)
    k = jnp.zeros((b, hkv, s, d), jnp.float32)
    lens = jnp.asarray(np.array([100, 256], np.int32))
    mod.decode_attention(q, k, k, lens, block_kv=128)
    sc = jnp.zeros((b, hkv, s), jnp.float32)
    mod.decode_attention(q, k.astype(jnp.int8), k.astype(jnp.int8), lens,
                         block_kv=128, k_scale=sc, v_scale=sc)


def _h_flash_attention():
    import jax.numpy as jnp
    from repro.kernels import flash_attention as mod
    b, hq, hkv, s, d = 1, 2, 1, 128, 64
    q = jnp.zeros((b, hq, s, d), jnp.float32)
    k = jnp.zeros((b, hkv, s, d), jnp.float32)
    mod.flash_attention(q, k, k)
    mod.flash_attention(q, k, k, causal=False, window=64)


def _h_hete_matmul():
    import jax.numpy as jnp
    from repro.kernels import hete_matmul as mod
    x = jnp.zeros((128, 256), jnp.float32)
    w = jnp.zeros((256, 128), jnp.float32)
    mod.matmul(x, w)
    mod.matmul(x, w, jnp.zeros((128,), jnp.float32), activation="gelu")
    mod.gated_matmul(x, w, w)


def _h_q8_matmul():
    import jax.numpy as jnp
    from repro.kernels import q8_matmul as mod
    x = jnp.zeros((128, 256), jnp.float32)
    q = jnp.zeros((256, 128), jnp.int8)
    mod.q8_matmul(x, q, jnp.zeros((128,), jnp.float32))
    # non-zero-scale epilogue: run the kernel on a real quantized weight
    # so the k == n_k-1 scale multiply is exercised, not just the zero path
    w = (jnp.arange(256 * 128, dtype=jnp.float32).reshape(256, 128)
         / (256 * 128) - 0.5)
    qw, scale = mod.quantize_weights(w)
    mod.q8_matmul(x + 1.0, qw, scale)


def _h_rmsnorm():
    import jax.numpy as jnp
    from repro.kernels import rmsnorm as mod
    mod.rmsnorm(jnp.zeros((16, 128), jnp.float32),
                jnp.zeros((128,), jnp.float32))


def _h_ssd_chunk():
    import jax.numpy as jnp
    from repro.kernels import ssd_chunk as mod
    bs, ln, h, p, n, chunk = 1, 16, 2, 64, 32, 8
    mod.ssd_chunk(jnp.zeros((bs, ln, h, p), jnp.float32),
                  jnp.zeros((bs, ln, h), jnp.float32),
                  jnp.zeros((h,), jnp.float32),
                  jnp.zeros((bs, ln, h, n), jnp.float32),
                  jnp.zeros((bs, ln, h, n), jnp.float32), chunk=chunk)


HARNESSES: List[Tuple[str, Callable[[], None]]] = [
    ("paged_attention", _h_paged_attention),
    ("paged_prefill", _h_paged_prefill),
    ("decode_attention", _h_decode_attention),
    ("flash_attention", _h_flash_attention),
    ("hete_matmul", _h_hete_matmul),
    ("q8_matmul", _h_q8_matmul),
    ("rmsnorm", _h_rmsnorm),
    ("ssd_chunk", _h_ssd_chunk),
]


def check_kernels(only: Optional[Sequence[str]] = None) -> List[Finding]:
    """Check every kernel module (or the named subset) and return findings."""
    out: List[Finding] = []
    for name, harness in HARNESSES:
        if only is not None and name not in only:
            continue
        out.extend(findings_for_callable(harness))
    return out
