"""Lock-discipline lint for the threaded serving/core classes.

`AsyncLLM` runs an event loop thread beside caller threads;
`AsyncParamManager` and the HeteGen engine fan work out to pinning /
CPU / transfer executors.  Any attribute those classes write from more
than one thread entry point must be written under the class's declared
lock, or the telemetry/handle maps race.

The analysis, per class in ``src/repro/serving`` + ``src/repro/core``:

* **declared locks** — ``self.X = threading.Lock()/RLock()/Condition()``
  in ``__init__``.  ``Condition(self.Y)`` aliases X to Y's lock (the
  canonical lock), so guarding with either name counts.
* **thread entry points** — methods handed to ``Thread(target=self.M)``
  or ``executor.submit(self.M, ...)``.  Classes with none are skipped:
  single-threaded objects need no locking.
* **shared attributes** — written (assignment, augmented assignment,
  subscript store, or a mutating method call like ``append``/``pop``/
  ``clear``) outside ``__init__`` by a thread entry point, or by two or
  more different methods.
* **the check** — every write to a shared attribute must be lexically
  under ``with self.<lock>``, or sit in a helper whose every call site
  in the class is itself lock-held (lock *inheritance*, computed to a
  fixpoint — this is how ``AsyncLLM._register`` is proven safe).
  Calls from ``__init__`` count as held: no second thread exists yet.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from .diagnostics import Finding

RULE = "lock-discipline"

_LOCK_CTORS = {"Lock", "RLock", "Condition"}
_MUTATORS = {"append", "appendleft", "pop", "popleft", "clear", "update",
             "extend", "add", "remove", "insert", "setdefault", "discard"}


def scope_files(root: Path) -> List[str]:
    rels: List[str] = []
    for sub in ("src/repro/serving", "src/repro/core"):
        rels += sorted(str(p.relative_to(root).as_posix())
                       for p in (root / sub).glob("*.py"))
    return rels


def _self_attr(node: ast.expr) -> Optional[str]:
    """Head attribute of a `self.`-rooted expression: self.a.b[c] -> a."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        parent = node.value
        if isinstance(parent, ast.Name) and parent.id == "self" and \
                isinstance(node, ast.Attribute):
            return node.attr
        node = parent
    return None


def _lock_ctor(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr in _LOCK_CTORS:
        return True
    return isinstance(f, ast.Name) and f.id in _LOCK_CTORS


@dataclass
class _Write:
    attr: str
    line: int
    guarded: bool
    method: str


@dataclass
class _CallSite:
    callee: str
    guarded: bool
    method: str


class _MethodScan(ast.NodeVisitor):
    """Collect self-attribute writes and self-method calls with their
    lexical `with self.<lock>` guard state."""

    def __init__(self, method: str, locks: Dict[str, str]):
        self.method = method
        self.locks = locks          # attr -> canonical lock attr
        self.guarded = False
        self.writes: List[_Write] = []
        self.calls: List[_CallSite] = []

    def _is_lock(self, expr: ast.expr) -> bool:
        a = _self_attr(expr) if isinstance(expr, ast.Attribute) else None
        return a is not None and a in self.locks

    def visit_With(self, node: ast.With) -> None:
        held = any(self._is_lock(item.context_expr) for item in node.items)
        prev = self.guarded
        self.guarded = self.guarded or held
        for stmt in node.body:
            self.visit(stmt)
        self.guarded = prev
        for item in node.items:
            self.visit(item.context_expr)

    def _record_write(self, target: ast.expr, line: int) -> None:
        attr = _self_attr(target)
        if attr is not None and attr not in self.locks:
            self.writes.append(
                _Write(attr, line, self.guarded, self.method))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record_write(t, node.lineno)
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record_write(node.target, node.lineno)
        self.visit(node.value)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._record_write(node.target, node.lineno)
        if node.value is not None:
            self.visit(node.value)

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute):
            head = _self_attr(f.value) if isinstance(
                f.value, (ast.Attribute, ast.Subscript)) else None
            if f.attr in _MUTATORS and head is not None:
                self.writes.append(
                    _Write(head, node.lineno, self.guarded, self.method))
            if isinstance(f.value, ast.Name) and f.value.id == "self":
                self.calls.append(
                    _CallSite(f.attr, self.guarded, self.method))
        self.generic_visit(node)


def _thread_targets(cls: ast.ClassDef) -> Set[str]:
    """Methods handed to Thread(target=self.M) / executor.submit(self.M)."""
    targets: Set[str] = set()

    def _self_method(expr) -> Optional[str]:
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and expr.value.id == "self":
            return expr.attr
        return None

    for node in ast.walk(cls):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        fname = f.attr if isinstance(f, ast.Attribute) else \
            f.id if isinstance(f, ast.Name) else None
        if fname == "Thread":
            for kw in node.keywords:
                if kw.arg == "target":
                    m = _self_method(kw.value)
                    if m:
                        targets.add(m)
        elif fname == "submit" and node.args:
            m = _self_method(node.args[0])
            if m:
                targets.add(m)
    return targets


def _declared_locks(cls: ast.ClassDef) -> Dict[str, str]:
    """attr -> canonical lock attr, for locks assigned in __init__."""
    locks: Dict[str, str] = {}
    init = next((n for n in cls.body
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                 and n.name == "__init__"), None)
    if init is None:
        return locks
    for node in ast.walk(init):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        attr = _self_attr(node.targets[0])
        if attr is None or not isinstance(node.value, ast.Call) or \
                not _lock_ctor(node.value):
            continue
        canonical = attr
        # Condition(self.Y): reuse Y's canonical lock
        if node.value.args:
            arg_attr = _self_attr(node.value.args[0])
            if arg_attr is not None and arg_attr in locks:
                canonical = locks[arg_attr]
        locks[attr] = canonical
    return locks


def _check_class(rel: str, cls: ast.ClassDef) -> List[Finding]:
    locks = _declared_locks(cls)
    targets = _thread_targets(cls)
    if not locks or not targets:
        return []                   # single-threaded or lock-free class

    methods = [n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    scans: Dict[str, _MethodScan] = {}
    for m in methods:
        scan = _MethodScan(m.name, locks)
        for stmt in m.body:
            scan.visit(stmt)
        scans[m.name] = scan

    # shared = written by a thread entry point, or by >= 2 methods
    writers: Dict[str, Set[str]] = {}
    for name, scan in scans.items():
        if name == "__init__":
            continue
        for w in scan.writes:
            writers.setdefault(w.attr, set()).add(name)
    shared = {attr for attr, who in writers.items()
              if who & targets or len(who) >= 2}

    # lock inheritance: a helper is held if every in-class call site is
    # held (lexically, from __init__, or from another held helper)
    call_sites: Dict[str, List[_CallSite]] = {}
    for name, scan in scans.items():
        for c in scan.calls:
            if c.callee in scans:
                call_sites.setdefault(c.callee, []).append(c)
    inherited: Set[str] = set()
    changed = True
    while changed:
        changed = False
        for name in scans:
            if name in inherited or name in targets or name == "__init__":
                continue
            sites = call_sites.get(name, [])
            if sites and all(
                    s.guarded or s.method == "__init__"
                    or s.method in inherited for s in sites):
                inherited.add(name)
                changed = True

    findings: List[Finding] = []
    lock_names = sorted(set(locks.values()))
    for name, scan in scans.items():
        if name == "__init__" or name in inherited:
            continue
        for w in scan.writes:
            if w.attr in shared and not w.guarded:
                who = sorted(writers.get(w.attr, set()))
                findings.append(Finding(
                    RULE, rel, w.line,
                    f"{cls.name}.{name} writes self.{w.attr} without "
                    f"holding {' / '.join('self.' + l for l in lock_names)}"
                    f" — the attribute is also written by "
                    f"{', '.join(m for m in who if m != name) or 'a thread'}"
                    f" (thread entry points: {', '.join(sorted(targets))})"))
    return findings


def check_locks(root: Path, files: Optional[List[str]] = None) \
        -> List[Finding]:
    files = files if files is not None else scope_files(root)
    findings: List[Finding] = []
    for rel in files:
        tree = ast.parse((root / rel).read_text(), filename=rel)
        for node in tree.body:
            if isinstance(node, ast.ClassDef):
                findings.extend(_check_class(rel, node))
    return findings
