"""Hot-path sync lint: no host synchronization on the decode step.

HeteGen's throughput comes from overlapping CPU compute, PCIe transfer,
and device compute; a single hidden host sync (`.item()`, `np.asarray`
on a device array, `jax.device_get`, `block_until_ready`) on the decode
step serializes the whole pipeline.  This lint walks the may-call graph
from ``ContinuousBatcher.step`` (the one function every decode token
passes through) and flags those calls in any reachable function under
``src/repro/serving`` or ``src/repro/core``.

Escapes, in declared order of preference:

* ``SAMPLING_SINKS`` — functions whose *job* is host-side sampling
  (the per-step sample and the speculative accept/reject mirror); the
  sync there is the one the design budget already accounts for.
* ``np.asarray([...literal...])`` — building a host array from Python
  scalars is not a device sync; exempted structurally.
* ``# lint: allow[hot-path-sync] why`` — site-level suppression with a
  mandatory justification (e.g. the engine's stream-timing syncs, which
  are the measurement the alpha controller feeds on).
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional, Set, Tuple

from .callgraph import CodeIndex, FuncInfo, build_index, reachable_from
from .diagnostics import Finding

RULE = "hot-path-sync"

# the decode step: every generated token passes through here.  The
# HeteGen engine's linear is declared explicitly because the backend
# reaches it through jit-built closures the static graph cannot follow.
ENTRY_POINTS = [
    ("src/repro/serving/batcher.py", "ContinuousBatcher", "step"),
    ("src/repro/core/engine.py", "HeteGenEngine", "linear"),
]

# functions whose purpose is host-side sampling/acceptance: the one
# host sync per step the design accounts for (docs/ANALYSIS.md)
SAMPLING_SINKS = {
    ("src/repro/serving/batcher.py", "ContinuousBatcher",
     "_sample_slot_rows"),
    # the traced body of _sample_slot_rows (the public wrapper only adds
    # the tracer span around the same budgeted host sync)
    ("src/repro/serving/batcher.py", "ContinuousBatcher",
     "_sample_slot_rows_traced"),
    ("src/repro/serving/speculative.py", None, "filtered_probs"),
    ("src/repro/serving/speculative.py", None, "logprob_record"),
    ("src/repro/serving/speculative.py", None, "accept_row"),
}

_NUMPY_ALIASES = {"np", "numpy"}
_LITERAL = (ast.List, ast.Tuple, ast.ListComp, ast.GeneratorExp,
            ast.Constant)


def scope_files(root: Path) -> List[str]:
    rels = []
    for sub in ("src/repro/serving", "src/repro/core"):
        rels += sorted(str(p.relative_to(root).as_posix())
                       for p in (root / sub).glob("*.py"))
    # models.model is transit (backends call into it) but its findings
    # are out of scope here — jnp-only by construction
    extra = root / "src/repro/models/model.py"
    if extra.exists():
        rels.append("src/repro/models/model.py")
    return rels


def _flag_sync_calls(fn: FuncInfo) -> Iterable[Tuple[int, str]]:
    for node in ast.walk(fn.node):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "item" and not node.args:
                yield node.lineno, ".item() forces a device->host transfer"
            elif f.attr == "block_until_ready":
                yield node.lineno, "block_until_ready() stalls the " \
                    "dispatch pipeline"
            elif f.attr == "device_get" and \
                    isinstance(f.value, ast.Name) and f.value.id == "jax":
                yield node.lineno, "jax.device_get copies device->host"
            elif f.attr in ("asarray", "array") and \
                    isinstance(f.value, ast.Name) and \
                    f.value.id in _NUMPY_ALIASES:
                if node.args and isinstance(node.args[0], _LITERAL):
                    continue        # host literal, not a device sync
                yield node.lineno, f"np.{f.attr} on a (possibly device) " \
                    "array blocks until the value is ready"


def check_hotpath(root: Path,
                  files: Optional[List[str]] = None,
                  entries=None, sinks=None) -> List[Finding]:
    files = files if files is not None else scope_files(root)
    entries = entries if entries is not None else ENTRY_POINTS
    sinks = sinks if sinks is not None else SAMPLING_SINKS
    index = build_index(root, files)
    reach = reachable_from(index, entries)
    findings: List[Finding] = []
    for key in sorted(reach, key=lambda k: (k[0], str(k[1]), k[2])):
        path, cls, name = key
        if not (path.startswith("src/repro/serving/")
                or path.startswith("src/repro/core/")):
            continue                      # transit modules: out of scope
        if key in sinks or (path, None, name) in sinks:
            continue
        fn = index.funcs[key]
        for line, why in _flag_sync_calls(fn):
            findings.append(Finding(
                RULE, path, line,
                f"{fn.qualname} is reachable from the decode step "
                f"(ContinuousBatcher.step): {why}"))
    return findings
