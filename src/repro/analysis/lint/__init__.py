"""`repro.analysis.lint` — static invariant analyzer for the kernels
and the serving stack (docs/ANALYSIS.md).

Rules:

* ``kernel-grid-bounds`` / ``kernel-tile-alignment`` / ``kernel-dtype``
  / ``kernel-scalar-arity`` — Pallas BlockSpec/grid proofs
  (:mod:`.kernel_check`)
* ``hot-path-sync`` — no host sync reachable from the decode step
  (:mod:`.hotpath`)
* ``prng-discipline`` — request-owned keys only (:mod:`.prng`)
* ``lock-discipline`` — cross-thread writes under the declared lock
  (:mod:`.locks`)
* ``telemetry-no-sync`` — no host sync reachable from the tracer's
  recording/export surface (:mod:`.telemetry_sync`)

Run ``python -m repro.analysis.lint --strict`` (the tier-1 CI gate) or
``--changed-only`` for the fast git-diff-scoped mode.  Suppress a
finding with ``# lint: allow[rule-name] justification``.
"""

from .diagnostics import (Finding, SuppressionIndex, exit_code,  # noqa: F401
                          render_human, render_json)
from .hotpath import check_hotpath                               # noqa: F401
from .kernel_check import check_kernels, findings_for_callable   # noqa: F401
from .locks import check_locks                                   # noqa: F401
from .prng import check_prng                                     # noqa: F401
from .telemetry_sync import check_telemetry                      # noqa: F401
