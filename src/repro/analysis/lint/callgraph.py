"""An over-approximate call graph over the serving/core sources.

Python gives us no static dispatch, so resolution is deliberately
conservative (may-call): ``self.m(...)`` resolves to the same class's
``m`` if it exists, else to *every* method named ``m``; ``obj.m(...)``
resolves to every method or function named ``m`` in the scanned set;
bare names resolve through the module's import aliases and module-level
functions.  Functions passed as callables to ``*.submit(...)`` or
``Thread(target=...)`` count as calls (they will run).  Nested
functions and lambdas are folded into their enclosing def.

Over-approximation errs toward *more* reachable code — exactly the right
direction for the hot-path lint, which must not miss a sync hiding
behind a dynamically-dispatched backend method.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple


@dataclass
class FuncInfo:
    path: str                       # repo-relative
    module: str                     # e.g. "repro.serving.batcher"
    cls: Optional[str]              # enclosing class name or None
    name: str
    node: ast.AST                   # FunctionDef / AsyncFunctionDef

    @property
    def qualname(self) -> str:
        return f"{self.cls}.{self.name}" if self.cls else self.name

    @property
    def key(self) -> Tuple[str, Optional[str], str]:
        return (self.path, self.cls, self.name)


@dataclass
class ModuleInfo:
    path: str
    module: str
    tree: ast.Module
    aliases: Dict[str, str] = field(default_factory=dict)  # local -> target


class CodeIndex:
    """Parsed modules plus name -> definition lookup tables."""

    def __init__(self):
        self.modules: Dict[str, ModuleInfo] = {}          # path -> info
        self.funcs: Dict[Tuple[str, Optional[str], str], FuncInfo] = {}
        self.methods_by_name: Dict[str, List[FuncInfo]] = {}
        self.functions_by_name: Dict[str, List[FuncInfo]] = {}
        self.classes: Dict[str, List[Tuple[str, ast.ClassDef]]] = {}

    def add_file(self, path: Path, rel: str, module: str) -> None:
        tree = ast.parse(path.read_text(), filename=str(path))
        mi = ModuleInfo(rel, module, tree)
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mi.aliases[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom):
                for a in node.names:
                    mi.aliases[a.asname or a.name] = \
                        f"{node.module}.{a.name}" if node.module else a.name
        self.modules[rel] = mi

        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fi = FuncInfo(rel, module, None, node.name, node)
                self.funcs[fi.key] = fi
                self.functions_by_name.setdefault(node.name, []).append(fi)
            elif isinstance(node, ast.ClassDef):
                self.classes.setdefault(node.name, []).append((rel, node))
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        fi = FuncInfo(rel, module, node.name,
                                      item.name, item)
                        self.funcs[fi.key] = fi
                        self.methods_by_name.setdefault(
                            item.name, []).append(fi)

    def class_method(self, path: str, cls: str, name: str) \
            -> Optional[FuncInfo]:
        return self.funcs.get((path, cls, name))


_CALLABLE_SINKS = {"submit", "Thread", "map", "call_soon", "after"}


def _called_names(fn: FuncInfo, index: CodeIndex) -> Iterable[ast.expr]:
    """Yield callee expressions: call targets plus callables handed to
    executors/threads (which will be called)."""
    for node in ast.walk(fn.node):
        if not isinstance(node, ast.Call):
            continue
        yield node.func
        f = node.func
        sink = (isinstance(f, ast.Attribute) and f.attr in _CALLABLE_SINKS) \
            or (isinstance(f, ast.Name) and f.id in _CALLABLE_SINKS)
        if sink:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, (ast.Attribute, ast.Name)):
                    yield arg


def _resolve(expr: ast.expr, fn: FuncInfo, index: CodeIndex) \
        -> List[FuncInfo]:
    if isinstance(expr, ast.Attribute):
        name = expr.attr
        if isinstance(expr.value, ast.Name) and expr.value.id == "self" \
                and fn.cls is not None:
            own = index.class_method(fn.path, fn.cls, name)
            if own is not None:
                return [own]
        # unknown receiver: every method or function with this name may run
        return index.methods_by_name.get(name, []) \
            + index.functions_by_name.get(name, [])
    if isinstance(expr, ast.Name):
        mi = index.modules[fn.path]
        same = [f for f in index.functions_by_name.get(expr.id, [])
                if f.path == fn.path]
        if same:
            return same
        target = mi.aliases.get(expr.id)
        if target:
            leaf = target.rsplit(".", 1)[-1]
            return [f for f in index.functions_by_name.get(leaf, [])]
    return []


def reachable_from(index: CodeIndex,
                   entries: Iterable[Tuple[str, Optional[str], str]]) \
        -> Set[Tuple[str, Optional[str], str]]:
    """BFS over may-call edges from the entry points (path, cls, name)."""
    seen: Set[Tuple[str, Optional[str], str]] = set()
    work = [index.funcs[e] for e in entries if e in index.funcs]
    for fn in work:
        seen.add(fn.key)
    while work:
        fn = work.pop()
        for expr in _called_names(fn, index):
            for callee in _resolve(expr, fn, index):
                if callee.key not in seen:
                    seen.add(callee.key)
                    work.append(callee)
    return seen


def build_index(root: Path, rel_files: Iterable[str],
                pkg_prefix: str = "repro") -> CodeIndex:
    index = CodeIndex()
    for rel in rel_files:
        p = root / rel
        mod = rel.removeprefix("src/").removesuffix(".py").replace("/", ".")
        index.add_file(p, rel, mod)
    return index
