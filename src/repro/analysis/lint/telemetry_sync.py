"""Telemetry sync lint: the tracer must never synchronize the device.

The whole point of :mod:`repro.telemetry` is measuring the overlap of
the pin / transfer / host-GEMM / device streams *without perturbing it*
(docs/OBSERVABILITY.md).  A ``.item()``, ``jax.device_get``,
``block_until_ready``, or ``np.asarray`` on a device array anywhere in
the recording or snapshot path would serialize the very streams under
measurement — the observer effect this rule forbids statically.

The walk starts from every recording entry point (``Tracer.span`` /
``event`` and the :class:`MetricsRegistry` instruments) plus the
snapshot/export surface, follows the may-call graph across the
telemetry package, and flags any host-sync call in a reachable
function.  Unlike ``hot-path-sync`` there are no sampling sinks and no
budgeted escapes: telemetry has *zero* legitimate device syncs, so a
``# lint: allow[telemetry-no-sync]`` should essentially never appear.
"""

from __future__ import annotations

from pathlib import Path
from typing import List, Optional

from .callgraph import build_index, reachable_from
from .diagnostics import Finding
from .hotpath import _flag_sync_calls

RULE = "telemetry-no-sync"

# recording hot path (runs inside the streams being measured) plus the
# snapshot/export/report surface (runs on the driver thread, but a sync
# there still stalls dispatch mid-serve when called between steps)
ENTRY_POINTS = [
    ("src/repro/telemetry/tracer.py", "Tracer", "span"),
    ("src/repro/telemetry/tracer.py", "Tracer", "event"),
    ("src/repro/telemetry/tracer.py", "Tracer", "spans"),
    ("src/repro/telemetry/tracer.py", "Tracer", "events_list"),
    ("src/repro/telemetry/tracer.py", "_LiveSpan", "__exit__"),
    ("src/repro/telemetry/metrics.py", "Counter", "inc"),
    ("src/repro/telemetry/metrics.py", "Gauge", "set"),
    ("src/repro/telemetry/metrics.py", "Histogram", "observe"),
    ("src/repro/telemetry/metrics.py", "MetricsRegistry", "absorb"),
    ("src/repro/telemetry/metrics.py", "MetricsRegistry", "snapshot"),
    ("src/repro/telemetry/export.py", None, "to_chrome_trace"),
    ("src/repro/telemetry/export.py", None, "write_chrome_trace"),
    ("src/repro/telemetry/overlap.py", None, "compute_overlap"),
    ("src/repro/telemetry/recalibrate.py", None, "recalibrate_alpha"),
]


def scope_files(root: Path) -> List[str]:
    sub = root / "src/repro/telemetry"
    return sorted(str(p.relative_to(root).as_posix())
                  for p in sub.glob("*.py"))


def check_telemetry(root: Path,
                    files: Optional[List[str]] = None,
                    entries=None) -> List[Finding]:
    files = files if files is not None else scope_files(root)
    if not files:
        return []
    index = build_index(root, files)
    entries = entries if entries is not None else ENTRY_POINTS
    reach = reachable_from(index, [e for e in entries
                                   if e in index.funcs])
    findings: List[Finding] = []
    for key in sorted(reach, key=lambda k: (k[0], str(k[1]), k[2])):
        path, cls, name = key
        if not path.startswith("src/repro/telemetry/"):
            continue
        fn = index.funcs[key]
        for line, why in _flag_sync_calls(fn):
            findings.append(Finding(
                RULE, path, line,
                f"{fn.qualname} is reachable from the telemetry "
                f"recording/export surface: {why}"))
    return findings
