"""Findings, suppressions, and rendering for `repro.analysis.lint`.

A *finding* is one violated invariant, anchored to a file/line and a rule
name.  Suppressions are source comments of the form

    # lint: allow[rule-name] justification for why this site is exempt

placed on the flagged line or the line directly above it.  The
justification is mandatory: a bare ``allow[...]`` suppresses the finding
but emits a ``bare-suppression`` warning in its place, so suppressed
sites stay visible in review (and fail ``--strict``).

Exit-code semantics (used by the driver and tools/ci.sh):

    0  no findings (warnings allowed unless --strict)
    1  findings
    2  the analyzer itself crashed
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

REPO_ROOT = Path(__file__).resolve().parents[4]

_SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*allow\[([A-Za-z0-9_-]+)\]\s*(.*?)\s*$")


@dataclass
class Finding:
    rule: str
    path: str                 # repo-relative, POSIX separators
    line: int
    message: str
    severity: str = "error"   # "error" | "warning"

    def render(self) -> str:
        return (f"{self.path}:{self.line}: "
                f"[{self.rule}] {self.severity}: {self.message}")


def relpath(p: str | Path, root: Path = REPO_ROOT) -> str:
    p = Path(p).resolve()
    try:
        return p.relative_to(root).as_posix()
    except ValueError:
        return p.as_posix()


@dataclass
class Suppression:
    rule: str
    line: int
    justification: str
    covers: Tuple[int, ...] = ()
    used: bool = False


def scan_suppressions(path: Path) -> List[Suppression]:
    out: List[Suppression] = []
    try:
        text = path.read_text()
    except OSError:
        return out
    lines = text.splitlines()
    for i, ln in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(ln)
        if not m:
            continue
        # a suppression covers its own line (trailing comment) and the
        # next source line after the comment block it belongs to, so a
        # multi-line justification still anchors to the flagged statement
        covers = [i]
        j = i
        while j < len(lines):
            stripped = lines[j].strip()
            j += 1
            if stripped and not stripped.startswith("#"):
                covers.append(j)
                break
        out.append(Suppression(m.group(1), i, m.group(2), tuple(covers)))
    return out


class SuppressionIndex:
    """Per-file cache of `# lint: allow[...]` comments.

    A suppression on line L covers findings on L (trailing comment) and
    L+1 (comment-above).  Bare suppressions still suppress, but each one
    surfaces as a ``bare-suppression`` warning so it cannot hide
    silently.
    """

    def __init__(self, root: Path = REPO_ROOT):
        self.root = root
        self._cache: Dict[str, List[Suppression]] = {}

    def _for_file(self, rel: str) -> List[Suppression]:
        if rel not in self._cache:
            self._cache[rel] = scan_suppressions(self.root / rel)
        return self._cache[rel]

    def matches(self, f: Finding) -> Optional[Suppression]:
        for s in self._for_file(f.path):
            if s.rule == f.rule and f.line in s.covers:
                return s
        return None

    def apply(self, findings: Iterable[Finding]) -> List[Finding]:
        kept: List[Finding] = []
        for f in findings:
            s = self.matches(f)
            if s is None:
                kept.append(f)
            else:
                s.used = True
                if not s.justification:
                    kept.append(Finding(
                        "bare-suppression", f.path, s.line,
                        f"suppression of [{f.rule}] has no justification "
                        f"(write `# lint: allow[{f.rule}] <why>`)",
                        severity="warning"))
        return kept


def exit_code(findings: List[Finding], strict: bool) -> int:
    errors = [f for f in findings if f.severity == "error"]
    if errors:
        return 1
    if strict and findings:
        return 1
    return 0


def render_human(findings: List[Finding]) -> str:
    if not findings:
        return "lint: clean (0 findings)"
    lines = [f.render() for f in sorted(
        findings, key=lambda f: (f.path, f.line, f.rule))]
    n_err = sum(f.severity == "error" for f in findings)
    n_warn = len(findings) - n_err
    lines.append(f"lint: {n_err} error(s), {n_warn} warning(s)")
    return "\n".join(lines)


def render_json(findings: List[Finding]) -> str:
    return json.dumps({"findings": [asdict(f) for f in sorted(
        findings, key=lambda f: (f.path, f.line, f.rule))]}, indent=2)
