"""Mamba2 — state-space duality (SSD) blocks (arXiv:2405.21060).

Implements the selective state-space recurrence

    h_t = exp(A * dt_t) h_{t-1} + dt_t * B_t x_t^T        (per head)
    y_t = C_t . h_t + D x_t

three ways, all numerically equivalent (tested against each other):

  * ``ssd_recurrent``  — step-by-step scan (oracle; also the decode step)
  * ``ssd_chunked``    — the SSD chunked form: intra-chunk attention-like
    matmuls + inter-chunk state carry; this is the train/prefill path and
    the shape the Pallas kernel (:mod:`repro.kernels.ssd_chunk`) tiles
  * ``mamba_decode_step`` — O(1) single-token state update

The surrounding block (in_proj -> conv1d -> SSD -> gated RMSNorm ->
out_proj) follows the Mamba2 reference layout; zamba2 reuses it as its
trunk layer.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.shardings import NO_RULES, ShardingRules
from repro.models.layers import rmsnorm


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_recurrent(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                  c: jax.Array, d: jax.Array,
                  h0: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Reference scan.  x (B,L,H,P); dt (B,L,H); a (H) negative;
    b/c (B,L,G,N) broadcast over heads; d (H).  Returns (y, h_final) with
    h (B,H,P,N)."""
    bs, ln, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    if h0 is None:
        h0 = jnp.zeros((bs, h, p, n), dtype=jnp.float32)

    def step(hprev, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P) (B,H) (B,G,N)
        decay = jnp.exp(dtt * a)[..., None, None]   # (B,H,1,1)
        bt_h = jnp.repeat(bt, rep, axis=1)          # (B,H,N)
        ct_h = jnp.repeat(ct, rep, axis=1)
        upd = (dtt[..., None] * xt)[..., None] * bt_h[:, :, None, :]
        hnew = hprev * decay + upd.astype(jnp.float32)
        yt = jnp.einsum("bhpn,bhn->bhp", hnew, ct_h.astype(jnp.float32))
        return hnew, yt

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          b.transpose(1, 0, 2, 3), c.transpose(1, 0, 2, 3))
    hN, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3) + x.astype(jnp.float32) * d[:, None]
    return y.astype(x.dtype), hN


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, d: jax.Array, *, chunk: int = 128,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD (the 'dual' quadratic-within-chunk form).

    Exactly equal to :func:`ssd_recurrent` (up to fp assoc.); compute is
    matmul-shaped so the MXU (or its Pallas kernel) runs it efficiently.
    """
    bs, ln, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    assert ln % chunk == 0, f"seq {ln} not divisible by chunk {chunk}"
    nc = ln // chunk
    if h0 is None:
        h0 = jnp.zeros((bs, h, p, n), dtype=jnp.float32)

    # reshape into chunks: (B, nc, K, ...)
    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h)
    bc = jnp.repeat(b, rep, axis=2).reshape(bs, nc, chunk, h, n)
    cc = jnp.repeat(c, rep, axis=2).reshape(bs, nc, chunk, h, n)

    la = (dtc * a).astype(jnp.float32)              # log-decay per step
    cum = jnp.cumsum(la, axis=2)                    # (B,nc,K,H) inclusive
    # intra-chunk decay matrix: exp(cum_i - cum_j) for j <= i
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,K,K,H)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    xdt = xc * dtc[..., None]                       # dt_j * x_j
    cb = jnp.einsum("bnkhs,bnlhs->bnklh", cc.astype(jnp.float32),
                    bc.astype(jnp.float32))         # C_i . B_j
    y_intra = jnp.einsum("bnklh,bnklh,bnlhp->bnkhp", cb, decay,
                         xdt.astype(jnp.float32))

    # per-chunk state contribution: sum_j exp(cum_K - cum_j) dt_j B_j x_j
    tail = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,K,H)
    state_c = jnp.einsum("bnkh,bnkhs,bnkhp->bnhps", tail,
                         bc.astype(jnp.float32), xdt.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])         # (B,nc,H)

    def carry(hprev, inp):
        sc, cd, ccf, cumf = inp
        # y_inter_i = C_i . h_prev * exp(cum_i)
        y_inter = jnp.einsum("bkhs,bhps,bkh->bkhp", ccf, hprev,
                             jnp.exp(cumf))
        hnew = hprev * cd[..., None, None] + sc
        return hnew, y_inter

    xs = (state_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2),
          cc.astype(jnp.float32).transpose(1, 0, 2, 3, 4),
          cum.transpose(1, 0, 2, 3))
    hN, y_inter = jax.lax.scan(carry, h0, xs)
    y = y_intra + y_inter.transpose(1, 0, 2, 3, 4)
    y = y.reshape(bs, ln, h, p) + x.astype(jnp.float32) * d[:, None]
    return y.astype(x.dtype), hN


def ssd_decode_step(h: jax.Array, xt: jax.Array, dtt: jax.Array,
                    a: jax.Array, bt: jax.Array, ct: jax.Array,
                    d: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token state update.  h (B,H,P,N); xt (B,H,P); dtt (B,H);
    bt/ct (B,G,N)."""
    hq = h.shape[1]
    rep = hq // bt.shape[1]
    bt_h = jnp.repeat(bt, rep, axis=1)
    ct_h = jnp.repeat(ct, rep, axis=1)
    decay = jnp.exp(dtt * a)[..., None, None]
    upd = (dtt[..., None] * xt)[..., None] * bt_h[:, :, None, :]
    hnew = h * decay + upd.astype(jnp.float32)
    yt = jnp.einsum("bhpn,bhn->bhp", hnew, ct_h.astype(jnp.float32))
    yt = yt + xt.astype(jnp.float32) * d[:, None]
    return hnew, yt.astype(xt.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block (projections + conv + SSD + gated norm)
# ---------------------------------------------------------------------------

def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  xbc (B,L,C); w (K,C); returns (y, new_state)
    where state carries the last K-1 inputs."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    xpad = jnp.concatenate([state, xbc], axis=1)
    new_state = xpad[:, -(k - 1):, :] if k > 1 else state
    ys = sum(xpad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(ys + bias), new_state


def mamba_block(cfg, p: Dict, x: jax.Array, *,
                ssm_state: Optional[jax.Array] = None,
                conv_state: Optional[jax.Array] = None,
                chunked: bool = True,
                rules: ShardingRules = NO_RULES):
    """Full Mamba2 block over a sequence.  x (B,L,d_model).

    Returns (y, new_ssm_state, new_conv_state).
    """
    bs, ln, _ = x.shape
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    g = cfg.ssm_groups

    z = x @ p["w_z"]
    xr = x @ p["w_x"]
    bc = x @ p["w_bc"]
    dt = x @ p["w_dt"]
    z = rules.act(z, "batch", None, "ff")
    xr = rules.act(xr, "batch", None, "ff")
    # depthwise causal conv: splitting the fused [x;B;C] conv into x / BC
    # parts is exact (depthwise = channelwise)
    if conv_state is not None:
        cs_x, cs_bc = conv_state
    else:
        cs_x = cs_bc = None
    xr, new_conv_x = _causal_conv(xr, p["conv_x_w"], p["conv_x_b"], cs_x)
    bc, new_conv_bc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"], cs_bc)
    new_conv = (new_conv_x, new_conv_bc)
    xs = xr.reshape(bs, ln, h, pdim)
    xs = rules.act(xs, "batch", None, "ssm_heads", None)
    b = bc[..., :g * n].reshape(bs, ln, g, n)
    c = bc[..., g * n:].reshape(bs, ln, g, n)

    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    if ln == 1 and ssm_state is not None:
        hnew, yt = ssd_decode_step(ssm_state, xs[:, 0], dt[:, 0], a,
                                   b[:, 0], c[:, 0], p["D"])
        y = yt[:, None]
        new_state = hnew
    elif chunked and ln % cfg.ssm_chunk == 0 and ln > cfg.ssm_chunk:
        y, new_state = ssd_chunked(xs, dt, a, b, c, p["D"],
                                   chunk=cfg.ssm_chunk, h0=ssm_state)
    else:
        y, new_state = ssd_recurrent(xs, dt, a, b, c, p["D"], h0=ssm_state)

    y = y.reshape(bs, ln, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return rules.act(out, "batch", None, "embed"), new_state, new_conv
