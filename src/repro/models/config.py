"""Model configuration — one dataclass expressive enough for all assigned
architectures (dense GQA/MLA transformers, MoE, SSM, hybrid, enc-dec, VLM
backbone) plus the paper's OPT family.

Every field maps to a documented mechanism in :mod:`repro.models.layers`.
Architecture files in :mod:`repro.configs` instantiate this dataclass with
the exact published numbers and register themselves in the global registry.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    family: str = "dense"          # dense | moe | ssm | hybrid | encdec | vlm

    # --- trunk dimensions ---
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 512

    # --- attention ---
    attn_kind: str = "gqa"          # gqa | mla | none
    pos_emb: str = "rope"           # rope | learned | none
    rope_theta: float = 10_000.0
    max_seq: int = 131_072
    window: Optional[int] = None    # sliding-window size for local layers
    layer_pattern: Optional[str] = None  # e.g. "LG": local/global alternating
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    qk_norm: bool = False

    # --- MLP ---
    mlp_kind: str = "gated_silu"    # gated_silu | relu2 | gelu | relu

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 1
    shared_expert: bool = False
    moe_layer_period: int = 1       # every k-th layer is MoE (1 = all)
    capacity_factor: float = 1.25
    moe_group_size: int = 512       # GShard-style dispatch group

    # --- MLA (DeepSeek/MiniCPM3-style latent attention) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (Mamba2 SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_groups: int = 1

    # --- hybrid (zamba2: shared attention block over a mamba trunk) ---
    shared_attn_period: int = 0     # apply the shared block every k layers
    shared_lora_rank: int = 0       # per-invocation LoRA on the shared block

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0            # stub frontend frames (whisper: 1500)

    # --- VLM backbone ---
    embeds_input: bool = False      # input_specs provides patch embeddings

    # --- norms / embeddings ---
    norm_kind: str = "rmsnorm"      # rmsnorm | layernorm
    norm_eps: float = 1e-5
    post_norm: bool = False         # gemma2 sandwich norms
    emb_scale: bool = False         # multiply embeddings by sqrt(d_model)
    tie_embeddings: bool = True
    attn_bias: bool = False         # OPT/whisper use biases

    # --- distribution ---
    fsdp: bool = False              # 2D weight sharding: big matrices also
                                    # shard their input dim over "data"
                                    # (required >=100B: 16-way TP alone
                                    # leaves tens of GB per chip)
    # --- numerics ---
    dtype: str = "bfloat16"         # parameter/activation dtype
    kv_dtype: Optional[str] = None  # "int8": quantized KV cache (per
                                    # token-head symmetric scales) — halves
                                    # decode's dominant HBM term; beyond-
                                    # paper opt per HeteGen §7 (quantization)
    # --- training-side defaults (launcher may override) ---
    optimizer: str = "adamw"        # adamw | adafactor
    remat: bool = True

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid trunks)."""
        return self.family in ("ssm", "hybrid")

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind string, e.g. ('local','global',...) for gemma2
        or ('moe','dense',...) for maverick."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm" or self.family == "hybrid":
                kinds.append("mamba")
            elif self.n_experts > 0:
                kinds.append("moe" if (i % self.moe_layer_period
                                       == self.moe_layer_period - 1) else "dense")
            elif self.layer_pattern:
                p = self.layer_pattern[i % len(self.layer_pattern)]
                kinds.append({"L": "local", "G": "global"}[p])
            else:
                kinds.append("dense")
        return tuple(kinds)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameters (exact for our parameterization)."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv_heads
        total = V * d                                   # embedding
        if not self.tie_embeddings:
            total += V * d
        if self.pos_emb == "learned":
            total += self.max_seq * d
        total += d                                       # final norm scale
        if self.norm_kind == "layernorm":
            total += d

        def attn_params() -> int:
            if self.attn_kind == "mla":
                p = d * self.q_lora_rank + self.q_lora_rank                 # q down + norm
                p += self.q_lora_rank * Hq * (self.qk_nope_dim + self.qk_rope_dim)
                p += d * (self.kv_lora_rank + self.qk_rope_dim) + self.kv_lora_rank
                p += self.kv_lora_rank * Hq * (self.qk_nope_dim + self.v_head_dim)
                p += Hq * self.v_head_dim * d
                return p
            p = d * Hq * hd + 2 * d * Hkv * hd + Hq * hd * d
            if self.attn_bias:
                p += Hq * hd + 2 * Hkv * hd + d
            if self.qk_norm:
                p += 2 * hd
            return p

        def mlp_params(ff: int) -> int:
            if self.mlp_kind.startswith("gated"):
                return 3 * d * ff
            return 2 * d * ff + (ff + d if self.attn_bias else 0)

        def mamba_params() -> int:
            din = self.d_inner
            H = self.ssm_heads
            G, N = self.ssm_groups, self.ssm_state
            proj_in = d * (2 * din + 2 * G * N + H)
            conv = (din + 2 * G * N) * self.ssm_conv + (din + 2 * G * N)
            extra = 3 * H + din                          # A_log, D, dt_bias, gated-norm
            proj_out = din * d
            return proj_in + conv + extra + proj_out

        norms_per_block = (4 if self.post_norm else 2) * d
        if self.norm_kind == "layernorm":
            norms_per_block *= 2

        for kind in self.layer_kinds():
            if kind == "mamba":
                total += mamba_params() + d              # pre-norm
            elif kind == "moe":
                total += attn_params() + norms_per_block
                total += d * self.n_experts              # router
                total += self.n_experts * mlp_params(f) // 1
                if self.shared_expert:
                    total += mlp_params(f)
            else:
                total += attn_params() + norms_per_block + mlp_params(f)

        if self.shared_attn_period:
            # one shared transformer block on concat([h, emb]) (2d wide)
            d2 = 2 * d
            total += d2 * Hq * hd + 2 * d2 * Hkv * hd + Hq * hd * d2
            total += (3 if self.mlp_kind.startswith("gated") else 2) \
                * d2 * self.d_ff
            total += 2 * d2 + d2 * d                     # norms + out proj
            n_calls = len(self.shared_attn_sites())
            r = self.shared_lora_rank
            if r:
                total += n_calls * (d2 * r + r * Hq * hd)  # per-site LoRA on q
        if self.encoder_layers:
            # encoder blocks + per-decoder-layer cross attention
            enc = self.encoder_layers * (attn_params() + mlp_params(f)
                                         + norms_per_block)
            cross = self.n_layers * (attn_params() + d)
            total += enc + cross + self.encoder_seq * d  # enc learned pos
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts + shared)."""
        if self.n_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_expert = (3 if self.mlp_kind.startswith("gated") else 2) * d * f
        n_moe = sum(1 for k in self.layer_kinds() if k == "moe")
        inactive = n_moe * (self.n_experts - self.top_k) * per_expert
        return self.param_count() - inactive

    def shared_attn_sites(self) -> Tuple[int, ...]:
        if not self.shared_attn_period:
            return ()
        return tuple(range(0, self.n_layers, self.shared_attn_period))

    def dtype_bytes(self) -> int:
        return {"float32": 4, "bfloat16": 2, "float16": 2}[self.dtype]


def kv_cache_bytes(cfg: ModelConfig, batch: int, seq: int) -> int:
    """KV-cache footprint for decode at (batch, seq)."""
    by = cfg.dtype_bytes()
    if cfg.family == "ssm":
        state = cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
        conv = (cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state) * cfg.ssm_conv
        return cfg.n_layers * batch * (state + conv) * by
    if cfg.attn_kind == "mla":
        per_tok = cfg.kv_lora_rank + cfg.qk_rope_dim
        return cfg.n_layers * batch * seq * per_tok * by
    if cfg.kv_dtype == "int8":
        by = 1
    per_tok = 2 * cfg.n_kv_heads * cfg.hd
    n_attn = cfg.n_layers
    if cfg.family == "hybrid":
        state = cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
        conv = (cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state) * cfg.ssm_conv
        mamba = cfg.n_layers * batch * (state + conv) * by
        shared = len(cfg.shared_attn_sites()) * batch * seq * per_tok * by
        return mamba + shared
    win = cfg.window
    if cfg.layer_pattern and win:
        kinds = cfg.layer_kinds()
        n_local = sum(1 for k in kinds if k == "local")
        n_global = len(kinds) - n_local
        return batch * per_tok * by * (n_local * min(win, seq) + n_global * seq)
    return n_attn * batch * seq * per_tok * by
