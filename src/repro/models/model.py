"""Model assembly: init / train-forward / prefill / decode for all families.

Families:

    dense | moe      decoder-only LM, scanned over super-blocks (a super-
                     block is one period of the layer pattern: e.g. gemma2's
                     (local, global) pair, maverick's (dense, moe) pair)
    ssm              Mamba2 trunk (attention-free)
    hybrid           zamba2: Mamba2 trunk + one shared attention block
                     (invoked every k layers with per-site LoRA)
    encdec           whisper: stub-frontend encoder + causal decoder with
                     cross attention
    vlm              llava: dense backbone whose prefill consumes
                     precomputed patch embeddings

Parameters are plain nested dicts; per-super-block leaves are stacked on a
leading axis and the trunk runs under ``lax.scan`` (keeps HLO size and
compile time independent of depth).  The KV / SSM cache is a dict pytree
carried through the same scan.
"""

from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.shardings import NO_RULES, ShardingRules
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _init_norm(cfg, key, d) -> Dict:
    p = {"scale": jnp.ones((d,), _dtype(cfg))}
    if cfg.norm_kind == "layernorm":
        p["bias"] = jnp.zeros((d,), _dtype(cfg))
    if cfg.post_norm:                      # gemma (1+w) rmsnorm: init w=0
        p["scale"] = jnp.zeros((d,), _dtype(cfg))
    return p


def _dense(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def _init_attn(cfg, key, d_in: Optional[int] = None,
               d_out: Optional[int] = None) -> Dict:
    d = d_in or cfg.d_model
    do = d_out or cfg.d_model
    hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    dt = _dtype(cfg)
    p = {
        "wq": _dense(ks[0], (d, hq * hd), dt),
        "wk": _dense(ks[1], (d, hkv * hd), dt),
        "wv": _dense(ks[2], (d, hkv * hd), dt),
        "wo": _dense(ks[3], (hq * hd, do), dt),
    }
    if cfg.attn_bias:
        p.update(bq=jnp.zeros((hq * hd,), dt), bk=jnp.zeros((hkv * hd,), dt),
                 bv=jnp.zeros((hkv * hd,), dt),
                 bo=jnp.zeros((do,), dt))
    if cfg.qk_norm:
        p.update(q_norm=jnp.ones((hd,), dt), k_norm=jnp.ones((hd,), dt))
    return p


def _init_mla(cfg, key) -> Dict:
    d, dt = cfg.d_model, _dtype(cfg)
    h = cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": _dense(ks[0], (d, r_q), dt),
        "q_a_norm": jnp.ones((r_q,), dt),
        "wq_b": _dense(ks[1], (r_q, h * (dn + dr)), dt),
        "wkv_a": _dense(ks[2], (d, r_kv + dr), dt),
        "kv_a_norm": jnp.ones((r_kv,), dt),
        "wk_b": _dense(ks[3], (r_kv, h * dn), dt),
        "wv_b": _dense(ks[4], (r_kv, h * dv), dt),
        "wo": _dense(ks[5], (h * dv, d), dt),
    }


def _init_mlp(cfg, key, d_in: Optional[int] = None,
              d_out: Optional[int] = None) -> Dict:
    d = d_in or cfg.d_model
    do = d_out or cfg.d_model
    f, dt = cfg.d_ff, _dtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind.startswith("gated"):
        return {"w_gate": _dense(ks[0], (d, f), dt),
                "w_up": _dense(ks[1], (d, f), dt),
                "w_down": _dense(ks[2], (f, do), dt)}
    p = {"w_in": _dense(ks[0], (d, f), dt),
         "w_down": _dense(ks[1], (f, do), dt)}
    if cfg.attn_bias:
        p.update(b_in=jnp.zeros((f,), dt), b_down=jnp.zeros((do,), dt))
    return p


def _init_moe(cfg, key) -> Dict:
    d, f, e, dt = cfg.d_model, cfg.d_ff, cfg.n_experts, _dtype(cfg)
    ks = jax.random.split(key, 7)
    p = {"router": _dense(ks[0], (d, e), jnp.float32)}
    if cfg.mlp_kind.startswith("gated"):
        p.update(we_gate=_dense(ks[1], (e, d, f), dt),
                 we_up=_dense(ks[2], (e, d, f), dt),
                 we_down=_dense(ks[3], (e, f, d), dt))
    else:
        p.update(we_in=_dense(ks[1], (e, d, f), dt),
                 we_down=_dense(ks[3], (e, f, d), dt))
    if cfg.shared_expert:
        p.update(ws_gate=_dense(ks[4], (d, f), dt),
                 ws_up=_dense(ks[5], (d, f), dt),
                 ws_down=_dense(ks[6], (f, d), dt))
    return p


def _init_mamba(cfg, key) -> Dict:
    """Mamba2 block.  Projections are kept separate (w_z / w_x / w_bc /
    w_dt) rather than one fused in_proj so tensor parallelism can shard
    z/x/dt on heads and keep the small B/C projection replicated — a fused
    output dim cannot be sharded without resharding at the split points
    (DESIGN.md §4)."""
    d, dt = cfg.d_model, _dtype(cfg)
    din, h = cfg.d_inner, cfg.ssm_heads
    gn = cfg.ssm_groups * cfg.ssm_state
    ks = jax.random.split(key, 8)
    return {
        "w_z": _dense(ks[0], (d, din), dt),
        "w_x": _dense(ks[1], (d, din), dt),
        "w_bc": _dense(ks[2], (d, 2 * gn), dt),
        "w_dt": _dense(ks[3], (d, h), dt),
        "conv_x_w": _dense(ks[4], (cfg.ssm_conv, din), dt, scale=0.2),
        "conv_x_b": jnp.zeros((din,), dt),
        "conv_bc_w": _dense(ks[5], (cfg.ssm_conv, 2 * gn), dt, scale=0.2),
        "conv_bc_b": jnp.zeros((2 * gn,), dt),
        "A_log": jnp.zeros((h,), jnp.float32),          # A = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -1.0, jnp.float32),
        "gnorm": jnp.ones((din,), dt),
        "out_proj": _dense(ks[6], (din, d), dt),
        "ln": _init_norm(cfg, ks[7], d),
    }


def _init_block(cfg, key, kind: str) -> Dict:
    """One layer of a given kind."""
    ks = jax.random.split(key, 6)
    if kind == "mamba":
        return _init_mamba(cfg, ks[0])
    p: Dict = {"ln1": _init_norm(cfg, ks[0], cfg.d_model),
               "ln2": _init_norm(cfg, ks[1], cfg.d_model)}
    if cfg.post_norm:
        p["ln1_post"] = _init_norm(cfg, ks[2], cfg.d_model)
        p["ln2_post"] = _init_norm(cfg, ks[3], cfg.d_model)
    if cfg.attn_kind == "mla":
        p["attn"] = _init_mla(cfg, ks[4])
    else:
        p["attn"] = _init_attn(cfg, ks[4])
    if kind == "moe":
        p["moe"] = _init_moe(cfg, ks[5])
    else:
        p["mlp"] = _init_mlp(cfg, ks[5])
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 16)
    params: Dict = {
        "embed": _dense(keys[0], (cfg.vocab_size, cfg.d_model), dt, scale=1.0),
        "final_norm": _init_norm(cfg, keys[1], cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(keys[2], (cfg.d_model, cfg.vocab_size), dt)
    if cfg.pos_emb == "learned":
        params["pos"] = _dense(keys[3], (cfg.max_seq, cfg.d_model), dt,
                               scale=0.02)

    kinds = cfg.layer_kinds()
    if cfg.family in ("ssm", "hybrid"):
        period = cfg.shared_attn_period or cfg.n_layers
        n_groups = cfg.n_layers // period
        tail = cfg.n_layers - n_groups * period
        lkeys = jax.random.split(keys[4], cfg.n_layers)
        blocks = [_init_mamba(cfg, k) for k in lkeys]
        trunk = [_stack(blocks[g * period:(g + 1) * period])
                 for g in range(n_groups)]
        params["blocks"] = _stack(trunk) if n_groups > 1 else \
            jax.tree.map(lambda x: x[None], trunk[0])
        if tail:
            params["tail"] = _stack(blocks[n_groups * period:])
        if cfg.family == "hybrid":
            d2 = 2 * cfg.d_model
            sk = jax.random.split(keys[5], 8)
            shared = {"ln1": _init_norm(cfg, sk[0], d2),
                      "ln2": _init_norm(cfg, sk[1], d2),
                      "attn": _init_attn(cfg, sk[2], d_in=d2, d_out=d2),
                      "mlp": _init_mlp(cfg, sk[3], d_in=d2, d_out=d2)}
            # shared block emits d2; project back to d_model
            shared["proj"] = _dense(sk[4], (d2, cfg.d_model), dt)
            params["shared"] = shared
            n_sites = len(cfg.shared_attn_sites())
            r = cfg.shared_lora_rank
            if r:
                params["shared_lora"] = {
                    "a": _dense(sk[5], (n_sites, d2, r), dt, scale=0.02),
                    "b": jnp.zeros((n_sites, r, cfg.n_heads * cfg.hd), dt),
                }
        return params

    if cfg.family == "encdec":
        ek = jax.random.split(keys[6], cfg.encoder_layers)
        params["enc_blocks"] = _stack([_init_block(cfg, k, "dense")
                                       for k in ek])
        params["enc_pos"] = _dense(keys[7], (cfg.encoder_seq, cfg.d_model),
                                   dt, scale=0.02)
        params["enc_final_norm"] = _init_norm(cfg, keys[8], cfg.d_model)
        ck = jax.random.split(keys[9], cfg.n_layers)
        params["cross"] = _stack([
            {"attn": _init_attn(cfg, k),
             "ln": _init_norm(cfg, jax.random.fold_in(k, 1), cfg.d_model)}
            for k in ck])

    period = _pattern_period(cfg)
    n_super = cfg.n_layers // period
    bkeys = jax.random.split(keys[10], cfg.n_layers)
    supers = []
    for g in range(n_super):
        blk = {}
        for j in range(period):
            li = g * period + j
            blk[f"pos{j}"] = _init_block(cfg, bkeys[li], kinds[li])
        supers.append(blk)
    params["blocks"] = _stack(supers) if n_super > 1 else \
        jax.tree.map(lambda x: x[None], supers[0])
    return params


def _pattern_period(cfg: ModelConfig) -> int:
    if cfg.family in ("ssm", "hybrid"):
        return 1
    if cfg.layer_pattern:
        return len(cfg.layer_pattern)
    if cfg.n_experts and cfg.moe_layer_period > 1:
        return cfg.moe_layer_period
    return 1


# ---------------------------------------------------------------------------
# KV / state cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               spec_only: bool = False) -> Dict:
    """Cache pytree (jnp zeros, or ShapeDtypeStructs when ``spec_only``)."""
    dt = _dtype(cfg)

    def mk(shape, dtype=dt):
        if spec_only:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    cache: Dict = {"len": mk((), jnp.int32)}
    hd, hkv = cfg.hd, cfg.n_kv_heads

    if cfg.family in ("ssm", "hybrid"):
        period = cfg.shared_attn_period or cfg.n_layers
        n_groups = cfg.n_layers // period
        tail = cfg.n_layers - n_groups * period
        h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        gn2 = 2 * cfg.ssm_groups * cfg.ssm_state
        cache["ssm"] = mk((n_groups, period, batch, h, p, n), jnp.float32)
        cache["conv_x"] = mk((n_groups, period, batch, cfg.ssm_conv - 1,
                              cfg.d_inner))
        cache["conv_bc"] = mk((n_groups, period, batch, cfg.ssm_conv - 1,
                               gn2))
        if tail:
            cache["ssm_tail"] = mk((tail, batch, h, p, n), jnp.float32)
            cache["conv_x_tail"] = mk((tail, batch, cfg.ssm_conv - 1,
                                       cfg.d_inner))
            cache["conv_bc_tail"] = mk((tail, batch, cfg.ssm_conv - 1, gn2))
        if cfg.family == "hybrid":
            n_sites = len(cfg.shared_attn_sites())
            cache["shared_k"] = mk((n_sites, batch, hkv, max_len, hd))
            cache["shared_v"] = mk((n_sites, batch, hkv, max_len, hd))
        return cache

    period = _pattern_period(cfg)
    n_super = cfg.n_layers // period
    for j in range(period):
        if cfg.attn_kind == "mla":
            cache[f"lat{j}"] = mk((n_super, batch, max_len, cfg.kv_lora_rank))
            cache[f"kr{j}"] = mk((n_super, batch, max_len, cfg.qk_rope_dim))
        elif cfg.kv_dtype == "int8":
            # quantized cache: int8 values + per (token, head) scales
            cache[f"k{j}"] = mk((n_super, batch, hkv, max_len, hd), jnp.int8)
            cache[f"v{j}"] = mk((n_super, batch, hkv, max_len, hd), jnp.int8)
            cache[f"ks{j}"] = mk((n_super, batch, hkv, max_len), jnp.float32)
            cache[f"vs{j}"] = mk((n_super, batch, hkv, max_len), jnp.float32)
        else:
            # (stack, B, Hkv, T, hd): the attention dot consumes the cache
            # with no transpose (see layers._attend_block "bhtd")
            cache[f"k{j}"] = mk((n_super, batch, hkv, max_len, hd))
            cache[f"v{j}"] = mk((n_super, batch, hkv, max_len, hd))
    if cfg.family == "encdec":
        cache["cross_k"] = mk((cfg.n_layers, batch, cfg.encoder_seq, hkv, hd))
        cache["cross_v"] = mk((cfg.n_layers, batch, cfg.encoder_seq, hkv, hd))
    return cache


# ---------------------------------------------------------------------------
# Attention layer application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _apply_attn_layer(cfg, p, x, positions, *, kind: str,
                      kv_cache: Optional[Tuple] = None, cur_len=None,
                      rules: ShardingRules = NO_RULES,
                      cross_kv: Optional[Tuple] = None,
                      linear=None, kv_format: str = "bhtd",
                      norm_fn=None, attend_fn=None,
                      block_tables=None, paged_attend_fn=None):
    """Pre-norm attention + residual.  Returns (x, new_kv_cache).

    ``kv_cache`` is (k, v) buffers (B,T,...) to update at ``cur_len``;
    None during training (attend within the sequence only).

    ``linear`` is the pluggable matmul backend (see
    :mod:`repro.serving.backends`): every weight matmul of the layer is
    routed through it, so the same layer math serves both the resident
    jitted path (``None`` — weights read from ``p``) and the HeteGen
    offload engine.  ``kv_format`` is the cache layout: "bhtd" for the
    scan-stacked resident cache, "bthd" for the per-layer backend cache.
    ``norm_fn``/``attend_fn`` optionally replace the inline norm /
    attention with pre-jitted equivalents (the eager offload path keeps
    its small device pieces fused; see :func:`make_backend_ops`).

    With ``block_tables`` (B, nb), ``kv_cache`` holds *page pools*
    instead of dense buffers — (k_pages, v_pages) in (P, Hkv, ps, hd)
    layout, or (k, v, k_scale, v_scale) pools for q8 pages — written via
    :func:`_paged_write` and attended through :func:`_paged_attend` (or
    the pre-jitted ``paged_attend_fn``).
    """
    window = cfg.window if kind == "local" else None
    norm = norm_fn or (lambda pp, h: L.apply_norm(cfg, pp, h))
    h = norm(p["ln1"], x)

    if cfg.attn_kind == "mla":
        q_nope, q_rope = L.mla_project_q(cfg, p["attn"], h, positions)
        latent, k_rope = L.mla_latent_kv(cfg, p["attn"], h, positions)
        if kv_cache is None:
            out = L.mla_attend(cfg, p["attn"], q_nope, q_rope, latent,
                               k_rope, q_positions=positions,
                               kv_positions=positions, causal=True,
                               rules=rules)
            new_cache = None
        else:
            lat_buf, kr_buf = kv_cache
            lat_buf = _update_kv(lat_buf, latent, cur_len)
            kr_buf = _update_kv(kr_buf, k_rope, cur_len)
            t = lat_buf.shape[1]
            kvpos = jnp.arange(t)
            out = L.mla_attend(cfg, p["attn"], q_nope, q_rope, lat_buf,
                               kr_buf, q_positions=positions,
                               kv_positions=kvpos[None],
                               kv_len=cur_len + latent.shape[1],
                               causal=True, rules=rules)
            new_cache = (lat_buf, kr_buf)
    else:
        q, k, v = L.gqa_qkv(cfg, p["attn"], h, positions, rules,
                            linear=linear)
        if cross_kv is not None:
            k, v = cross_kv
            kvpos = jnp.arange(k.shape[1])
            out = L.attention(q, k, v, q_positions=positions,
                              kv_positions=kvpos[None], causal=False,
                              rules=rules)
            new_cache = None
        elif kv_cache is None:
            out = L.attention(q, k, v, q_positions=positions,
                              kv_positions=positions, causal=True,
                              window=window, attn_softcap=cfg.attn_softcap,
                              rules=rules)
            new_cache = None
        elif block_tables is not None:
            if len(kv_cache) == 4:      # q8 pools: int8 pages + scales
                k_pg, v_pg, ks_pg, vs_pg = kv_cache
                k_pg, ks_pg = _paged_write_q8(k_pg, ks_pg, k, block_tables,
                                              cur_len)
                v_pg, vs_pg = _paged_write_q8(v_pg, vs_pg, v, block_tables,
                                              cur_len)
                new_cache = (k_pg, v_pg, ks_pg, vs_pg)
                scales = (ks_pg, vs_pg)
            else:
                k_pg, v_pg = kv_cache
                k_pg = _paged_write(k_pg, k, block_tables, cur_len)
                v_pg = _paged_write(v_pg, v, block_tables, cur_len)
                new_cache = (k_pg, v_pg)
                scales = (None, None)
            pa = paged_attend_fn or functools.partial(_paged_attend, cfg)
            out = pa(q, k_pg, v_pg, block_tables, positions,
                     cur_len + k.shape[1], window, *scales)
        else:
            k_buf, v_buf = kv_cache     # (B, Hkv, T, D) or (B, T, Hkv, D)
            k_buf = _update_kv(k_buf, k, cur_len, layout=kv_format)
            v_buf = _update_kv(v_buf, v, cur_len, layout=kv_format)
            if attend_fn is not None:
                out = attend_fn(q, k_buf, v_buf, positions,
                                cur_len + k.shape[1], window)
            else:
                t = k_buf.shape[2] if kv_format == "bhtd" else k_buf.shape[1]
                kvpos = jnp.arange(t)
                out = L.attention(q, k_buf, v_buf, q_positions=positions,
                                  kv_positions=kvpos[None],
                                  kv_len=cur_len + k.shape[1], causal=True,
                                  window=window,
                                  attn_softcap=cfg.attn_softcap,
                                  kv_format=kv_format, rules=rules)
            new_cache = (k_buf, v_buf)
        out = L.attn_out(cfg, p["attn"], out, rules, linear=linear)

    if cfg.post_norm:
        out = norm(p["ln1_post"], out)
    return x + out, new_cache



def _apply_attn_layer_stacked(cfg, p, x, positions, *, kind: str, stacks,
                              li, cur_len, rules: ShardingRules = NO_RULES):
    """Like :func:`_apply_attn_layer` but against stacked (L, B, T, ...)
    cache buffers carried through the trunk scan: only the new token rows
    are written (in place); the layer's cache is sliced for attention.
    Returns (x, updated_stacks)."""
    window = cfg.window if kind == "local" else None
    h = L.apply_norm(cfg, p["ln1"], x)

    if cfg.attn_kind == "mla":
        q_nope, q_rope = L.mla_project_q(cfg, p["attn"], h, positions)
        latent, k_rope = L.mla_latent_kv(cfg, p["attn"], h, positions)
        lat_stack, kr_stack = stacks
        lat_stack = _stack_write(lat_stack, latent, li, cur_len)
        kr_stack = _stack_write(kr_stack, k_rope, li, cur_len)
        lat_buf = _stack_layer(lat_stack, li)
        kr_buf = _stack_layer(kr_stack, li)
        t = lat_buf.shape[1]
        kvpos = jnp.arange(t)
        out = L.mla_attend(cfg, p["attn"], q_nope, q_rope, lat_buf, kr_buf,
                           q_positions=positions, kv_positions=kvpos[None],
                           kv_len=cur_len + latent.shape[1], causal=True,
                           rules=rules)
        new_stacks = (lat_stack, kr_stack)
    else:
        q, k, v = L.gqa_qkv(cfg, p["attn"], h, positions, rules)
        if cfg.kv_dtype == "int8":
            (k_stack, v_stack, ks_stack, vs_stack) = stacks
            k_stack, ks_stack = _stack_write_q8(k_stack, ks_stack, k, li,
                                                cur_len)
            v_stack, vs_stack = _stack_write_q8(v_stack, vs_stack, v, li,
                                                cur_len)
            dt = jnp.dtype(cfg.dtype)
            k_buf = (_stack_layer(k_stack, li).astype(dt)
                     * _stack_layer(ks_stack, li)[..., None].astype(dt))
            v_buf = (_stack_layer(v_stack, li).astype(dt)
                     * _stack_layer(vs_stack, li)[..., None].astype(dt))
            new_stacks_q8 = (k_stack, v_stack, ks_stack, vs_stack)
        else:
            k_stack, v_stack = stacks
            k_stack = _stack_write(k_stack, k, li, cur_len, layout="bhtd")
            v_stack = _stack_write(v_stack, v, li, cur_len, layout="bhtd")
            k_buf = _stack_layer(k_stack, li)      # (B, Hkv, T, D)
            v_buf = _stack_layer(v_stack, li)
        kvpos = jnp.arange(k_buf.shape[2])
        out = L.attention(q, k_buf, v_buf, q_positions=positions,
                          kv_positions=kvpos[None],
                          kv_len=cur_len + k.shape[1], causal=True,
                          window=window, attn_softcap=cfg.attn_softcap,
                          kv_format="bhtd", rules=rules)
        out = L.attn_out(cfg, p["attn"], out, rules)
        new_stacks = new_stacks_q8 if cfg.kv_dtype == "int8" \
            else (k_stack, v_stack)

    if cfg.post_norm:
        out = L.apply_norm(cfg, p["ln1_post"], out)
    return x + out, new_stacks


def _apply_ffn(cfg, p, x, kind: str, rules: ShardingRules,
               aux: Optional[jax.Array] = None, linear=None, norm_fn=None):
    norm = norm_fn or (lambda pp, h: L.apply_norm(cfg, pp, h))
    h = norm(p["ln2"], x)
    if kind == "moe":
        y = L.moe(cfg, p["moe"], h, rules)
        if aux is not None:
            aux = aux + L.moe_aux_loss(cfg, p["moe"], h)
    else:
        y = L.mlp(cfg, p["mlp"], h, rules, linear=linear)
    if cfg.post_norm:
        y = norm(p["ln2_post"], y)
    return (x + y) if aux is None else (x + y, aux)


# ---------------------------------------------------------------------------
# Trunks
# ---------------------------------------------------------------------------

def _transformer_trunk(cfg, params, x, positions, *, cache=None, cur_len=None,
                       rules: ShardingRules = NO_RULES, remat=False):
    """Scan over super-blocks.  Returns (x, new_cache_dict)."""
    kinds = cfg.layer_kinds()
    period = _pattern_period(cfg)

    def block(carry, blk):
        x, aux = carry
        p_blk, kv_in = blk
        new_kv = {}
        for j in range(period):
            kind = kinds[j]
            kvc = None
            if kv_in is not None:
                if cfg.attn_kind == "mla":
                    kvc = (kv_in[f"lat{j}"], kv_in[f"kr{j}"])
                else:
                    kvc = (kv_in[f"k{j}"], kv_in[f"v{j}"])
            x, kv_out = _apply_attn_layer(cfg, p_blk[f"pos{j}"], x, positions,
                                          kind=kind, kv_cache=kvc,
                                          cur_len=cur_len, rules=rules)
            if kv_out is not None:
                if cfg.attn_kind == "mla":
                    new_kv[f"lat{j}"], new_kv[f"kr{j}"] = kv_out
                else:
                    new_kv[f"k{j}"], new_kv[f"v{j}"] = kv_out
            x, aux = _apply_ffn(cfg, p_blk[f"pos{j}"], x, kind, rules,
                                aux=aux)
            x = rules.act(x, "batch", "seq", "embed")
        return (x, aux), new_kv

    if remat:
        block = jax.checkpoint(block,
                               policy=jax.checkpoint_policies.nothing_saveable)

    kv_keys = [k for k in (cache or {})
               if any(k.startswith(pfx) and k[len(pfx):].isdigit()
                      for pfx in ("k", "v", "lat", "kr", "ks", "vs"))]

    if cache and not _legacy_cache_scan():
        # carry path: stacked caches updated in place (one token-row DUS
        # per layer) instead of copied through scan xs/ys
        def block_carry(carry, p_blk):
            x, aux, li, kvs = carry
            new_kvs = dict(kvs)
            for j in range(period):
                kind = kinds[j]
                if cfg.attn_kind == "mla":
                    stacks = (new_kvs[f"lat{j}"], new_kvs[f"kr{j}"])
                elif cfg.kv_dtype == "int8":
                    stacks = (new_kvs[f"k{j}"], new_kvs[f"v{j}"],
                              new_kvs[f"ks{j}"], new_kvs[f"vs{j}"])
                else:
                    stacks = (new_kvs[f"k{j}"], new_kvs[f"v{j}"])
                x, stacks = _apply_attn_layer_stacked(
                    cfg, p_blk[f"pos{j}"], x, positions, kind=kind,
                    stacks=stacks, li=li, cur_len=cur_len, rules=rules)
                if cfg.attn_kind == "mla":
                    new_kvs[f"lat{j}"], new_kvs[f"kr{j}"] = stacks
                elif cfg.kv_dtype == "int8":
                    (new_kvs[f"k{j}"], new_kvs[f"v{j}"],
                     new_kvs[f"ks{j}"], new_kvs[f"vs{j}"]) = stacks
                else:
                    new_kvs[f"k{j}"], new_kvs[f"v{j}"] = stacks
                x, aux = _apply_ffn(cfg, p_blk[f"pos{j}"], x, kind, rules,
                                    aux=aux)
                x = rules.act(x, "batch", "seq", "embed")
            return (x, aux, li + 1, new_kvs), ()

        kvs0 = {k: cache[k] for k in kv_keys}
        (x, aux, _, new_kv), _ = jax.lax.scan(
            block_carry,
            (x, jnp.zeros((), jnp.float32), jnp.int32(0), kvs0),
            params["blocks"])
        return x, new_kv, aux

    xs_cache = {k: cache[k] for k in kv_keys} if cache else None
    (x, aux), new_kv = jax.lax.scan(
        block, (x, jnp.zeros((), jnp.float32)), (params["blocks"], xs_cache))
    return x, new_kv, aux


def _mamba_trunk(cfg, params, x, positions, *, cache=None, cur_len=None,
                 rules: ShardingRules = NO_RULES, remat=False,
                 emb0=None):
    """SSM / hybrid trunk: scan over groups of ``period`` mamba layers,
    with the shared attention block applied at each group start (hybrid)."""
    period = cfg.shared_attn_period or cfg.n_layers
    n_groups = cfg.n_layers // period
    tail = cfg.n_layers - n_groups * period
    hybrid = cfg.family == "hybrid"

    def mamba_one(x, p, states):
        ssm_st, conv_st = states
        h = L.apply_norm(cfg, p["ln"], x)
        y, s2, c2 = S.mamba_block(cfg, p, h, ssm_state=ssm_st,
                                  conv_state=conv_st, rules=rules)
        return x + y, (s2, c2)

    def shared_block(x, site_idx, kv):
        p = params["shared"]
        h2 = jnp.concatenate([x, emb0], axis=-1)
        h = L.apply_norm(cfg, p["ln1"], h2)
        q, k, v = L.gqa_qkv(cfg, p["attn"], h, positions, rules)
        if "shared_lora" in params:
            la = params["shared_lora"]["a"][site_idx]
            lb = params["shared_lora"]["b"][site_idx]
            b_, s_, _ = h.shape
            dq = ((h @ la) @ lb).reshape(b_, s_, cfg.n_heads, cfg.hd)
            if cfg.pos_emb == "rope":
                dq = L.rope(dq, positions, cfg.rope_theta)
            q = q + dq
        if kv is None:
            out = L.attention(q, k, v, q_positions=positions,
                              kv_positions=positions, causal=True,
                              rules=rules)
            new_kv = None
        else:
            k_buf, v_buf = kv                  # (B, Hkv, T, D)
            k_buf = _update_kv(k_buf, k, cur_len, layout="bhtd")
            v_buf = _update_kv(v_buf, v, cur_len, layout="bhtd")
            kvpos = jnp.arange(k_buf.shape[2])
            out = L.attention(q, k_buf, v_buf, q_positions=positions,
                              kv_positions=kvpos[None],
                              kv_len=cur_len + k.shape[1], causal=True,
                              kv_format="bhtd", rules=rules)
            new_kv = (k_buf, v_buf)
        b_, s_, hq_, hd_ = out.shape
        h2 = h2 + out.reshape(b_, s_, hq_ * hd_) @ p["attn"]["wo"]
        hm = L.apply_norm(cfg, p["ln2"], h2)
        h2 = h2 + L.mlp(cfg, p["mlp"], hm, rules)
        return x + h2 @ p["proj"], new_kv

    def group(x, inp):
        gi, p_grp, states, kv = inp
        new_kv = None
        if hybrid:
            x, new_kv = shared_block(x, gi, kv)
        new_states = []
        for j in range(period):
            pj = jax.tree.map(lambda a: a[j], p_grp)
            stj = jax.tree.map(lambda a: a[j], states)
            x, st2 = mamba_one(x, pj, stj)
            new_states.append(st2)
        ssm_new = jnp.stack([st[0] for st in new_states])
        cx_new = jnp.stack([st[1][0] for st in new_states])
        cbc_new = jnp.stack([st[1][1] for st in new_states])
        return x, (ssm_new, cx_new, cbc_new, new_kv)

    if remat:
        group = jax.checkpoint(group,
                               policy=jax.checkpoint_policies.nothing_saveable)

    have_cache = cache is not None
    gn2 = 2 * cfg.ssm_groups * cfg.ssm_state
    if have_cache:
        states = (cache["ssm"], (cache["conv_x"], cache["conv_bc"]))
    else:
        states = (
            jnp.zeros((n_groups, period, x.shape[0], cfg.ssm_heads,
                       cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            (jnp.zeros((n_groups, period, x.shape[0], cfg.ssm_conv - 1,
                        cfg.d_inner), _dtype(cfg)),
             jnp.zeros((n_groups, period, x.shape[0], cfg.ssm_conv - 1,
                        gn2), _dtype(cfg))))
    kvs = (cache.get("shared_k"), cache.get("shared_v")) if have_cache \
        else (None, None)

    def scan_body(x, inp):
        gi, p_grp, st_ssm, st_cx, st_cbc, k_b, v_b = inp
        kv = (k_b, v_b) if k_b is not None else None
        x, (s2, cx2, cbc2, kv2) = group(
            x, (gi, p_grp, (st_ssm, (st_cx, st_cbc)), kv))
        outs = {"ssm": s2, "conv_x": cx2, "conv_bc": cbc2}
        if kv2 is not None:
            outs["shared_k"], outs["shared_v"] = kv2
        return x, outs

    idx = jnp.arange(n_groups)
    have_kv = hybrid and kvs[0] is not None
    kv_xs_k = kvs[0][:n_groups] if have_kv else None
    kv_xs_v = kvs[1][:n_groups] if have_kv else None
    xs = (idx, params["blocks"], states[0], states[1][0], states[1][1],
          kv_xs_k, kv_xs_v)
    x, outs = jax.lax.scan(scan_body, x, xs)
    new_cache = dict(outs) if have_cache else {}

    # tail layers (eager, at most period-1 of them)
    if tail:
        tail_sites = hybrid and (n_groups * period in cfg.shared_attn_sites())
        if tail_sites:
            kv = None
            if have_cache:
                kv = (cache["shared_k"][n_groups], cache["shared_v"][n_groups])
            x, kv2 = shared_block(x, n_groups, kv)
            if have_cache and kv2 is not None:
                new_cache["shared_k"] = jnp.concatenate(
                    [new_cache["shared_k"], kv2[0][None]], axis=0)
                new_cache["shared_v"] = jnp.concatenate(
                    [new_cache["shared_v"], kv2[1][None]], axis=0)
        new_tail = []
        for t in range(tail):
            pj = jax.tree.map(lambda a: a[t], params["tail"])
            stj = (cache["ssm_tail"][t],
                   (cache["conv_x_tail"][t], cache["conv_bc_tail"][t])) \
                if have_cache else (None, None)
            x, st2 = mamba_one(x, pj, stj)
            new_tail.append(st2)
        if have_cache:
            new_cache["ssm_tail"] = jnp.stack([st[0] for st in new_tail])
            new_cache["conv_x_tail"] = jnp.stack([st[1][0]
                                                  for st in new_tail])
            new_cache["conv_bc_tail"] = jnp.stack([st[1][1]
                                                   for st in new_tail])
    return x, new_cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(cfg, params, tokens, rules: ShardingRules = NO_RULES):
    x = params["embed"][tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return rules.act(x, "batch", "seq", "embed")


def lm_logits(cfg, params, x, rules: ShardingRules = NO_RULES):
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = x @ head
    logits = L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return rules.act(logits, "batch", "seq", "vocab")



import os as _os


def _legacy_cache_scan() -> bool:
    """Baseline A/B toggle for EXPERIMENTS.md §Perf: the legacy path
    threads per-layer caches through scan xs/ys, which copies every
    layer's full cache slice once per step.  The default (carry) path
    keeps stacked caches in the scan carry and writes only the new token
    rows in place."""
    return _os.environ.get("REPRO_LEGACY_CACHE_SCAN", "0") == "1"


def _scatter_pos(cur_len, b, s):
    """(B, s) write positions for a per-slot length vector: row i writes
    ``cur_len[i] + [0, s)``.  Paired with ``mode="drop"`` scatters so a
    padded row (speculative verify pads ragged drafts to one width) whose
    tail would run past the buffer writes nothing there."""
    return (jnp.asarray(cur_len, jnp.int32)[:, None]
            + jnp.arange(s, dtype=jnp.int32)[None])


def _stack_write(stack, new, li, cur_len, *, layout: str = "bthd"):
    """Write ``new`` (B, s, ...) into a stacked cache at layer ``li``,
    position ``cur_len`` (scalar, or a (B,) per-slot vector — continuous
    batching decode at s == 1, speculative verify at s > 1).

    layout "bthd": stack (L, B, T, ...) — MLA latents/rope keys.
    layout "bhtd": stack (L, B, H, T, D) — KV stacks in attention-native
    layout (no transpose on the read path)."""
    cl = jnp.asarray(cur_len)
    zero = jnp.int32(0)
    if layout == "bhtd":
        if cl.ndim == 0:
            new = jnp.swapaxes(new, 1, 2)      # (B,H,s,D)
            start = (jnp.asarray(li, jnp.int32), zero, zero,
                     cl.astype(jnp.int32), zero)
            return jax.lax.dynamic_update_slice(
                stack, new[None].astype(stack.dtype), start)
        b, s = new.shape[:2]
        pos = _scatter_pos(cl, b, s)
        # non-contiguous advanced indices: broadcast (B, s) dims lead, so
        # the slice's H lands after them — the value is (B, s, H, D) as-is
        return stack.at[li, jnp.arange(b)[:, None], :, pos].set(
            new.astype(stack.dtype), mode="drop")
    if cl.ndim == 0:
        start = (jnp.asarray(li, jnp.int32), zero, cl.astype(jnp.int32)) \
            + (zero,) * (stack.ndim - 3)
        return jax.lax.dynamic_update_slice(
            stack, new[None].astype(stack.dtype), start)
    b, s = new.shape[:2]
    pos = _scatter_pos(cl, b, s)
    return stack.at[li, jnp.arange(b)[:, None], pos].set(
        new.astype(stack.dtype), mode="drop")



def _quantize_kv(new):
    """(B,s,H,D) -> (int8 (B,H,s,D)-compatible values, scales (B,s,H))."""
    m = jnp.max(jnp.abs(new.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(new.astype(jnp.float32) / m[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, m.astype(jnp.float32)


def _stack_write_q8(stack, scale_stack, new, li, cur_len):
    """Quantize ``new`` (B,s,H,D) and write into int8 stack + scale stack."""
    q, m = _quantize_kv(new)
    stack = _stack_write(stack, q, li, cur_len, layout="bhtd")
    # scales: (L,B,H,T): write m (B,s,H) -> (B,H,s)
    cl = jnp.asarray(cur_len)
    if cl.ndim == 0:
        zero = jnp.int32(0)
        ms = jnp.swapaxes(m, 1, 2)
        start = (jnp.asarray(li, jnp.int32), zero, zero, cl.astype(jnp.int32))
        scale_stack = jax.lax.dynamic_update_slice(
            scale_stack, ms[None].astype(scale_stack.dtype), start)
    else:
        b, s = m.shape[:2]
        pos = _scatter_pos(cl, b, s)
        scale_stack = scale_stack.at[li, jnp.arange(b)[:, None], :, pos].set(
            m.astype(scale_stack.dtype), mode="drop")
    return stack, scale_stack


def _stack_layer(stack, li):
    return jax.lax.dynamic_index_in_dim(stack, li, 0, keepdims=False)


def _paged_positions(block_tables, new, cur_len, page_size):
    """(page, offset) scatter coordinates for writing ``new`` (B, s, ...)
    into a page pool through ``block_tables`` (B, nb) at ``cur_len``
    (scalar, or a (B,) per-slot vector — decode at s == 1, speculative
    verify at s > 1).  Per-slot positions past the table's last block
    (a verify batch's padded rows near ``max_len``) are redirected to the
    trash page instead of clamping into a real one."""
    b, s = new.shape[:2]
    cl = jnp.asarray(cur_len, jnp.int32)
    if cl.ndim == 0:
        pos = cl + jnp.arange(s, dtype=jnp.int32)          # (s,)
        page = block_tables[:, pos // page_size]            # (B, s)
        off = jnp.broadcast_to((pos % page_size)[None], (b, s))
    else:
        pos = _scatter_pos(cl, b, s)                        # (B, s)
        blk = pos // page_size
        nb = block_tables.shape[1]
        page = jnp.take_along_axis(block_tables,
                                   jnp.minimum(blk, nb - 1), axis=1)
        page = jnp.where(blk < nb, page, 0)                 # trash page
        off = pos % page_size
    return page, off


def _paged_write(pages, new, block_tables, cur_len):
    """Scatter ``new`` (B, s, H, D) into a (P, H, page_size, D) pool.

    The paged counterpart of :func:`_update_kv`: physical pages come from
    the block table, so the write touches only the slot's own tokens —
    never a (B, max_len) slice.  Unmapped table entries point at the
    allocator's trash page, keeping masked garbage writes harmless.
    """
    page, off = _paged_positions(block_tables, new, cur_len, pages.shape[2])
    return pages.at[page, :, off].set(new.astype(pages.dtype))


def _paged_write_q8(pages, scale_pages, new, block_tables, cur_len):
    """Quantize ``new`` (B, s, H, D) and scatter into int8 pages plus
    per-(page, head, token) scale pages (P, H, page_size)."""
    q, m = _quantize_kv(new)
    page, off = _paged_positions(block_tables, new, cur_len, pages.shape[2])
    pages = pages.at[page, :, off].set(q)
    scale_pages = scale_pages.at[page, :, off].set(
        m.astype(scale_pages.dtype))
    return pages, scale_pages


def _paged_attend(cfg, q, k_pages, v_pages, block_tables, q_positions,
                  kv_len, window, k_scale=None, v_scale=None):
    """Attention over a paged cache.  Decode (s == 1, no window) runs the
    paged flash-decode kernel; everything else — prefill chunks starting
    at any offset, and windowed layers at any width — runs the paged
    flash-prefill kernel.  Both read K/V through the block table at HBM
    rate: the cache is never gathered into a dense (B, Hkv, T, D) buffer.
    """
    from repro.kernels import ops as K

    b, s = q.shape[:2]
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
    if s == 1 and window is None:
        out = K.paged_decode_attention(q[:, 0], k_pages, v_pages,
                                       block_tables, lens,
                                       k_scale=k_scale, v_scale=v_scale,
                                       softcap=cfg.attn_softcap)
        return out[:, None]
    offs = q_positions[:, 0].astype(jnp.int32)             # (B,)
    out = K.paged_prefill_attention(jnp.swapaxes(q, 1, 2), k_pages, v_pages,
                                    block_tables, offs,
                                    k_scale=k_scale, v_scale=v_scale,
                                    softcap=cfg.attn_softcap, window=window)
    return jnp.swapaxes(out, 1, 2)


def _update_kv(buf, new, cur_len, *, layout: str = "bthd"):
    """Write ``new`` (B,s,H,D) into a cache buffer at ``cur_len``.

    ``layout`` "bthd": buf (B,T,H,D), seq axis 1 (offload runtime / MLA
    latents (B,T,R)).  "bhtd": buf (B,H,T,D), seq axis 2 (stacked KV).
    Scalar ``cur_len``: contiguous dynamic_update_slice; vector (B,):
    per-slot scatter (continuous-batching decode at s == 1, speculative
    verify at s > 1 — per-slot tails past the buffer are dropped).
    """
    cl = jnp.asarray(cur_len)
    if cl.ndim == 0:
        if layout == "bhtd":
            new = jnp.swapaxes(new, 1, 2)      # (B,H,s,D)
            axis = 2
        else:
            axis = 1
        return jax.lax.dynamic_update_slice_in_dim(
            buf, new.astype(buf.dtype), cl, axis=axis)
    b, s = new.shape[:2]
    pos = _scatter_pos(cl, b, s)
    rows = jnp.arange(b)[:, None]
    if layout == "bhtd":
        # broadcast advanced dims lead: value stays (B, s, H, D) as-is
        return buf.at[rows, :, pos].set(new.astype(buf.dtype), mode="drop")
    return buf.at[rows, pos].set(new.astype(buf.dtype), mode="drop")


def _positions_from(cur_len, b, s):
    base = jnp.arange(s, dtype=jnp.int32)[None, :]
    cl = jnp.asarray(cur_len, jnp.int32)
    if cl.ndim == 1:
        return cl[:, None] + base
    return cl + base + jnp.zeros((b, 1), jnp.int32)


def _add_learned_pos(cfg, params, x, positions):
    if cfg.pos_emb == "learned":
        x = x + params["pos"][positions]
    return x


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward_train(cfg: ModelConfig, params: Dict, batch: Dict,
                  rules: ShardingRules = NO_RULES,
                  return_aux: bool = False) -> jax.Array:
    """Full causal forward over a (B, S) batch -> logits (B, S, V).

    ``batch`` carries "tokens" and, for stub-frontend families, "embeds"
    (vlm: replaces token embeddings; encdec: encoder frames).
    """
    if cfg.embeds_input and "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg))
        b, s = x.shape[:2]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = embed_tokens(cfg, params, tokens, rules)
    positions = _positions_from(jnp.int32(0), b, s)
    x = _add_learned_pos(cfg, params, x, positions)

    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "encdec":
        enc = _encode(cfg, params, batch["enc_embeds"], rules)
        x, _ = _encdec_decoder(cfg, params, x, positions, enc, rules,
                               cache=None, cur_len=None)
    elif cfg.family in ("ssm", "hybrid"):
        emb0 = x if cfg.family == "hybrid" else None
        x, _ = _mamba_trunk(cfg, params, x, positions, rules=rules,
                            remat=cfg.remat, emb0=emb0)
    else:
        x, _, aux = _transformer_trunk(cfg, params, x, positions, rules=rules,
                                       remat=cfg.remat)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params, x, rules)
    if return_aux:
        return logits, aux
    return logits


def _encode(cfg, params, enc_embeds, rules):
    x = enc_embeds.astype(_dtype(cfg))
    b, s = x.shape[:2]
    x = x + params["enc_pos"][None, :s]
    positions = _positions_from(jnp.int32(0), b, s)

    def body(x, p):
        h = L.apply_norm(cfg, p["ln1"], x)
        q, k, v = L.gqa_qkv(cfg, p["attn"], h, positions, rules)
        out = L.attention(q, k, v, q_positions=positions,
                          kv_positions=positions, causal=False, rules=rules)
        x = x + L.attn_out(cfg, p["attn"], out, rules)
        x = _apply_ffn(cfg, p, x, "dense", rules)
        return x, ()

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.apply_norm(cfg, params["enc_final_norm"], x)


def _encdec_decoder(cfg, params, x, positions, enc, rules, *, cache,
                    cur_len):
    """Decoder with self attention (+cache) and cross attention to ``enc``
    (or to cached cross K/V when ``enc`` is None)."""
    def body(x, inp):
        p_blk, pc, kv_in, cross_in = inp
        kvc = (kv_in["k0"], kv_in["v0"]) if kv_in is not None else None
        x, kv_out = _apply_attn_layer(cfg, p_blk["pos0"], x, positions,
                                      kind="dense", kv_cache=kvc,
                                      cur_len=cur_len, rules=rules)
        # cross attention
        hx = L.apply_norm(cfg, pc["ln"], x)
        q, ck, cv = L.gqa_qkv(cfg, pc["attn"], hx, positions, rules)
        if cross_in is not None:
            ck, cv = cross_in
        kvpos = jnp.arange(ck.shape[1])
        out = L.attention(q, ck, cv, q_positions=positions,
                          kv_positions=kvpos[None], causal=False, rules=rules)
        x = x + L.attn_out(cfg, pc["attn"], out, rules)
        x = _apply_ffn(cfg, p_blk["pos0"], x, "dense", rules)
        outs = {}
        if kv_out is not None:
            outs["k0"], outs["v0"] = kv_out
        if cross_in is None:
            outs["cross_k"], outs["cross_v"] = ck, cv
        return x, outs

    kv_xs = None
    cross_xs = None
    if cache is not None:
        kv_xs = {"k0": cache["k0"], "v0": cache["v0"]}
        if enc is None:
            cross_xs = (cache["cross_k"], cache["cross_v"])

    if enc is not None and cache is not None:
        # prefill: compute cross K/V from encoder output, store them
        def body_with_enc(x, inp):
            p_blk, pc, kv_in = inp
            kvc = (kv_in["k0"], kv_in["v0"])
            x, kv_out = _apply_attn_layer(cfg, p_blk["pos0"], x, positions,
                                          kind="dense", kv_cache=kvc,
                                          cur_len=cur_len, rules=rules)
            hx = L.apply_norm(cfg, pc["ln"], x)
            q, _, _ = L.gqa_qkv(cfg, pc["attn"], hx, positions, rules)
            encpos = _positions_from(jnp.int32(0), enc.shape[0], enc.shape[1])
            _, ck, cv = L.gqa_qkv(cfg, pc["attn"], enc, encpos, rules)
            kvpos = jnp.arange(ck.shape[1])
            out = L.attention(q, ck, cv, q_positions=positions,
                              kv_positions=kvpos[None], causal=False,
                              rules=rules)
            x = x + L.attn_out(cfg, pc["attn"], out, rules)
            x = _apply_ffn(cfg, p_blk["pos0"], x, "dense", rules)
            return x, {"k0": kv_out[0], "v0": kv_out[1],
                       "cross_k": ck, "cross_v": cv}

        x, outs = jax.lax.scan(body_with_enc, x,
                               (params["blocks"], params["cross"], kv_xs))
        return x, outs

    if enc is not None:
        # training: cross K/V recomputed per layer from enc
        def body_train(x, inp):
            p_blk, pc = inp
            x, _ = _apply_attn_layer(cfg, p_blk["pos0"], x, positions,
                                     kind="dense", kv_cache=None,
                                     cur_len=None, rules=rules)
            hx = L.apply_norm(cfg, pc["ln"], x)
            q, _, _ = L.gqa_qkv(cfg, pc["attn"], hx, positions, rules)
            encpos = _positions_from(jnp.int32(0), enc.shape[0], enc.shape[1])
            _, ck, cv = L.gqa_qkv(cfg, pc["attn"], enc, encpos, rules)
            kvpos = jnp.arange(ck.shape[1])
            out = L.attention(q, ck, cv, q_positions=positions,
                              kv_positions=kvpos[None], causal=False,
                              rules=rules)
            x = x + L.attn_out(cfg, pc["attn"], out, rules)
            x = _apply_ffn(cfg, p_blk["pos0"], x, "dense", rules)
            return x, ()

        body_fn = body_train
        if cfg.remat:
            body_fn = jax.checkpoint(
                body_fn, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body_fn, x, (params["blocks"], params["cross"]))
        return x, {}

    # decode: use cached cross K/V
    x, outs = jax.lax.scan(body, x, (params["blocks"], params["cross"],
                                     kv_xs, cross_xs))
    return x, outs


def prefill(cfg: ModelConfig, params: Dict, batch: Dict, cache: Dict,
            rules: ShardingRules = NO_RULES,
            all_logits: bool = False) -> Tuple[Dict, jax.Array]:
    """Process the prompt, fill the cache, return (cache, last_logits).

    ``all_logits=True`` returns logits for EVERY position, (B, S, V)
    instead of (B, V) — the speculative-verify shape, where one
    prefill-shaped pass must score each draft position's next-token
    distribution."""
    if cfg.embeds_input and "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg))
        b, s = x.shape[:2]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = embed_tokens(cfg, params, tokens, rules)
    cur_len = cache["len"]
    positions = _positions_from(cur_len, b, s)
    x = _add_learned_pos(cfg, params, x, positions)

    new_cache = dict(cache)
    if cfg.family == "encdec":
        # prefill carries encoder frames; decode reuses the cached cross K/V
        enc = None
        if "enc_embeds" in batch:
            enc = _encode(cfg, params, batch["enc_embeds"], rules)
        x, outs = _encdec_decoder(cfg, params, x, positions, enc, rules,
                                  cache=cache, cur_len=cur_len)
        new_cache.update(outs)
    elif cfg.family in ("ssm", "hybrid"):
        emb0 = x if cfg.family == "hybrid" else None
        x, outs = _mamba_trunk(cfg, params, x, positions, cache=cache,
                               cur_len=cur_len, rules=rules, emb0=emb0)
        new_cache.update(outs)
    else:
        x, outs, _ = _transformer_trunk(cfg, params, x, positions,
                                        cache=cache, cur_len=cur_len,
                                        rules=rules)
        new_cache.update(outs)
    new_cache["len"] = cur_len + s
    x = L.apply_norm(cfg, params["final_norm"],
                     x if all_logits else x[:, -1:])
    logits = lm_logits(cfg, params, x, rules)
    return new_cache, (logits if all_logits else logits[:, 0])


def decode_step(cfg: ModelConfig, params: Dict, token: jax.Array,
                cache: Dict, rules: ShardingRules = NO_RULES
                ) -> Tuple[Dict, jax.Array]:
    """One decode step: token (B,) int32 -> (cache, logits (B, V))."""
    batch = {"tokens": token[:, None]}
    new_cache, logits = prefill(cfg, params, batch, cache, rules)
    return new_cache, logits


# ---------------------------------------------------------------------------
# Backend-parameterized execution — one layer-math core, pluggable linears
# ---------------------------------------------------------------------------
#
# The functions below drive the SAME per-layer math as the jitted scan trunk
# (_apply_attn_layer / _apply_ffn / layers.gqa_qkv / layers.mlp), but with
# every weight matmul routed through an injected ``linear(x, name)``
# callable.  A resident backend implements ``linear`` as a device matmul
# over its own weight inventory; the HeteGen backend implements it as the
# engine's alpha-split host/device execution (repro.serving.backends).

def decoder_layer(cfg, p, x, positions, *, kv_cache, cur_len, linear,
                  kind: str = "dense", rules: ShardingRules = NO_RULES,
                  ops: Optional[Dict] = None, block_tables=None):
    """One full decoder layer (attention + FFN), backend-parameterized.

    ``kv_cache`` is this layer's (k, v) buffers in (B, T, Hkv, hd) layout;
    ``cur_len`` is a scalar, or a (B,) per-slot length vector for
    continuous batching.  ``ops`` optionally carries pre-jitted "norm" /
    "attend" device pieces (:func:`make_backend_ops`) for eager drivers.
    Returns (x, (k_buf, v_buf)).

    With ``block_tables`` the layer runs against paged page pools instead
    (``kv_cache`` = (k_pages, v_pages[, k_scale, v_scale]); see
    :mod:`repro.serving.kv_cache`): writes scatter through the block
    table and decode attends via the paged flash-decode kernel.
    """
    ops = ops or {}
    x, new_kv = _apply_attn_layer(cfg, p, x, positions, kind=kind,
                                  kv_cache=kv_cache, cur_len=cur_len,
                                  rules=rules, linear=linear,
                                  kv_format="bthd",
                                  norm_fn=ops.get("norm"),
                                  attend_fn=ops.get("attend"),
                                  block_tables=block_tables,
                                  paged_attend_fn=ops.get("paged_attend"))
    x = _apply_ffn(cfg, p, x, kind, rules, linear=linear,
                   norm_fn=ops.get("norm"))
    return x, new_kv


def make_backend_ops(cfg: ModelConfig) -> Dict:
    """Jitted device pieces for the eager offload driver: norms, the
    attention core (per-layer window is a static arg), and the lm head —
    the small on-device math between engine linears stays fused, as in the
    pre-seam offload runtime."""
    from functools import partial

    def _attend(q, k_buf, v_buf, q_positions, kv_len, window):
        kvpos = jnp.arange(k_buf.shape[1])
        return L.attention(q, k_buf, v_buf, q_positions=q_positions,
                           kv_positions=kvpos[None], kv_len=kv_len,
                           causal=True, window=window,
                           attn_softcap=cfg.attn_softcap, kv_format="bthd")

    def _paged(q, k_pages, v_pages, block_tables, q_positions, kv_len,
               window, k_scale=None, v_scale=None):
        return _paged_attend(cfg, q, k_pages, v_pages, block_tables,
                             q_positions, kv_len, window,
                             k_scale=k_scale, v_scale=v_scale)

    return {"norm": jax.jit(partial(L.apply_norm, cfg)),
            "attend": jax.jit(_attend, static_argnums=(5,)),
            "paged_attend": jax.jit(_paged, static_argnums=(6,)),
            "logits": jax.jit(lambda shared, x: lm_logits(cfg, shared, x))}


def extract_backend_params(cfg: ModelConfig, params: Dict):
    """Split a stacked param pytree into (shared, weights, biases).

    ``weights``/``biases`` map flat linear names ("blk{l}.wq", ...) to
    per-layer arrays — the inventory a LinearBackend executes; ``shared``
    keeps everything the layer math reads directly (embeddings, norms,
    qk-norm scales, lm head) plus per-layer small-param dicts under
    "layers".
    """
    if cfg.family not in ("dense", "vlm") or cfg.attn_kind != "gqa":
        raise NotImplementedError(
            "backend execution supports dense GQA decoders "
            f"(got family={cfg.family}, attn={cfg.attn_kind})")
    period = _pattern_period(cfg)
    weights: Dict = {}
    biases: Dict = {}
    shared: Dict = {"embed": params["embed"],
                    "final_norm": params["final_norm"]}
    for kname in ("lm_head", "pos"):
        if kname in params:
            shared[kname] = params[kname]
    supers = [jax.tree.map(lambda a, _g=g: a[_g], params["blocks"])
              for g in range(cfg.n_layers // period)]
    layers = []
    for l in range(cfg.n_layers):
        g, j = divmod(l, period)
        blk = supers[g][f"pos{j}"]
        a, m = blk["attn"], blk.get("mlp", {})
        for nm in ("wq", "wk", "wv", "wo"):
            weights[f"blk{l}.{nm}"] = a[nm]
        if cfg.attn_bias:
            for nm, bk in (("wq", "bq"), ("wk", "bk"), ("wv", "bv"),
                           ("wo", "bo")):
                biases[f"blk{l}.{nm}"] = a[bk]
        for nm in ("w_gate", "w_up", "w_down", "w_in"):
            if nm in m:
                weights[f"blk{l}.{nm}"] = m[nm]
        if cfg.attn_bias and "b_in" in m:
            biases[f"blk{l}.w_in"] = m["b_in"]
            biases[f"blk{l}.w_down"] = m["b_down"]
        small = {"ln1": blk["ln1"], "ln2": blk["ln2"],
                 "attn": {}, "mlp": {}}
        if cfg.post_norm:
            small["ln1_post"] = blk["ln1_post"]
            small["ln2_post"] = blk["ln2_post"]
        if cfg.qk_norm:
            small["attn"] = {"q_norm": a["q_norm"], "k_norm": a["k_norm"]}
        layers.append(small)
    shared["layers"] = layers
    return shared, weights, biases


def init_backend_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Per-layer KV cache for backend execution: "k{l}"/"v{l}" buffers in
    (B, T, Hkv, hd) layout plus "len" (scalar; continuous batching replaces
    it with a (B,) per-slot vector).  Batch lives on axis 0 of every
    buffer.  The paged alternative (no dense (B, T) buffers) is minted by
    :meth:`repro.serving.kv_cache.PagedKVCache.init_cache`."""
    dt = _dtype(cfg)
    cache: Dict = {"len": jnp.zeros((), jnp.int32)}
    for l in range(cfg.n_layers):
        cache[f"k{l}"] = jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd),
                                   dt)
        cache[f"v{l}"] = jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd),
                                   dt)
    return cache


def backend_prefill(cfg: ModelConfig, shared: Dict, batch: Dict, cache: Dict,
                    *, linear, ops: Optional[Dict] = None,
                    all_logits: bool = False) -> Tuple[Dict, jax.Array]:
    """Prompt/step processing through the shared layer math with all
    linears routed through ``linear(x, "blk{l}.{name}")``.  Mirrors
    :func:`prefill` for the dense GQA families.  ``ops`` carries the
    pre-jitted device pieces for eager drivers (:func:`make_backend_ops`).
    ``all_logits=True`` returns (B, S, V) per-position logits — the
    speculative-verify shape.

    A cache holding "pages_k{l}"/"pages_v{l}" pools plus "block_tables"
    (from :class:`repro.serving.kv_cache.PagedKVCache`) switches every
    layer to the paged plumbing; "pages_ks{l}"/"pages_vs{l}" scale pools
    additionally select q8 (int8-page) writes."""
    ops = ops or {}
    if cfg.embeds_input and "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg))
        b, s = x.shape[:2]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = embed_tokens(cfg, shared, tokens)
    cur_len = cache["len"]
    positions = _positions_from(cur_len, b, s)
    x = _add_learned_pos(cfg, shared, x, positions)
    kinds = cfg.layer_kinds()
    new_cache = dict(cache)
    paged = "pages_k0" in cache         # paged pools instead of dense bufs
    bt = cache.get("block_tables")
    q8 = "pages_ks0" in cache
    for l in range(cfg.n_layers):
        lin = (lambda h, nm, _l=l: linear(h, f"blk{_l}.{nm}"))
        if paged:
            kvc = (cache[f"pages_k{l}"], cache[f"pages_v{l}"])
            if q8:
                kvc += (cache[f"pages_ks{l}"], cache[f"pages_vs{l}"])
        else:
            kvc = (cache[f"k{l}"], cache[f"v{l}"])
        x, kv = decoder_layer(cfg, shared["layers"][l], x, positions,
                              kv_cache=kvc, cur_len=cur_len, linear=lin,
                              kind=kinds[l], ops=ops,
                              block_tables=bt if paged else None)
        if paged:
            new_cache[f"pages_k{l}"], new_cache[f"pages_v{l}"] = kv[:2]
            if q8:
                (new_cache[f"pages_ks{l}"],
                 new_cache[f"pages_vs{l}"]) = kv[2:]
        else:
            new_cache[f"k{l}"], new_cache[f"v{l}"] = kv
    new_cache["len"] = cur_len + s
    norm = ops.get("norm") or (lambda pp, h: L.apply_norm(cfg, pp, h))
    x = norm(shared["final_norm"], x if all_logits else x[:, -1:])
    if "logits" in ops:
        logits = ops["logits"](shared, x)
    else:
        logits = lm_logits(cfg, shared, x)
    return new_cache, (logits if all_logits else logits[:, 0])


def backend_decode(cfg: ModelConfig, shared: Dict, token: jax.Array,
                   cache: Dict, *, linear, ops: Optional[Dict] = None
                   ) -> Tuple[Dict, jax.Array]:
    """One decode step through the backend seam: token (B,) -> logits."""
    return backend_prefill(cfg, shared, {"tokens": token[:, None]}, cache,
                           linear=linear, ops=ops)
