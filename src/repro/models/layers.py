"""Layer math shared by the jitted production path and the offload runtime.

Pure functions over explicit parameter dicts — no module framework.  Every
attention variant required by the assigned architectures lives here:

  * GQA with RoPE / learned positions, optional QK-norm
  * sliding-window (local) + global alternating layers, logit softcapping
    (gemma2)
  * MLA — multi-head latent attention with low-rank q/kv and a compressed
    KV cache (minicpm3)
  * MoE top-1 with capacity-based GShard dispatch + optional shared expert
    (llama4 scout/maverick)
  * gated-SiLU / squared-ReLU / GELU / ReLU MLPs

Activation tensors are annotated with logical axes through a
:class:`~repro.distributed.shardings.ShardingRules` object (no-op outside a
mesh), so the same code serves single-host offload serving and the 512-chip
dry-run.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.shardings import NO_RULES, ShardingRules


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            plus_one: bool = False) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = scale.astype(jnp.float32)
    if plus_one:
        w = w + 1.0
    return (y * w).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


def apply_norm(cfg, p: Dict, x: jax.Array) -> jax.Array:
    if cfg.norm_kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps,
                   plus_one=cfg.post_norm)   # gemma-style (1+w) rmsnorm


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE to ``x`` of shape (..., S, H, D) at ``positions`` (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., S, half)
    ang = ang[..., None, :]                                    # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Attention core
# ---------------------------------------------------------------------------

NEG_INF = -2.0e38


def _mask_bias(q_pos: jax.Array, kv_pos: jax.Array, *, causal: bool,
               window: Optional[int], kv_len=None) -> jax.Array:
    """(..., Sq, Skv) additive bias from position/validity constraints."""
    qp = q_pos[..., :, None].astype(jnp.int32)
    kp = kv_pos[..., None, :].astype(jnp.int32)
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), dtype=bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    if kv_len is not None:
        ok &= kp < jnp.asarray(kv_len, jnp.int32)[..., None, None]
    ok &= kp >= 0
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _attend_block(q: jax.Array, k: jax.Array, v: jax.Array,
                  bias: jax.Array, cap: Optional[float],
                  kv_format: str = "bthd") -> jax.Array:
    """q (B,Sq,Hq,D); k/v (B,Skv,Hkv,D) ["bthd"] or (B,Hkv,Skv,D)
    ["bhtd" — the KV-cache-native layout: the scores dot consumes it with
    no transpose]; bias (B,Sq,Skv) -> (B,Sq,Hq,D)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2] if kv_format == "bthd" else k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    kspec = "btkd" if kv_format == "bthd" else "bktd"
    scores = jnp.einsum(f"bskgd,{kspec}->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / math.sqrt(d))
    scores = softcap(scores, cap)
    scores = scores + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(f"bkgst,{kspec}->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              q_positions: jax.Array, kv_positions: jax.Array,
              causal: bool = True, window: Optional[int] = None,
              attn_softcap: Optional[float] = None, kv_len=None,
              chunk_q: int = 1024, kv_format: str = "bthd",
              rules: ShardingRules = NO_RULES) -> jax.Array:
    """Masked multi-head attention with GQA, windows and softcap.

    Memory-bounded: when Sq*Skv is large the query axis is processed in
    chunks via ``lax.scan`` ("lazy flash" — each chunk's full score row fits
    comfortably in memory, so no online-softmax bookkeeping is needed; the
    Pallas kernel in :mod:`repro.kernels.flash_attention` is the TPU
    hot-path equivalent with true block tiling).
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1] if kv_format == "bthd" else k.shape[2]
    if sq * skv <= 4096 * 2048 or sq == 1 or sq % chunk_q != 0:
        bias = _mask_bias(jnp.broadcast_to(q_positions, (b, sq)),
                          jnp.broadcast_to(kv_positions, (b, skv)),
                          causal=causal, window=window, kv_len=kv_len)
        return _attend_block(q, k, v, bias, attn_softcap, kv_format)

    n_chunks = sq // chunk_q
    qs = q.reshape(b, n_chunks, chunk_q, hq, d).transpose(1, 0, 2, 3, 4)
    qp = jnp.broadcast_to(q_positions, (b, sq))
    qp = qp.reshape(b, n_chunks, chunk_q).transpose(1, 0, 2)
    kvp = jnp.broadcast_to(kv_positions, (b, skv))

    def body(_, qc):
        qi, qpi = qc
        bias = _mask_bias(qpi, kvp, causal=causal, window=window,
                          kv_len=kv_len)
        return None, _attend_block(qi, k, v, bias, attn_softcap, kv_format)

    _, out = jax.lax.scan(body, None, (qs, qp))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, d)
    return rules.act(out, "batch", "seq", "heads", None)


# ---------------------------------------------------------------------------
# GQA attention block (qkv projections + rope + attend + out projection)
# ---------------------------------------------------------------------------

def gqa_qkv(cfg, p: Dict, x: jax.Array, positions: jax.Array,
            rules: ShardingRules = NO_RULES, linear=None
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Project to q/k/v (with optional bias, qk-norm, rope).

    ``linear`` is the pluggable matmul backend: ``linear(x, "wq")`` must
    return ``x @ W_q`` *with bias already applied* (resident device matmul,
    HeteGen alpha-split, ...).  ``None`` uses the weights in ``p`` directly.
    """
    b, s, _ = x.shape
    hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    if linear is not None:
        q = linear(x, "wq").reshape(b, s, hq, hd)
        k = linear(x, "wk").reshape(b, s, hkv, hd)
        v = linear(x, "wv").reshape(b, s, hkv, hd)
    else:
        q = (x @ p["wq"]).reshape(b, s, hq, hd)
        k = (x @ p["wk"]).reshape(b, s, hkv, hd)
        v = (x @ p["wv"]).reshape(b, s, hkv, hd)
        if cfg.attn_bias:
            q = q + p["bq"].reshape(hq, hd)
            k = k + p["bk"].reshape(hkv, hd)
            v = v + p["bv"].reshape(hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_emb == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if s > 1:
        # decode (s == 1) skips these: with a seq-sharded cache
        # (kv_heads < TP) the useful layout follows the cache, not the
        # head axis — measured neutral on nemotron decode but strictly
        # fewer constraints for GSPMD to fight
        q = rules.act(q, "batch", "seq", "heads", None)
        k = rules.act(k, "batch", "seq", "kv_heads", None)
        v = rules.act(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def attn_out(cfg, p: Dict, o: jax.Array, rules: ShardingRules = NO_RULES,
             linear=None) -> jax.Array:
    b, s, hq, hd = o.shape
    if linear is not None:
        y = linear(o.reshape(b, s, hq * hd), "wo")
    else:
        y = o.reshape(b, s, hq * hd) @ p["wo"]
        if cfg.attn_bias:
            y = y + p["bo"]
    return rules.act(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (minicpm3 / deepseek style)
# ---------------------------------------------------------------------------

def mla_project_q(cfg, p: Dict, x: jax.Array, positions: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Return (q_nope (B,S,H,dn), q_rope (B,S,H,dr))."""
    b, s, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    ql = rmsnorm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
    q = (ql @ p["wq_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_latent_kv(cfg, p: Dict, x: jax.Array, positions: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Compressed per-token cache entries: (latent (B,S,R), k_rope (B,S,dr))."""
    dr = cfg.qk_rope_dim
    ckv = x @ p["wkv_a"]                                # (B,S,R+dr)
    latent = rmsnorm(ckv[..., :cfg.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = ckv[..., cfg.kv_lora_rank:]
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return latent, k_rope


def mla_attend(cfg, p: Dict, q_nope: jax.Array, q_rope: jax.Array,
               latent: jax.Array, k_rope: jax.Array, *,
               q_positions, kv_positions, kv_len=None,
               causal: bool = True, absorbed: bool = True,
               rules: ShardingRules = NO_RULES) -> jax.Array:
    """Attention over the compressed cache.

    ``absorbed=True`` uses the weight-absorption identity
    ``(q_nope @ Wk) . latent == (q_nope @ Wk_absorbed) . latent`` so scores
    are computed directly in the R-dim latent space and values are expanded
    only once per step — the memory-optimal decode path.  ``absorbed=False``
    decompresses K/V per token (reference path).
    """
    b, sq, h, dn = q_nope.shape
    skv = latent.shape[1]
    r = cfg.kv_lora_rank
    dv = cfg.v_head_dim
    wk = p["wk_b"].reshape(r, h, dn)                    # latent -> k_nope
    wv = p["wv_b"].reshape(r, h, dv)                    # latent -> v
    scale = 1.0 / math.sqrt(dn + cfg.qk_rope_dim)

    bias = _mask_bias(jnp.broadcast_to(q_positions, (b, sq)),
                      jnp.broadcast_to(kv_positions, (b, skv)),
                      causal=causal, window=None, kv_len=kv_len)

    if absorbed:
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wk)        # absorb Wk
        s_nope = jnp.einsum("bshr,btr->bhst", q_lat, latent,
                            preferred_element_type=jnp.float32)
    else:
        k_nope = jnp.einsum("btr,rhd->bthd", latent, wk)
        s_nope = jnp.einsum("bshd,bthd->bhst", q_nope, k_nope,
                            preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bshd,btd->bhst", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) * scale + bias[:, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    if absorbed:
        o_lat = jnp.einsum("bhst,btr->bshr", probs.astype(latent.dtype),
                           latent)
        o = jnp.einsum("bshr,rhd->bshd", o_lat, wv)
    else:
        vfull = jnp.einsum("btr,rhd->bthd", latent, wv)
        o = jnp.einsum("bhst,bthd->bshd", probs.astype(vfull.dtype), vfull)
    y = o.reshape(b, sq, h * dv) @ p["wo"]
    return rules.act(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp(cfg, p: Dict, x: jax.Array, rules: ShardingRules = NO_RULES,
        linear=None) -> jax.Array:
    kind = cfg.mlp_kind
    if linear is None:
        def linear(h, nm):
            y = h @ p[nm]
            bias = {"w_in": "b_in", "w_down": "b_down"}.get(nm)
            if cfg.attn_bias and bias is not None and bias in p:
                y = y + p[bias]
            return y
    if kind.startswith("gated"):
        act = jax.nn.silu if kind == "gated_silu" else jax.nn.gelu
        h = act(linear(x, "w_gate")) * linear(x, "w_up")
    else:
        h = linear(x, "w_in")
        if kind == "relu2":
            h = jnp.square(jax.nn.relu(h))
        elif kind == "gelu":
            h = jax.nn.gelu(h)
        else:
            h = jax.nn.relu(h)
    h = rules.act(h, "batch", "seq", "ff")
    y = linear(h, "w_down")
    return rules.act(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# MoE — top-1 (Switch-style) with GShard capacity dispatch
# ---------------------------------------------------------------------------

def _moe_decode(cfg, p: Dict, x: jax.Array, rules: ShardingRules
                ) -> jax.Array:
    """Exact (dropless) top-1 routing for single-token decode.

    Capacity-dispatch with capacity == batch (the worst case: every token
    on one expert), so no token is ever dropped and the result is exactly
    the routed computation.  Tokens move to the (model-sharded) experts
    via small all-to-alls; expert weights never move.

    [§Perf hillclimb #1] The previous implementation gathered per-token
    expert weights (``we[idx]``); under expert-sharded weights GSPMD
    lowered that to an all-reduce of a (B, d, f) gathered-weight tensor —
    3.6 s of ICI time per decode step for scout (48 MoE layers x 3
    matmuls x 2.7 GB).  Dispatching activations instead moves ~MBs:
    measured collective term 3629 ms -> ~1 ms on the same cell (see
    EXPERIMENTS.md §Perf).
    """
    b, _, d = x.shape
    e = cfg.n_experts
    xt = x[:, 0]
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(gates, axis=-1)                     # (B,)
    gate = jnp.max(gates, axis=-1).astype(xt.dtype)

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)   # (B, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot - 1.0
    slot = jnp.max(pos, axis=-1).astype(jnp.int32)       # (B,)
    slot_oh = jax.nn.one_hot(slot, b, dtype=jnp.float32)
    dispatch = jnp.einsum("be,bc->bec", onehot, slot_oh).astype(xt.dtype)

    xin = jnp.einsum("bec,bd->ecd", dispatch, xt)        # (E, C=B, d)
    xin = rules.act(xin, "experts", None, "embed")
    if cfg.mlp_kind.startswith("gated"):
        act = jax.nn.silu if cfg.mlp_kind == "gated_silu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", xin, p["we_gate"])) \
            * jnp.einsum("ecd,edf->ecf", xin, p["we_up"])
    else:
        h = jax.nn.relu(jnp.einsum("ecd,edf->ecf", xin, p["we_in"]))
    h = rules.act(h, "experts", None, None)
    xout = jnp.einsum("ecf,efd->ecd", h, p["we_down"])
    xout = rules.act(xout, "experts", None, "embed")
    y = jnp.einsum("bec,ecd->bd", dispatch * gate[:, None, None], xout)
    y = y[:, None]
    if cfg.shared_expert:
        y = y + mlp(cfg, {"w_gate": p["ws_gate"], "w_up": p["ws_up"],
                          "w_down": p["ws_down"]}, x, rules)
    return rules.act(y, "batch", "seq", "embed")


def moe(cfg, p: Dict, x: jax.Array, rules: ShardingRules = NO_RULES
        ) -> jax.Array:
    """Top-1 routed experts with capacity; optional always-on shared expert.

    Dispatch/combine are one-hot einsums (cost ~= tokens * group * cf * d
    flops, a few %% of expert compute) — the standard TPU-friendly pattern;
    the expert dimension is sharded over the 'model' mesh axis (EP), so
    GSPMD materializes the token all-to-all.  Single-token decode takes the
    exact gather path (:func:`_moe_decode`).
    """
    b, s, d = x.shape
    if s == 1:
        return _moe_decode(cfg, p, x, rules)
    e, cf = cfg.n_experts, cfg.capacity_factor
    gs = min(cfg.moe_group_size, b * s)
    tokens = b * s
    n_groups = max(tokens // gs, 1)
    gs = tokens // n_groups
    xg = x.reshape(n_groups, gs, d)
    xg = rules.act(xg, "expert_group", None, "embed")

    logits = (xg @ p["router"].astype(xg.dtype)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)              # (G, gs, E)
    idx = jnp.argmax(gates, axis=-1)                     # top-1
    gate = jnp.max(gates, axis=-1)
    cap = max(1, int(math.ceil(gs * cf * cfg.top_k / e)))

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)   # (G, gs, E)
    pos = jnp.cumsum(onehot, axis=1) * onehot - 1.0      # position in expert
    keep = (pos >= 0) & (pos < cap)                      # capacity drop
    slot = jnp.max(pos, axis=-1)                         # (G, gs) chosen slot
    slot_oh = jax.nn.one_hot(jnp.clip(slot, 0, cap - 1), cap,
                             dtype=jnp.float32)          # (G, gs, cap)
    dispatch = jnp.einsum("gse,gsc->gsec", onehot * keep, slot_oh)
    combine = dispatch * gate[..., None, None]

    xin = jnp.einsum("gsec,gsd->gecd", dispatch.astype(xg.dtype), xg)
    xin = rules.act(xin, "expert_group", "experts", None, "embed")
    if cfg.mlp_kind == "gated_silu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["we_gate"])) \
            * jnp.einsum("gecd,edf->gecf", xin, p["we_up"])
    else:
        h = jax.nn.relu(jnp.einsum("gecd,edf->gecf", xin, p["we_in"]))
    h = rules.act(h, "expert_group", "experts", None, None)
    xout = jnp.einsum("gecf,efd->gecd", h, p["we_down"])
    xout = rules.act(xout, "expert_group", "experts", None, "embed")
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(xout.dtype), xout)
    y = y.reshape(b, s, d)

    if cfg.shared_expert:
        y = y + mlp(cfg, {"w_gate": p["ws_gate"], "w_up": p["ws_up"],
                          "w_down": p["ws_down"]}, x, rules)
    return rules.act(y, "batch", "seq", "embed")


def moe_aux_loss(cfg, p: Dict, x: jax.Array) -> jax.Array:
    """Switch-style load-balancing loss (used by the training path)."""
    b, s, d = x.shape
    logits = (x.reshape(-1, d) @ p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    idx = jnp.argmax(probs, axis=-1)
    e = cfg.n_experts
    frac_tokens = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return e * jnp.sum(frac_tokens * frac_probs)
