"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Model code annotates tensors with *logical* axes ("batch", "embed", "ff",
"experts", ...); a :class:`ShardingRules` table maps those to mesh axes.
Inside ``jit`` the annotations become ``with_sharding_constraint``s; outside
a mesh context they are no-ops, so the same model code runs single-device.

The default table implements:

  * data parallelism over ("pod", "data") on the batch axis
    (the DCN-crossing "pod" axis only ever carries data parallelism);
  * Megatron tensor parallelism over "model" on heads / ff / vocab;
  * expert parallelism over "model" for MoE experts;
  * optional sequence parallelism ("sp") — activations between blocks are
    sharded over "model" on the sequence axis, turning TP all-reduces into
    reduce-scatter + all-gather pairs (used by the perf hillclimb).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


# logical axis -> mesh axes (None = replicated)
DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "qkv": ("model",),          # fused qkv output dim
    "ff": ("model",),
    "experts": ("model",),
    "expert_group": ("pod", "data"),
    "vocab": ("model",),
    "kv_seq": None,             # decode KV cache sequence axis
    "ssm_heads": ("model",),
    "conv_ch": ("model",),
    "stage": None,
}

# sequence-parallel overlay: activations sharded over model on seq between
# blocks; KV-cache seq sharded when kv_heads cannot fill the model axis.
SP_OVERLAY = {
    "seq": ("model",),
}


def _mesh_axis_names() -> Tuple[str, ...]:
    m = getattr(jax.sharding, "get_abstract_mesh", None)
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and mesh.axis_names:
            return tuple(mesh.axis_names)
    except Exception:
        pass
    return ()


@dataclasses.dataclass
class ShardingRules:
    """Maps logical axes to mesh axes and applies activation constraints.

    All spec construction is *shape-guarded*: a mesh axis is only assigned
    to a tensor dim it divides (longest prefix of the mapped axes whose
    size product divides the dim), so unusual head counts / tiny batches
    degrade to replication instead of GSPMD padding blowups.
    """

    table: Dict[str, Optional[Tuple[str, ...]]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))
    mesh_axes: Tuple[str, ...] = ()          # axes present in the mesh
    mesh_shape: Dict[str, int] = dataclasses.field(default_factory=dict)
    mesh: Optional[object] = None            # concrete Mesh: act() binds
                                             # NamedShardings (a bare
                                             # PartitionSpec constraint
                                             # needs an ambient mesh and
                                             # silently cannot apply here)
    enabled: bool = True

    @classmethod
    def for_mesh(cls, mesh, *, sequence_parallel: bool = False,
                 overrides: Optional[Dict] = None) -> "ShardingRules":
        table = dict(DEFAULT_RULES)
        if sequence_parallel:
            table.update(SP_OVERLAY)
        if overrides:
            table.update(overrides)
        return cls(table=table, mesh_axes=tuple(mesh.axis_names),
                   mesh_shape={a: int(n) for a, n in
                               zip(mesh.axis_names, mesh.devices.shape)},
                   mesh=mesh)

    @classmethod
    def disabled(cls) -> "ShardingRules":
        return cls(enabled=False)

    # ------------------------------------------------------------------
    def _axes_for(self, logical: Optional[str],
                  dim: Optional[int]) -> Optional[Tuple[str, ...]]:
        if logical is None:
            return None
        mesh_axes = self.table.get(logical)
        if mesh_axes is None:
            return None
        present = tuple(a for a in mesh_axes if a in self.mesh_axes)
        if not present:
            return None
        if dim is None:
            return present
        # longest prefix whose size product divides the dim
        out = []
        prod = 1
        for a in present:
            n = self.mesh_shape.get(a, 1)
            if dim % (prod * n) == 0:
                out.append(a)
                prod *= n
            else:
                break
        return tuple(out) or None

    def _mk_spec(self, logical, shape=None) -> P:
        cands = []
        for i, ax in enumerate(logical):
            dim = None if shape is None else shape[i]
            cands.append(self._axes_for(ax, dim) or ())
        # a mesh axis may appear at most once per spec: resolve conflicts
        # right-to-left so inner, more specific dims win (e.g. under
        # sequence parallelism the q/k/v head dim keeps "model" and the
        # seq dim drops it — Megatron-SP semantics)
        used: set = set()
        parts: list = [None] * len(cands)
        for i in range(len(cands) - 1, -1, -1):
            axes = tuple(a for a in cands[i] if a not in used)
            used.update(axes)
            parts[i] = None if not axes else (
                axes[0] if len(axes) == 1 else axes)
        return P(*parts)

    def spec(self, *logical: Optional[str]) -> P:
        """PartitionSpec for a tensor whose dims carry these logical axes."""
        return self._mk_spec(logical)

    def spec_for_shape(self, shape, *logical: Optional[str]) -> P:
        assert len(shape) == len(logical), (shape, logical)
        return self._mk_spec(logical, shape)

    def act(self, x, *logical: Optional[str]):
        """Annotate an activation; no-op when rules are disabled."""
        if not self.enabled or not self.mesh_axes:
            return x
        spec = self.spec_for_shape(x.shape, *logical)
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, spec))
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except (ValueError, RuntimeError):
            return x                      # no mesh context (eager/offload path)


NO_RULES = ShardingRules.disabled()
