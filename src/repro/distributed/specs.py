"""Derive PartitionSpec trees for params / optimizer state / caches / batches.

Specs are assigned by leaf *path* (the parameter's role) and guarded by the
leaf *shape* (a mesh axis is never assigned to a dim it does not divide).
The table implements Megatron-style TP + EP with batch data-parallel over
("pod", "data") — see DESIGN.md §4.

Used by launch/dryrun.py (and any real launcher) to produce in_shardings /
out_shardings for ``jax.jit``.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.shardings import ShardingRules
from repro.models.config import ModelConfig


# (path regex, logical axes per dim — right-aligned against leaf shape)
# first match wins; "×" rows document intent
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings: vocab-sharded (so tied lm_head logits shard over vocab;
    # the input-side gather costs one small (tokens x d) all-reduce)
    (r"\['embed'\]$",            ("vocab", None)),
    (r"\['lm_head'\]$",          (None, "vocab")),
    (r"\['pos'\]$",              (None, None)),
    (r"\['enc_pos'\]$",          (None, None)),
    # attention projections (leading stack dims absorbed as None)
    (r"\['wq'\]$",               (None, "qkv")),
    (r"\['wk'\]$",               (None, "qkv")),
    (r"\['wv'\]$",               (None, "qkv")),
    (r"\['wo'\]$",               ("qkv", None)),
    (r"\['bq'\]$",               ("qkv",)),
    (r"\['bk'\]$",               ("qkv",)),
    (r"\['bv'\]$",               ("qkv",)),
    # MLA factors: head-expanded matrices shard on the head dim
    (r"\['wq_b'\]$",             (None, "qkv")),
    (r"\['wk_b'\]$",             (None, "qkv")),
    (r"\['wv_b'\]$",             (None, "qkv")),
    (r"\['wq_a'\]$",             (None, None)),
    (r"\['wkv_a'\]$",            (None, None)),
    # MLP
    (r"\['w_gate'\]$",           (None, "ff")),
    (r"\['w_up'\]$",             (None, "ff")),
    (r"\['w_in'\]$",             (None, "ff")),
    (r"\['b_in'\]$",             ("ff",)),
    (r"\['w_down'\]$",           ("ff", None)),
    # MoE experts (EP on the expert dim)
    (r"\['we_\w+'\]$",           ("experts", None, None)),
    (r"\['ws_gate'\]$",          (None, "ff")),
    (r"\['ws_up'\]$",            (None, "ff")),
    (r"\['ws_down'\]$",          ("ff", None)),
    (r"\['router'\]$",           (None, None)),
    # mamba2 (heads on model axis; B/C small -> replicated)
    (r"\['w_z'\]$",              (None, "ff")),
    (r"\['w_x'\]$",              (None, "ff")),
    (r"\['w_dt'\]$",             (None, "ssm_heads")),
    (r"\['w_bc'\]$",             (None, None)),
    (r"\['conv_x_w'\]$",         (None, "ff")),
    (r"\['conv_x_b'\]$",         ("ff",)),
    (r"\['conv_bc_\w'\]$",       (None, None)),
    (r"\['A_log'\]$",            ("ssm_heads",)),
    (r"\['D'\]$",                ("ssm_heads",)),
    (r"\['dt_bias'\]$",          ("ssm_heads",)),
    (r"\['gnorm'\]$",            ("ff",)),
    (r"\['out_proj'\]$",         ("ff", None)),
    # shared-block lora
    (r"\['shared_lora'\]\['a'\]$", (None, None, None)),
    (r"\['shared_lora'\]\['b'\]$", (None, None, "qkv")),
    (r"\['proj'\]$",             (None, None)),
)

_EXTRA_TABLE = {}


_FSDP_IN = re.compile(
    r"\['(wq|wk|wv|w_gate|w_up|w_in|w_z|w_x)'\]$")   # shard input dim (d)
_FSDP_OUT = re.compile(r"\['(wo|w_down|out_proj)'\]$")  # shard output dim
# experts: gate/up shard the OUTPUT dim (f) so the d-contraction stays
# local; down shards its INPUT dim (f) to match — one activation
# all-reduce per MoE layer instead of three (§Perf hillclimb #1)
_FSDP_EXPERT_OUT = re.compile(r"\['we_(gate|up|in)'\]$")
_FSDP_EXPERT_IN = re.compile(r"\['we_down'\]$")
# fsdp only pays when the model-sharded leaf is still large; below this
# the weight all-gathers it induces cost more than the memory it saves
FSDP_MIN_BYTES_PER_CHIP = 512 * 2**20


def _spec_for_param(path: str, shape: Tuple[int, ...],
                    rules: ShardingRules, fsdp: bool = False,
                    kv_divisible: bool = True) -> P:
    # GQA with kv_heads < TP: sharding wk/wv at sub-head granularity
    # forces GSPMD to all-gather attention scores (1.1 TB/step measured on
    # mistral train — §Perf hillclimb #2).  Megatron's answer: replicate
    # K/V projections across the model axis; q heads carry the TP.
    if not kv_divisible and re.search(r"\['(wk|wv|bk|bv)'\]$", path):
        parts = [None] * len(shape)
        # the replicated-over-model K/V weights of a >=100B arch would
        # cost GBs per chip (nemotron: 8.7 GB); store their input dim
        # data-sharded instead (one small activation all-reduce per use)
        if fsdp and len(shape) >= 2 and "data" in rules.mesh_axes:
            dp = rules.mesh_shape.get("data", 1)
            if shape[-2] % dp == 0:
                parts[-2] = "data"
        return P(*parts)
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path):
            # right-align logical axes against the shape (stack dims -> None)
            pad = (None,) * (len(shape) - len(logical))
            logical = pad + tuple(logical)[-len(shape):] \
                if len(logical) <= len(shape) else logical[-len(shape):]
            parts = []
            for dim, ax in zip(shape, logical):
                if ax in _EXTRA_TABLE:
                    axes = _EXTRA_TABLE[ax]
                    if axes is None:
                        parts.append(None)
                        continue
                    prod = 1
                    keep = []
                    for a in axes:
                        n = rules.mesh_shape.get(a, 1)
                        if a in rules.mesh_axes and dim % (prod * n) == 0:
                            keep.append(a)
                            prod *= n
                    parts.append(tuple(keep) or None if len(keep) != 1
                                 else keep[0])
                else:
                    got = rules._axes_for(ax, dim)
                    parts.append(None if got is None
                                 else (got[0] if len(got) == 1 else got))
            if fsdp and "data" in rules.mesh_axes:
                dp = rules.mesh_shape.get("data", 1)
                # bytes/chip after the base (model/expert) sharding
                shard_f = 1
                for part in parts:
                    for a in (part if isinstance(part, tuple)
                              else (part,) if part else ()):
                        shard_f *= rules.mesh_shape.get(a, 1)
                n_elems = 1
                for dsz in shape:
                    n_elems *= dsz
                per_chip = n_elems * 2 / max(shard_f, 1)     # bf16
                tgt = None
                if per_chip >= FSDP_MIN_BYTES_PER_CHIP:
                    if _FSDP_IN.search(path) and len(shape) >= 2:
                        tgt = len(shape) - 2       # input dim
                    elif _FSDP_OUT.search(path) and len(shape) >= 2:
                        tgt = len(shape) - 1       # output dim
                    elif _FSDP_EXPERT_OUT.search(path) and len(shape) >= 3:
                        tgt = len(shape) - 1       # per-expert output dim
                    elif _FSDP_EXPERT_IN.search(path) and len(shape) >= 3:
                        tgt = len(shape) - 2       # down: input dim (f)
                if tgt is not None and parts[tgt] is None \
                        and shape[tgt] % dp == 0:
                    parts[tgt] = "data"
            return P(*parts)
    return P(*([None] * len(shape)))    # norms, scalars, biases: replicated


def param_specs(cfg: ModelConfig, rules: ShardingRules,
                params_shape: Optional[Any] = None, *,
                serve: bool = False):
    """PartitionSpec tree matching ``init_params(cfg, key)``.

    ``serve``: serving keeps wk/wv TP-sharded even at sub-head
    granularity (the cache is seq-sharded, attention reads are local);
    training replicates them when kv_heads < TP to keep attention math
    head-local (§Perf hillclimb #2).
    """
    if params_shape is None:
        from repro.models.model import init_params
        params_shape = jax.eval_shape(
            lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    ms = rules.mesh_shape.get("model", 1)
    kv_div = True if serve else \
        ((cfg.n_kv_heads % ms == 0) if cfg.n_kv_heads else True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        out.append(_spec_for_param(path, leaf.shape, rules, fsdp=cfg.fsdp,
                                   kv_divisible=kv_div))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_specs(cfg: ModelConfig, rules: ShardingRules, opt_shape,
                    pspecs) -> Any:
    """Optimizer-state specs mirroring the parameter layout.

    adamw m/v inherit the param spec; adafactor vr/vc drop the reduced dim.
    Scalars replicate.
    """
    pflat = {jax.tree_util.keystr(kp): spec for kp, spec in
             jax.tree_util.tree_flatten_with_path(pspecs)[0]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_shape)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        spec = None
        m = re.match(r"\['(m|v)'\](.*)$", path)
        if m:
            spec = pflat.get(m.group(2))
        m2 = re.match(r"\['s'\](.*)\['(vr|vc|v)'\]$", path)
        if m2:
            base = pflat.get(m2.group(1))
            if base is not None:
                parts = list(base)
                if m2.group(2) == "vr":      # mean over last dim
                    parts = parts[:-1]
                elif m2.group(2) == "vc":    # mean over second-to-last dim
                    parts = parts[:-2] + parts[-1:]
                spec = P(*parts)
        if spec is None or len(spec) != len(leaf.shape):
            spec = P(*([None] * len(leaf.shape)))
        out.append(spec)
    return jax.tree_util.tree_unflatten(treedef, out)


def cache_specs(cfg: ModelConfig, rules: ShardingRules, cache_shape) -> Any:
    """Specs for the KV/state cache.

    Batch shards over ("pod","data") where divisible; heads shard over
    "model" when the head count divides it, otherwise the sequence dim
    takes the model axis (long-context small-head caches).
    """
    ms = rules.mesh_shape.get("model", 1)
    batch_axes = [a for a in ("pod", "data") if a in rules.mesh_axes]

    def bspec(dim):
        keep, prod = [], 1
        for a in batch_axes:
            n = rules.mesh_shape[a]
            if dim % (prod * n) == 0:
                keep.append(a)
                prod *= n
        return tuple(keep) or None if len(keep) != 1 else keep[0]

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        shape = leaf.shape
        key = path.strip("[]'")
        if re.search(r"\['(ks|vs)\d+'\]$", path):
            st, b, hkv, t = shape
            if hkv % ms == 0 and ms > 1:
                spec = P(None, bspec(b), "model", None)
            elif t % ms == 0 and ms > 1:
                spec = P(None, bspec(b), None, "model")
            else:
                spec = P(None, bspec(b), None, None)
        elif re.search(r"\['(k|v|shared_k|shared_v)\d*'\]$", path):
            # (stack, B, Hkv, T, hd) — attention-native layout
            st, b, hkv, t, hd = shape
            if hkv % ms == 0 and ms > 1:
                spec = P(None, bspec(b), "model", None, None)
            elif t % ms == 0 and ms > 1:
                spec = P(None, bspec(b), None, "model", None)
            else:
                spec = P(None, bspec(b), None, None, None)
        elif re.search(r"\['(lat|kr)\d+'\]$", path):
            st, b, t, r = shape
            spec = P(None, bspec(b), "model" if t % ms == 0 else None, None)
        elif re.search(r"\['cross_[kv]'\]$", path):
            st, b, t, hkv, hd = shape
            spec = P(None, bspec(b), None,
                     "model" if hkv % ms == 0 else None, None)
        elif re.search(r"\['ssm(_tail)?'\]$", path):
            # (..., B, H, P, N)
            h = shape[-3]
            lead = [None] * (len(shape) - 4)
            spec = P(*lead, bspec(shape[-4]),
                     "model" if h % ms == 0 else None, None, None)
        elif re.search(r"\['conv_(x|bc)(_tail)?'\]$", path):
            ch = shape[-1]
            lead = [None] * (len(shape) - 3)
            spec = P(*lead, bspec(shape[-3]), None,
                     "model" if ch % ms == 0 else None)
        else:                                 # "len" scalar etc.
            spec = P(*([None] * len(shape)))
        out.append(spec)
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_specs(cfg: ModelConfig, rules: ShardingRules, batch_shape) -> Any:
    batch_axes = [a for a in ("pod", "data") if a in rules.mesh_axes]

    def bspec(dim):
        keep, prod = [], 1
        for a in batch_axes:
            n = rules.mesh_shape[a]
            if dim % (prod * n) == 0:
                keep.append(a)
                prod *= n
        return tuple(keep) or None if len(keep) != 1 else keep[0]

    def one(leaf):
        shape = leaf.shape
        if not shape:
            return P()
        parts = [bspec(shape[0])] + [None] * (len(shape) - 1)
        return P(*parts)

    return jax.tree.map(one, batch_shape)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
