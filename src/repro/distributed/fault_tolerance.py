"""Fault tolerance for long multi-pod runs.

Pieces (each unit-tested; the multi-host signals are simulated here, the
interfaces are the production ones):

* :class:`StragglerDetector` — per-host EWMA of step times; a host whose
  smoothed step time exceeds ``factor`` x the fleet median is flagged (the
  runbook action at scale is to demote/replace it and let elastic restore
  resume the run).
* :func:`retry` — step-level retry with bounded attempts for transient
  failures (preempted collective, flaky host).
* :class:`PreemptionHandler` — SIGTERM -> checkpoint-now flag (maintenance
  events on cloud TPU fleets give a grace window).
* :class:`ElasticTopology` — given the currently-live device count, picks
  the largest supported (data, model) grid and rebuilds mesh+rules; with
  the elastic checkpoint layer (checkpoint/manager.py) a run continues on
  fewer/more hosts.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


class StragglerDetector:
    def __init__(self, alpha: float = 0.2, factor: float = 1.5,
                 warmup: int = 3):
        self.alpha = alpha
        self.factor = factor
        self.warmup = warmup
        self.ewma: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}

    def update(self, host: str, step_time: float) -> None:
        prev = self.ewma.get(host)
        self.ewma[host] = step_time if prev is None else \
            (1 - self.alpha) * prev + self.alpha * step_time
        self.counts[host] = self.counts.get(host, 0) + 1

    def stragglers(self) -> List[str]:
        ready = {h: t for h, t in self.ewma.items()
                 if self.counts[h] >= self.warmup}
        if len(ready) < 2:
            return []
        med = float(np.median(list(ready.values())))
        return [h for h, t in ready.items() if t > self.factor * med]

    def fleet_summary(self) -> Dict[str, float]:
        if not self.ewma:
            return {}
        vals = list(self.ewma.values())
        return {"median": float(np.median(vals)),
                "max": max(vals), "min": min(vals),
                "stragglers": len(self.stragglers())}


def retry(fn: Callable, *, attempts: int = 3, backoff: float = 0.0,
          exceptions: Tuple = (RuntimeError, OSError)):
    """Run ``fn`` with bounded retries on transient failures."""
    last = None
    for i in range(attempts):
        try:
            return fn()
        except exceptions as e:          # pragma: no cover - timing path
            last = e
            if backoff:
                time.sleep(backoff * (2 ** i))
    raise last


class PreemptionHandler:
    """SIGTERM sets a flag the train loop polls (checkpoint + exit)."""

    def __init__(self, install: bool = True):
        self.triggered = False
        self._prev = None
        if install:
            try:
                self._prev = signal.signal(signal.SIGTERM, self._on_signal)
            except ValueError:           # not in main thread (tests)
                self._prev = None

    def _on_signal(self, signum, frame):
        self.triggered = True

    def trigger(self) -> None:           # test hook
        self.triggered = True

    def reset(self) -> None:
        self.triggered = False


@dataclasses.dataclass(frozen=True)
class TopologyChoice:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    devices_used: int


class ElasticTopology:
    """Pick the best mesh for however many devices are currently alive.

    Preference: keep the model axis as requested, shrink/grow data (and
    pod) parallelism — losing a host should cost throughput, not the run.
    """

    def __init__(self, model_parallel: int = 16,
                 axes: Tuple[str, ...] = ("data", "model")):
        self.model_parallel = model_parallel
        self.axes = axes

    def choose(self, n_devices: int) -> TopologyChoice:
        mp = self.model_parallel
        while mp > 1 and n_devices % mp:
            mp //= 2
        dp = n_devices // mp
        # data axis should get any leftover power
        return TopologyChoice(shape=(dp, mp), axes=("data", "model"),
                              devices_used=dp * mp)

    def make_mesh(self, devices: Optional[list] = None):
        devices = devices if devices is not None else jax.devices()
        choice = self.choose(len(devices))
        devs = np.array(devices[:choice.devices_used]).reshape(choice.shape)
        from jax.sharding import Mesh
        return Mesh(devs, choice.axes)


def reshard_state(state, mesh, spec_fn):
    """Re-place a restored state pytree onto a new mesh.

    ``spec_fn(path, leaf) -> PartitionSpec`` supplies the target layout.
    """
    from jax.sharding import NamedSharding

    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    out = []
    for kp, leaf in flat:
        spec = spec_fn(jax.tree_util.keystr(kp), leaf)
        out.append(jax.device_put(leaf, NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)
