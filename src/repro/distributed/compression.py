"""Gradient compression for DCN-crossing collectives.

At 1000+ node scale the pod-crossing gradient all-reduce rides the
data-center network (25 GB/s/host vs 50 GB/s/link ICI), so the cross-pod
term dominates.  This module provides int8 uniform quantization with
per-chunk scales and **error feedback** (the quantization residual is
carried into the next step, which keeps SGD convergence — Karimireddy et
al. 2019):

    q, scale = quantize(g + e);   e' = (g + e) - dequantize(q, scale)

``compressed_psum_mean`` runs inside ``shard_map``: each member all-gathers
the int8 payload + fp32 scales (wire bytes ~= 1/4 of fp32) and reduces
locally — the collective itself moves compressed data, unlike
quantize-then-psum-fp32 schemes.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def quantize_int8(x: jax.Array, chunk: int = 2048
                  ) -> Tuple[jax.Array, jax.Array, Tuple[int, ...]]:
    """Uniform symmetric int8 quantization with per-chunk scales.

    Returns (q int8 (n_chunks, chunk), scales fp32 (n_chunks,), shape).
    """
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % chunk
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127
                 ).astype(jnp.int8)
    return q, scale, shape


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    shape: Tuple[int, ...]) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = int(np.prod(shape)) if shape else 1
    return flat[:n].reshape(shape)


def quantization_error(x: jax.Array, chunk: int = 2048) -> jax.Array:
    q, s, shp = quantize_int8(x, chunk)
    return x.astype(jnp.float32) - dequantize_int8(q, s, shp)


# ---------------------------------------------------------------------------
# Error-feedback state over a gradient pytree
# ---------------------------------------------------------------------------

def ef_init(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_compress(grads, ef_state, chunk: int = 2048):
    """(grads, error) -> (quantized payloads, new error)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s, shp = quantize_int8(corrected, chunk)
        new_e = corrected - dequantize_int8(q, s, shp)
        return (q, s, shp), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    payload = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_ef = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return payload, new_ef


# ---------------------------------------------------------------------------
# Compressed mean-all-reduce (shard_map collective)
# ---------------------------------------------------------------------------

def compressed_psum_mean(x: jax.Array, axis_name: str,
                         chunk: int = 2048) -> jax.Array:
    """Mean over ``axis_name`` members moving int8 on the wire.

    all_gather(int8 q) + all_gather(fp32 scales), dequantize + mean locally.
    Wire bytes: n + n/chunk*4  vs  4n for fp32 psum (~3.9x compression).
    """
    q, scale, shape = quantize_int8(x, chunk)
    qs = jax.lax.all_gather(q, axis_name)            # (N, n_chunks, chunk)
    ss = jax.lax.all_gather(scale, axis_name)
    n_members = qs.shape[0]
    deq = jax.vmap(lambda qq, sc: dequantize_int8(qq, sc, shape))(qs, ss)
    return jnp.mean(deq, axis=0)


def make_compressed_allreduce(mesh, axis: str = "pod", chunk: int = 2048):
    """Gradient-tree mean-all-reduce over ``axis`` with int8 wire format.

    Use for the DCN (pod) axis; ICI-local reductions stay fp32/bf16 (they
    are not the bottleneck).  Returns a function grads -> grads.
    """
    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    def reduce_tree(grads):
        def one(g):
            fn = shard_map(
                functools.partial(compressed_psum_mean, axis_name=axis,
                                  chunk=chunk),
                mesh=mesh,
                in_specs=P(axis),
                out_specs=P(),
                check_vma=False,   # value IS replicated after the local mean
            )
            stacked = jnp.broadcast_to(g[None], (mesh.shape[axis],) + g.shape)
            return fn(stacked)
        return jax.tree.map(one, grads)

    return reduce_tree
