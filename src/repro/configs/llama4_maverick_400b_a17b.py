"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Interpretation notes (DESIGN.md §5): experts alternate with dense FFN
layers (moe_layer_period=2) so the assigned totals reconcile with ~400B
total / ~17B active; a shared (always-on) expert accompanies the routed
top-1 expert, per the Llama-4 family design.  Text-only inputs (the "early
fusion" frontend is outside the assigned backbone).
"""
from repro.configs import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    top_k=1,
    shared_expert=True,
    moe_layer_period=2,
    qk_norm=True,
    rope_theta=500_000.0,
    max_seq=131_072,
    mlp_kind="gated_silu",
    tie_embeddings=False,
    optimizer="adafactor",
    fsdp=True,
))
