"""Tiny configs for tests/examples (fast on one CPU core)."""
from repro.configs import register
from repro.models.config import ModelConfig

TINY = register(ModelConfig(
    name="tiny",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    max_seq=512,
    dtype="float32",
    remat=False,
))

TINY_MOE = register(ModelConfig(
    name="tiny-moe",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    n_experts=4,
    shared_expert=True,
    moe_group_size=64,
    max_seq=512,
    dtype="float32",
    remat=False,
))
