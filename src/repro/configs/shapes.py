"""Assigned input shapes and ``input_specs`` (ShapeDtypeStruct stand-ins).

Each LM-family architecture is paired with four shapes:

    train_4k      seq 4,096   global_batch 256   -> train_step
    prefill_32k   seq 32,768  global_batch 32    -> prefill_step
    decode_32k    seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                   KV cache of seq_len)
    long_500k     seq 524,288 global_batch 1     -> serve_step; requires a
                  sub-quadratic trunk: run for SSM/hybrid archs only (the
                  skip list for full-attention archs is in DESIGN.md §5)

``input_specs`` allocates nothing — every leaf is a ``ShapeDtypeStruct`` —
so the 512-chip dry-run can lower/compile the full configs on one host.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import init_cache


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    s = SHAPES[shape]
    if s.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention trunk: 500k-token decode requires a "
                       "sub-quadratic architecture (DESIGN.md §5)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: str, *, batch_override: Optional[int] = None,
                spec_only: bool = True) -> Dict:
    """Step-function inputs for one (arch, shape) cell.

    train   -> {"tokens", "labels"} (+ stub-frontend embeds)
    prefill -> {"batch": {...}, "cache": zero cache sized to seq}
    decode  -> {"token", "cache" (full), "cache_len"}
    """
    s = SHAPES[shape]
    b = batch_override or s.batch
    i32, f = jnp.int32, jnp.dtype(cfg.dtype)

    def mk(shp, dt):
        if spec_only:
            return jax.ShapeDtypeStruct(shp, dt)
        if jnp.issubdtype(dt, jnp.integer):
            return jnp.zeros(shp, dt)
        return jnp.zeros(shp, dt)

    if s.kind == "train":
        batch: Dict = {}
        if cfg.embeds_input:
            batch["embeds"] = mk((b, s.seq, cfg.d_model), f)
        else:
            batch["tokens"] = mk((b, s.seq), i32)
        if cfg.family == "encdec":
            batch["enc_embeds"] = mk((b, cfg.encoder_seq, cfg.d_model), f)
            if "tokens" not in batch:
                batch["tokens"] = mk((b, s.seq), i32)
        batch["labels"] = mk((b, s.seq), i32)
        return {"batch": batch}

    if s.kind == "prefill":
        batch = {}
        if cfg.embeds_input:
            batch["embeds"] = mk((b, s.seq, cfg.d_model), f)
        else:
            batch["tokens"] = mk((b, s.seq), i32)
        if cfg.family == "encdec":
            batch["enc_embeds"] = mk((b, cfg.encoder_seq, cfg.d_model), f)
            if "tokens" not in batch:
                batch["tokens"] = mk((b, s.seq), i32)
        cache = init_cache(cfg, b, s.seq, spec_only=spec_only)
        return {"batch": batch, "cache": cache}

    # decode: one new token against a cache of length seq
    cache = init_cache(cfg, b, s.seq, spec_only=spec_only)
    return {"token": mk((b,), i32), "cache": cache}
