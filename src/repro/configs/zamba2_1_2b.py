"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (GQA kv=32) d_ff=8192,
ssm_state=64 — Mamba2 trunk + shared attention blocks.
[arXiv:2411.15242; hf]

The single shared transformer block (attention + MLP over
concat([hidden, embeddings]), 2*d wide) is invoked every 6 mamba layers
with a per-site LoRA (rank 128) on the query projection; its weights are
reused 7x per step, which the HeteGen module scheduler exploits
(gain g scales with calls — DESIGN.md §5).
"""
from repro.configs import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    shared_attn_period=6,
    shared_lora_rank=128,
    mlp_kind="gated_silu",
    rope_theta=10_000.0,
    max_seq=524_288,
    tie_embeddings=True,
))
