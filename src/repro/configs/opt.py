"""OPT family (Zhang et al., 2022) — the paper's evaluation models.

Used by the paper-reproduction benchmarks (Fig. 8, Tables 2-3): HeteGen
offloads OPT-6.7B/13B/30B on the A10+Xeon hardware model.  opt-125m /
opt-1.3b serve as runnable CPU-scale models for the end-to-end examples.
"""
from repro.configs import register
from repro.models.config import ModelConfig


def _opt(name, layers, d, heads, ffn):
    return register(ModelConfig(
        name=name,
        family="dense",
        n_layers=layers,
        d_model=d,
        n_heads=heads,
        n_kv_heads=heads,
        d_ff=ffn,
        vocab_size=50272,
        pos_emb="learned",
        norm_kind="layernorm",
        mlp_kind="relu",
        attn_bias=True,
        max_seq=2048,
        tie_embeddings=True,
        dtype="float32",
    ))


OPT_125M = _opt("opt-125m", 12, 768, 12, 3072)
OPT_1_3B = _opt("opt-1.3b", 24, 2048, 32, 8192)
OPT_6_7B = _opt("opt-6.7b", 32, 4096, 32, 16384)
OPT_13B = _opt("opt-13b", 40, 5120, 40, 20480)
OPT_30B = _opt("opt-30b", 48, 7168, 56, 28672)
