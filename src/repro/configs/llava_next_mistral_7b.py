"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]

The assigned entry specifies the transformer BACKBONE only; the anyres
vision frontend is a stub — ``input_specs`` provides precomputed patch
embeddings (B, S, d_model) for train/prefill; decode consumes tokens.
"""
from repro.configs import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    embeds_input=True,
    mlp_kind="gated_silu",
    rope_theta=1_000_000.0,
    max_seq=32_768,
    tie_embeddings=False,
))
