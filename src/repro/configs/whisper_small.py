"""whisper-small [audio] — 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865 — enc-dec, conv frontend (stub).  [arXiv:2212.04356;
unverified]

The conv1d+log-mel frontend is a stub: ``input_specs`` provides
precomputed frame embeddings (B, 1500, d).  The assigned decode shapes
(32k) exceed Whisper's published 448 decoder positions — the learned
position table is sized to the assignment (synthetic; DESIGN.md §5).
"""
from repro.configs import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    encoder_layers=12,
    encoder_seq=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    pos_emb="learned",
    norm_kind="layernorm",
    mlp_kind="gelu",
    attn_bias=True,
    max_seq=32_768,
    tie_embeddings=True,
))
