"""Architecture registry.

One module per assigned architecture (exact published dimensions, with the
source tag from the assignment) plus the paper's OPT family and tiny test
configs.  ``get_config(name)`` returns the full-size config; ``reduced(cfg)``
returns a smoke-test-scale config of the same family/pattern (small widths,
few experts, tiny vocab) used by per-arch CPU smoke tests — full configs are
exercised only via the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.models.config import ModelConfig

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


ASSIGNED_ARCHS = (
    "llama4-maverick-400b-a17b",
    "llama4-scout-17b-16e",
    "nemotron-4-340b",
    "gemma2-2b",
    "mistral-nemo-12b",
    "minicpm3-4b",
    "llava-next-mistral-7b",
    "whisper-small",
    "zamba2-1.2b",
    "mamba2-2.7b",
)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        gemma2_2b,
        llama4_maverick_400b_a17b,
        llama4_scout_17b_16e,
        llava_next_mistral_7b,
        mamba2_2_7b,
        minicpm3_4b,
        mistral_nemo_12b,
        nemotron_4_340b,
        opt,
        tiny,
        whisper_small,
        zamba2_1_2b,
    )


def reduced(cfg: ModelConfig, *, layers: int | None = None) -> ModelConfig:
    """Smoke-test-scale variant preserving family / pattern / mechanisms."""
    period = 1
    if cfg.layer_pattern:
        period = len(cfg.layer_pattern)
    elif cfg.n_experts and cfg.moe_layer_period > 1:
        period = cfg.moe_layer_period
    if cfg.shared_attn_period:
        shared_period = 2
        n_layers = layers or 5                             # 2 groups + tail
    else:
        shared_period = 0
        n_layers = layers or max(2, 2 * period)

    heads = min(cfg.n_heads, 4)
    kv = min(cfg.n_kv_heads, heads)
    if kv and heads % kv:
        kv = heads
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        max_seq=512,
        dtype="float32",
        remat=False,
        moe_group_size=64,
    )
    if cfg.n_experts:
        changes["n_experts"] = min(cfg.n_experts, 4)
        # no-drop capacity so train/prefill/decode paths agree exactly in
        # correctness tests (production configs keep capacity semantics)
        changes["capacity_factor"] = float(changes["n_experts"])
    if cfg.attn_kind == "mla":
        changes.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                       qk_rope_dim=8, v_head_dim=16, head_dim=None)
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.shared_attn_period:
        changes.update(shared_attn_period=shared_period,
                       shared_lora_rank=8)
    if cfg.encoder_layers:
        changes.update(encoder_layers=2, encoder_seq=24)
    if cfg.window:
        changes["window"] = 32
    return dataclasses.replace(cfg, **changes)
