"""minicpm3-4b [dense] — 62L d_model=2560 40H (GQA kv=40) d_ff=6400
vocab=73448 — MLA.  [hf:openbmb/MiniCPM3-4B; hf]

MLA dims from the published config: q_lora 768, kv_lora 256, qk_nope 64,
qk_rope 32, v_head 64.  The compressed cache stores (latent 256 + rope 32)
per token; decode uses the weight-absorption identity (layers.mla_attend).
"""
from repro.configs import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    mlp_kind="gated_silu",
    rope_theta=10_000.0,
    max_seq=32_768,
    tie_embeddings=True,
))
