"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000 — local+global alternating, logit softcap.
[arXiv:2408.00118; hf]"""
from repro.configs import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    layer_pattern="LG",          # local (4k sliding window) / global
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norm=True,              # sandwich norms, (1+w) rmsnorm
    emb_scale=True,
    mlp_kind="gated_gelu",
    rope_theta=10_000.0,
    max_seq=8192,
    tie_embeddings=True,
))
