"""mamba2-2.7b [ssm] — 64L d_model=2560 (attn-free) d_ff=0 vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]
"""
from repro.configs import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attn_kind="none",
    pos_emb="none",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    max_seq=1_048_576,
    tie_embeddings=True,
))
