"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Every layer is MoE (16 routed experts, top-1) plus a shared expert —
~109B total / ~17B active, matching the published Scout totals.
"""
from repro.configs import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="llama4-scout-17b-16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    n_experts=16,
    top_k=1,
    shared_expert=True,
    moe_layer_period=1,
    qk_norm=True,
    rope_theta=500_000.0,
    max_seq=131_072,
    mlp_kind="gated_silu",
    tie_embeddings=False,
    optimizer="adafactor",
    fsdp=True,
))
