"""Paged flash-prefill: chunk attention through a block table.

The chunked-prefill counterpart of :mod:`repro.kernels.paged_attention`:
a query chunk of ``S`` tokens starting at logical position ``kv_offset``
attends over everything already written to the slot's pages — all
previously prefilled chunks plus the causal triangle of the chunk itself
— without ever materializing the paged KV contiguously.  The grid is
(batch*q_heads, q_blocks, kv_blocks) with kv innermost exactly as in
:mod:`repro.kernels.flash_attention`; the K/V BlockSpec index maps
dereference the block table (a scalar-prefetch operand) so each kv step
DMAs one *physical* page, replacing the dense ``gather_pages`` copy the
old fallback paid per layer.

Mask layout: with per-batch ``kv_offset`` (the second scalar-prefetch
operand next to the block table), query row r of the chunk sits at
absolute position ``q_pos = kv_offset[b] + r`` while kv position is the
page-local ``k_pos = kj * page_size + column``.  The causal mask
``k_pos <= q_pos`` alone also covers the cache tail: the chunk's own K/V
are written before attention, so ``kv_len = kv_offset + S`` and every
position ``>= kv_len`` is above the last row's diagonal.  Pages past the
written range may be unmapped (the allocator's trash page) — they are
causally masked, contributing exact zeros to the online softmax, which
keeps the result bitwise independent of the chunking.  Sliding windows
add ``k_pos > q_pos - window``; fully-window-masked early pages are
harmless because their (m = -inf, p = 1) contribution is annihilated by
``alpha = 0`` at the first in-window page, and every row keeps at least
its own diagonal position in-window.

The q8 variant mirrors the decode kernel's: int8 pages plus
per-(page, head, token) scale pages, dequantized in VMEM.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _prefill_kernel(offs_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                    m_ref, l_ref, acc_ref, *,
                    scale, n_kv, page_size, block_q, hq, softcap, window):
    _prefill_body(offs_ref, bt_ref, q_ref, k_ref, v_ref, None, None, o_ref,
                  m_ref, l_ref, acc_ref, scale=scale, n_kv=n_kv,
                  page_size=page_size, block_q=block_q, hq=hq,
                  softcap=softcap, window=window)


def _prefill_kernel_q8(offs_ref, bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                       o_ref, m_ref, l_ref, acc_ref, *,
                       scale, n_kv, page_size, block_q, hq, softcap, window):
    _prefill_body(offs_ref, bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_ref, l_ref, acc_ref, scale=scale, n_kv=n_kv,
                  page_size=page_size, block_q=block_q, hq=hq,
                  softcap=softcap, window=window)


def _prefill_body(offs_ref, bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_ref, l_ref, acc_ref, *,
                  scale, n_kv, page_size, block_q, hq, softcap, window):
    bh = pl.program_id(0)
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    off = offs_ref[bh // hq]
    q_pos = off + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, page_size), 0)
    k_pos = kj * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, page_size), 1)

    def body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (ps, d)
        if ks_ref is not None:
            k = k * ks_ref[0, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)                   # (bq, ps)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0]
        if vs_ref is not None:
            v = v.astype(jnp.float32) \
                * vs_ref[0, 0].astype(jnp.float32)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # skip kv pages entirely above the q block's last diagonal — the
    # bound is traced (it depends on the prefetched kv_offset), which
    # pl.when handles fine
    @pl.when(kj * page_size <= off + qi * block_q + block_q - 1)
    def _():
        body()

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_prefill_attention(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, block_tables: jax.Array,
                            kv_offset: jax.Array, *,
                            k_scale: Optional[jax.Array] = None,
                            v_scale: Optional[jax.Array] = None,
                            softcap: Optional[float] = None,
                            window: Optional[int] = None,
                            block_q: int = 128,
                            interpret: bool = False) -> jax.Array:
    """q (B, Hq, S, D); k/v_pages (P, Hkv, page_size, D); block_tables
    (B, n_blocks) int32; kv_offset (B,) int32 -> (B, Hq, S, D).

    Query row r of batch b sits at absolute position ``kv_offset[b] + r``
    and attends causally over logical kv positions [0, kv_offset[b] + r]
    read through the block table.  The chunk's own K/V must already be
    written to the pages (kv_len == kv_offset + S); table entries past
    that range may point anywhere valid (e.g. the trash page).  With
    ``k_scale``/``v_scale`` (P, Hkv, page_size) the pages are int8 and
    dequantized per page inside VMEM.
    """
    b, hq, s, d = q.shape
    _, hkv, ps, _ = k_pages.shape
    nb = block_tables.shape[1]
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    q8 = k_scale is not None

    def _round_up(x, m):
        return (x + m - 1) // m * m

    bq = min(block_q, _round_up(s, 8))
    s_pad = _round_up(s, bq)
    qf = q.reshape(b * hq, s, d)
    if s_pad != s:
        # pad rows run at positions past the chunk; their output is
        # garbage sliced off below (the l==0 guard keeps them finite)
        qf = jnp.pad(qf, ((0, 0), (0, s_pad - s), (0, 0)))

    def kv_index(h, i, j, offs, bt):
        return (bt[h // hq, j], (h % hq) // group, 0, 0)

    in_specs = [
        pl.BlockSpec((1, bq, d), lambda h, i, j, offs, bt: (h, i, 0)),
        pl.BlockSpec((1, 1, ps, d), kv_index),
        pl.BlockSpec((1, 1, ps, d), kv_index),
    ]
    operands = [kv_offset.astype(jnp.int32), block_tables.astype(jnp.int32),
                qf, k_pages, v_pages]
    if q8:
        def sc_index(h, i, j, offs, bt):
            return (bt[h // hq, j], (h % hq) // group, 0)
        in_specs += [pl.BlockSpec((1, 1, ps), sc_index),
                     pl.BlockSpec((1, 1, ps), sc_index)]
        operands += [k_scale, v_scale]
        kern = _prefill_kernel_q8
    else:
        kern = _prefill_kernel
    kernel = functools.partial(kern, scale=scale, n_kv=nb, page_size=ps,
                               block_q=bq, hq=hq, softcap=softcap,
                               window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * hq, s_pad // bq, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, d),
                               lambda h, i, j, offs, bt: (h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hq, s_pad, d), q.dtype),
        interpret=interpret,
    )(*operands)
    return out[:, :s].reshape(b, hq, s, d)
