"""Paged flash-decode: single-token attention through a block table.

The paged KV cache (:mod:`repro.serving.kv_cache`) stores tokens in
fixed-size pages drawn from a global pool, with a per-sequence block table
mapping logical kv blocks to physical page ids.  This kernel is
:mod:`repro.kernels.decode_attention` re-read through that indirection:
the grid still walks kv blocks innermost with online-softmax accumulators
in VMEM, but the K/V BlockSpec index maps dereference the block table
(a scalar-prefetch operand, available before the body runs) so each step
DMAs the *physical* page for the logical block — the cache is never
materialized contiguously.

Page pools are laid out (n_pages, Hkv, page_size, hd): one (page_size, hd)
tile per (page, head) grid step, sublane = token-in-page, lane = head dim.
``kv_len`` is per-batch int32 in SMEM exactly as in the dense kernel, so
one compiled kernel serves every mix of slot lengths in a continuous
batch.  The q8 variant mirrors ``decode_attention``'s: int8 pages plus
per-(page, head, token) scale pages, dequantized in VMEM so HBM only ever
moves int8.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _paged_kernel(lens_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *,
                  scale, n_kv, page_size, hq, softcap):
    _paged_body(lens_ref, bt_ref, q_ref, k_ref, v_ref, None, None, o_ref,
                m_ref, l_ref, acc_ref, scale=scale, n_kv=n_kv,
                page_size=page_size, hq=hq, softcap=softcap)


def _paged_kernel_q8(lens_ref, bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                     o_ref, m_ref, l_ref, acc_ref, *,
                     scale, n_kv, page_size, hq, softcap):
    _paged_body(lens_ref, bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                m_ref, l_ref, acc_ref, scale=scale, n_kv=n_kv,
                page_size=page_size, hq=hq, softcap=softcap)


def _paged_body(lens_ref, bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                m_ref, l_ref, acc_ref, *,
                scale, n_kv, page_size, hq, softcap):
    bh = pl.program_id(0)
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = lens_ref[bh // hq]
    k_pos = kj * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)

    @pl.when(kj * page_size < kv_len)         # skip fully-invalid blocks
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (ps, d)
        if ks_ref is not None:
            k = k * ks_ref[0, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(k_pos < kv_len, s, NEG_INF)         # (1, ps)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0]
        if vs_ref is not None:
            v = v.astype(jnp.float32) \
                * vs_ref[0, 0].astype(jnp.float32)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           kv_len: jax.Array, *,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           softcap: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """q (B, Hq, D); k/v_pages (P, Hkv, page_size, D); block_tables
    (B, n_blocks) int32; kv_len (B,) int32 -> (B, Hq, D).

    Logical position t of batch b lives in page
    ``block_tables[b, t // page_size]`` at offset ``t % page_size``;
    positions at or beyond ``kv_len[b]`` are masked (their block-table
    entries may point anywhere valid, e.g. the allocator's trash page).
    With ``k_scale``/``v_scale`` (P, Hkv, page_size): pages are int8 and
    dequantized per block inside VMEM.
    """
    b, hq, d = q.shape
    _, hkv, ps, _ = k_pages.shape
    nb = block_tables.shape[1]
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    q8 = k_scale is not None

    qf = q.reshape(b * hq, 1, d)

    # with num_scalar_prefetch=2 every index_map receives (lens, bt) as
    # trailing arguments — bt is what turns a logical block id into the
    # physical page to DMA
    def kv_index(h, j, lens, bt):
        return (bt[h // hq, j], (h % hq) // group, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, d), lambda h, j, lens, bt: (h, 0, 0)),
        pl.BlockSpec((1, 1, ps, d), kv_index),
        pl.BlockSpec((1, 1, ps, d), kv_index),
    ]
    operands = [kv_len.astype(jnp.int32), block_tables.astype(jnp.int32),
                qf, k_pages, v_pages]
    if q8:
        def sc_index(h, j, lens, bt):
            return (bt[h // hq, j], (h % hq) // group, 0)
        in_specs += [pl.BlockSpec((1, 1, ps), sc_index),
                     pl.BlockSpec((1, 1, ps), sc_index)]
        operands += [k_scale, v_scale]
        kern = _paged_kernel_q8
    else:
        kern = _paged_kernel
    kernel = functools.partial(kern, scale=scale, n_kv=nb, page_size=ps,
                               hq=hq, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * hq, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, d), lambda h, j, lens, bt: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, d), q.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(b, hq, d)
