"""Flash attention (prefill) — online-softmax block tiling for the MXU.

Grid: (batch*q_heads, q_blocks, kv_blocks), kv innermost so the running
max / sum / output accumulators live in VMEM scratch across kv steps.
Supports GQA (kv head = q head // group via the kv BlockSpec index map),
causal masking with block-level skip, sliding windows (gemma2 local
layers) and attention-logit softcapping.

VMEM working set per step: q (bq, d) + k/v (bk, d) + scores (bq, bk) +
acc (bq, d) — with bq=bk=128, d<=256 comfortably under v5e's ~16 MB VMEM.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, n_kv, block_q, block_kv, causal,
                  window: Optional[int], softcap: Optional[float]):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = kj * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)

    def body():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip fully-masked kv blocks above the diagonal
        @pl.when(kj * block_kv <= qi * block_q + block_q - 1)
        def _():
            body()
    else:
        body()

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q (B, Hq, Sq, D); k/v (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    bq, bk = min(block_q, sq), min(block_kv, skv)
    assert sq % bq == 0 and skv % bk == 0
    scale = 1.0 / math.sqrt(d)

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    def kv_index(h, i, j):
        return ((h // hq) * hkv + (h % hq) // group, j, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, n_kv=skv // bk, block_q=bq,
        block_kv=bk, causal=causal, window=window, softcap=softcap)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, sq // bq, skv // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
