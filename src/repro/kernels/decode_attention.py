"""Flash-decode: single-token attention against a long KV cache.

Decode is the shape HeteGen serves (batch small, cache long): one query row
per (batch, head) attends over ``kv_len`` valid cache positions.  The grid
walks kv blocks innermost with online-softmax accumulators in VMEM — the
cache is read exactly once at HBM rate, which is the roofline for decode.

``kv_len`` is a per-batch int32 vector in SMEM (scalar-prefetch operand):
positions beyond it are masked, so one compiled kernel serves any prefix
length — cheaper than recompiling per step and required for continuous
batching where every slot has its own length.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale, n_kv, block_kv, hq, softcap):
    _decode_body(lens_ref, q_ref, k_ref, v_ref, None, None, o_ref,
                 m_ref, l_ref, acc_ref, scale=scale, n_kv=n_kv,
                 block_kv=block_kv, hq=hq, softcap=softcap)


def _decode_kernel_q8(lens_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                      m_ref, l_ref, acc_ref, *,
                      scale, n_kv, block_kv, hq, softcap):
    """int8 cache variant: K/V blocks are dequantized in VMEM (per-token
    scales), so HBM only ever moves int8 — the fusion XLA:CPU cannot do
    (EXPERIMENTS.md §Perf, mistral decode int8-KV iteration)."""
    _decode_body(lens_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                 m_ref, l_ref, acc_ref, scale=scale, n_kv=n_kv,
                 block_kv=block_kv, hq=hq, softcap=softcap)


def _decode_body(lens_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                 m_ref, l_ref, acc_ref, *,
                 scale, n_kv, block_kv, hq, softcap):
    bh = pl.program_id(0)
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = lens_ref[bh // hq]
    k_pos = kj * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_kv), 1)

    @pl.when(kj * block_kv < kv_len)          # skip fully-invalid blocks
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (1, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        if ks_ref is not None:
            k = k * ks_ref[0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(k_pos < kv_len, s, NEG_INF)         # (1, bk)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0]
        if vs_ref is not None:
            v = v.astype(jnp.float32) * vs_ref[0].astype(jnp.float32)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *,
                     k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None,
                     softcap: Optional[float] = None,
                     block_kv: int = 256,
                     interpret: bool = False) -> jax.Array:
    """q (B, Hq, D); k/v (B, Hkv, S, D); kv_len (B,) int32 -> (B, Hq, D).

    With ``k_scale``/``v_scale`` (B, Hkv, S): k/v are int8 and dequantized
    per kv block inside VMEM.
    """
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    bk = min(block_kv, s)
    assert s % bk == 0
    scale = 1.0 / math.sqrt(d)
    q8 = k_scale is not None

    qf = q.reshape(b * hq, 1, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)

    # with num_scalar_prefetch=1 every index_map receives the scalar ref
    # as a trailing argument
    def kv_index(h, j, lens):
        return ((h // hq) * hkv + (h % hq) // group, j, 0)

    in_specs = [
        pl.BlockSpec((1, 1, d), lambda h, j, lens: (h, 0, 0)),
        pl.BlockSpec((1, bk, d), kv_index),
        pl.BlockSpec((1, bk, d), kv_index),
    ]
    operands = [kv_len.astype(jnp.int32), qf, kf, vf]
    if q8:
        def sc_index(h, j, lens):
            return ((h // hq) * hkv + (h % hq) // group, j)
        in_specs += [pl.BlockSpec((1, bk), sc_index),
                     pl.BlockSpec((1, bk), sc_index)]
        operands += [k_scale.reshape(b * hkv, s),
                     v_scale.reshape(b * hkv, s)]
        kern = _decode_kernel_q8
    else:
        kern = _decode_kernel
    kernel = functools.partial(kern, scale=scale, n_kv=s // bk,
                               block_kv=bk, hq=hq, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hq, s // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, d), lambda h, j, lens: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, d), q.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(b, hq, d)
