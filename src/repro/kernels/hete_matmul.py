"""Blocked matmul kernel with fused bias/activation — the HeteGen hot spot.

The device-side fraction of a heterogeneous linear is a streamed-weight
matmul: weights arrive in 128-aligned column tiles (core/alpha.py quantizes
alpha to tile boundaries for exactly this reason) and should be consumed at
MXU rate with no re-layout.  The kernel tiles (M, N, K) into VMEM blocks,
accumulates in fp32 scratch, and applies bias + activation on the final K
step — fusing what would otherwise be three HBM round-trips (matmul, bias,
activation).

``gated_matmul`` fuses the gated-MLP pattern act(x@Wg) * (x@Wu) in one pass
over x (one read of the activations instead of two).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _apply_act(y, activation: Optional[str]):
    if activation is None or activation == "none":
        return y
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "relu2":
        r = jnp.maximum(y, 0.0)
        return r * r
    if activation == "gelu":
        return jax.nn.gelu(y)
    if activation == "silu":
        return y * jax.nn.sigmoid(y)
    raise ValueError(f"unknown activation {activation!r}")


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *,
                   activation, n_k, has_bias):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        y = acc_ref[...]
        if has_bias:
            y = y + b_ref[...].astype(jnp.float32)
        y = _apply_act(y, activation)
        o_ref[...] = y.astype(o_ref.dtype)


def matmul(x: jax.Array, w: jax.Array, bias: Optional[jax.Array] = None, *,
           activation: Optional[str] = None,
           block_m: int = 128, block_n: int = 128, block_k: int = 128,
           interpret: bool = False) -> jax.Array:
    """y = act(x @ w + bias); x (M, K), w (K, N) -> (M, N)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"shape {(m, k, n)} not divisible by blocks {(bm, bk, bn)}"
    n_k = k // bk
    has_bias = bias is not None
    if not has_bias:
        bias = jnp.zeros((n,), x.dtype)

    kernel = functools.partial(_matmul_kernel, activation=activation,
                               n_k=n_k, has_bias=has_bias)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bn,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, bias)


def _gated_kernel(x_ref, wg_ref, wu_ref, o_ref, accg_ref, accu_ref, *,
                  activation, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    accg_ref[...] += jnp.dot(x_ref[...], wg_ref[...],
                             preferred_element_type=jnp.float32)
    accu_ref[...] += jnp.dot(x_ref[...], wu_ref[...],
                             preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        g = _apply_act(accg_ref[...], activation)
        o_ref[...] = (g * accu_ref[...]).astype(o_ref.dtype)


def gated_matmul(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, *,
                 activation: str = "silu",
                 block_m: int = 128, block_n: int = 128, block_k: int = 128,
                 interpret: bool = False) -> jax.Array:
    """act(x @ w_gate) * (x @ w_up) — the gated-MLP first stage, fused."""
    m, k = x.shape
    _, n = w_gate.shape
    assert w_gate.shape == w_up.shape == (k, n)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    n_k = k // bk
    kernel = functools.partial(_gated_kernel, activation=activation, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up)
