"""Dispatch layer: jit'd public kernel API.

On TPU the Pallas kernels are compiled natively; on CPU (this container)
the pure-jnp references are the compiled path and the kernels run under
``interpret=True`` only in tests.  ``force`` overrides for benchmarking:

    repro_kernels.set_mode("pallas")      # TPU production
    repro_kernels.set_mode("ref")         # CPU/XLA fallback
    repro_kernels.set_mode("interpret")   # kernel body on CPU (tests)
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import hete_matmul as _mm
from repro.kernels import paged_attention as _paged
from repro.kernels import paged_prefill as _paged_pf
from repro.kernels import q8_matmul as _q8
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_chunk as _ssd

_MODE: Optional[str] = None


def set_mode(mode: Optional[str]) -> None:
    global _MODE
    assert mode in (None, "pallas", "ref", "interpret")
    _MODE = mode


def _mode() -> str:
    if _MODE is not None:
        return _MODE
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("activation",))
def _matmul_ref(x, w, bias=None, activation=None):
    return _ref.matmul(x, w, bias, activation=activation)


def matmul(x, w, bias=None, *, activation=None, **kw):
    m = _mode()
    if m == "ref":
        return _matmul_ref(x, w, bias, activation)
    return _mm.matmul(x, w, bias, activation=activation,
                      interpret=(m == "interpret"), **kw)


def gated_matmul(x, w_gate, w_up, *, activation="silu", **kw):
    m = _mode()
    if m == "ref":
        return _ref.gated_matmul(x, w_gate, w_up, activation=activation)
    return _mm.gated_matmul(x, w_gate, w_up, activation=activation,
                            interpret=(m == "interpret"), **kw)


def q8_matmul(x, q, scale, **kw):
    m = _mode()
    if m == "ref":
        return _ref.q8_matmul(x, q, scale)
    return _q8.q8_matmul(x, q, scale, interpret=(m == "interpret"), **kw)


quantize_weights = _q8.quantize_weights


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None, **kw):
    m = _mode()
    if m == "ref":
        return _ref.flash_attention(q, k, v, causal=causal, window=window,
                                    softcap=softcap)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap,
                               interpret=(m == "interpret"), **kw)


def decode_attention(q, k, v, kv_len, *, softcap=None, **kw):
    m = _mode()
    if m == "ref":
        return _ref.decode_attention(q, k, v, kv_len, softcap=softcap)
    return _dec.decode_attention(q, k, v, kv_len, softcap=softcap,
                                 interpret=(m == "interpret"), **kw)


def paged_decode_attention(q, k_pages, v_pages, block_tables, kv_len, *,
                           k_scale=None, v_scale=None, softcap=None, **kw):
    m = _mode()
    if m == "ref":
        return _ref.paged_decode_attention(
            q, k_pages, v_pages, block_tables, kv_len,
            k_scale=k_scale, v_scale=v_scale, softcap=softcap)
    return _paged.paged_decode_attention(
        q, k_pages, v_pages, block_tables, kv_len,
        k_scale=k_scale, v_scale=v_scale, softcap=softcap,
        interpret=(m == "interpret"), **kw)


def paged_prefill_attention(q, k_pages, v_pages, block_tables, kv_offset, *,
                            k_scale=None, v_scale=None, softcap=None,
                            window=None, **kw):
    m = _mode()
    if m == "ref":
        return _ref.paged_prefill_attention(
            q, k_pages, v_pages, block_tables, kv_offset,
            k_scale=k_scale, v_scale=v_scale, softcap=softcap, window=window)
    return _paged_pf.paged_prefill_attention(
        q, k_pages, v_pages, block_tables, kv_offset,
        k_scale=k_scale, v_scale=v_scale, softcap=softcap, window=window,
        interpret=(m == "interpret"), **kw)


def rmsnorm(x, scale, *, eps=1e-6, plus_one=False, **kw):
    m = _mode()
    if m == "ref":
        return _ref.rmsnorm(x, scale, eps=eps, plus_one=plus_one)
    return _rn.rmsnorm(x, scale, eps=eps, plus_one=plus_one,
                       interpret=(m == "interpret"), **kw)


def ssd_chunk(x, dt, a, b, c, *, chunk, **kw):
    m = _mode()
    if m == "ref":
        return _ref.ssd_chunk(x, dt, a, b, c, chunk=chunk)
    return _ssd.ssd_chunk(x, dt, a, b, c, chunk=chunk,
                          interpret=(m == "interpret"), **kw)
