"""Int8-weight matmul with per-column scales — quantized weight streaming.

HeteGen is link-bound, so streaming weights as int8 + fp32 per-column
scales cuts the PCIe/DMA bytes (2-byte bf16 -> 1-byte int8 + 4/N scale;
4-byte fp32 -> ~1/4), shifting the alpha equilibrium toward the device:
alpha* ~= T'cpu / (T'cpu + r * T'com) with r the wire ratio
(docs/ANALYSIS.md).  This is the live serving path, not an experiment:
:class:`repro.core.engine.HeteGenEngine` built with ``wstream="q8"``
quantizes each offloaded column shard once at load
(:func:`quantize_weights`), stages the ``(q, scale)`` pair through
:class:`repro.core.param_manager.AsyncParamManager`'s pinned rings (sized
to the *compressed* bytes), DMAs the pair, and computes the device share
with this kernel — the dequant happens inside the matmul, so no fp copy
of a streamed weight ever exists in HBM.  The policy layer prices the
compressed link through :attr:`repro.core.policy.LinearSpec.wire_bytes`.

Accumulates x_block @ q_block in fp32 and applies the per-column scale on
the final K step.  (Per-column — not per-tile — scales keep the epilogue a
single multiply.)
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def quantize_weights(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-output-column symmetric int8 quantization."""
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantize_weights_np(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side mirror of :func:`quantize_weights` (same wire format).

    The offload engine quantizes shards at load time on the host; this
    numpy twin avoids a device round-trip there.  Bit-identical to the
    jax version (tests/test_wstream.py pins them equal).
    """
    w32 = np.asarray(w, dtype=np.float32)
    scale = np.max(np.abs(w32), axis=0) / np.float32(127.0) \
        + np.float32(1e-12)
    q = np.clip(np.round(w32 / scale), -127, 127).astype(np.int8)
    return q, scale.astype(np.float32)


def _q8_kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # dequantize the weight tile in VMEM; MXU consumes fp32/bf16
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            q_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] * s_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def q8_matmul(x: jax.Array, q: jax.Array, scale: jax.Array, *,
              block_m: int = 128, block_n: int = 128, block_k: int = 128,
              interpret: bool = False) -> jax.Array:
    """x (M, K) fp  @  dequant(q (K, N) int8, scale (N,)) -> (M, N) fp."""
    m, k = x.shape
    k2, n = q.shape
    assert k == k2 and scale.shape == (n,)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    n_k = k // bk
    kernel = functools.partial(_q8_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bn,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, q, scale)
