"""Fused RMSNorm kernel.

One HBM read + one write per row (norm statistics computed in VMEM),
vs. unfused's extra round-trips for the square/mean/rsqrt chain.  Supports
the gemma-style ``(1 + w)`` scale variant used by post-norm configs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps, plus_one):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    if plus_one:
        w = w + 1.0
    o_ref[...] = (y * w).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            plus_one: bool = False, block_rows: int = 256,
            interpret: bool = False) -> jax.Array:
    """x (..., D) -> rmsnorm(x) * scale; rows tiled into VMEM blocks."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    kernel = functools.partial(_rmsnorm_kernel, eps=eps, plus_one=plus_one)
    out = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(shape)
