"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors one kernel's contract exactly; kernel tests sweep
shapes/dtypes and ``assert_allclose`` kernel(interpret=True) against these.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _act(y, activation: Optional[str]):
    if activation in (None, "none"):
        return y
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "relu2":
        r = jnp.maximum(y, 0.0)
        return r * r
    if activation == "gelu":
        return jax.nn.gelu(y)
    if activation == "silu":
        return y * jax.nn.sigmoid(y)
    raise ValueError(activation)


def matmul(x, w, bias=None, *, activation=None):
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return _act(y, activation).astype(x.dtype)


def gated_matmul(x, w_gate, w_up, *, activation="silu"):
    g = jnp.dot(x.astype(jnp.float32), w_gate.astype(jnp.float32))
    u = jnp.dot(x.astype(jnp.float32), w_up.astype(jnp.float32))
    return (_act(g, activation) * u).astype(x.dtype)


def q8_matmul(x, q, scale):
    y = jnp.dot(x.astype(jnp.float32), q.astype(jnp.float32))
    return (y * scale.astype(jnp.float32)[None, :]).astype(x.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None):
    """q (B,Hq,Sq,D); k/v (B,Hkv,Skv,D)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qf, k.astype(jnp.float32))
    s = s / math.sqrt(d)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def decode_attention(q, k, v, kv_len, *, softcap=None):
    """q (B,Hq,D); k/v (B,Hkv,S,D); kv_len (B,)."""
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, d).astype(jnp.float32)
    sc = jnp.einsum("bkgd,bktd->bkgt", qf, k.astype(jnp.float32))
    sc = sc / math.sqrt(d)
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)
    mask = jnp.arange(s)[None, :] < kv_len[:, None]
    sc = jnp.where(mask[:, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, d).astype(q.dtype)


def gather_pages(pages, block_tables):
    """(P, H, ps, D) pages + (B, nb) tables -> contiguous (B, H, nb*ps, D).

    The materialized-copy read of a paged cache (what the Pallas kernel's
    block-table index maps avoid); also the shared gather for prefill
    attention over paged caches.
    """
    g = pages[block_tables]                    # (B, nb, H, ps, D)
    b, nb, h, ps, d = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(b, h, nb * ps, d)


def gather_page_scales(scales, block_tables):
    """(P, H, ps) scale pages + (B, nb) tables -> (B, H, nb*ps)."""
    g = scales[block_tables]                   # (B, nb, H, ps)
    b, nb, h, ps = g.shape
    return g.transpose(0, 2, 1, 3).reshape(b, h, nb * ps)


def paged_decode_attention(q, k_pages, v_pages, block_tables, kv_len, *,
                           k_scale=None, v_scale=None, softcap=None):
    """q (B,Hq,D); k/v_pages (P,Hkv,ps,D); block_tables (B,nb); kv_len (B,).

    Gathers physical pages into a contiguous cache, then defers to the
    dense :func:`decode_attention` oracle — positions >= kv_len are
    masked, so trash-page contents never reach the softmax.
    """
    k = gather_pages(k_pages, block_tables)
    v = gather_pages(v_pages, block_tables)
    if k_scale is not None:
        k = k.astype(jnp.float32) \
            * gather_page_scales(k_scale, block_tables)[..., None]
        v = v.astype(jnp.float32) \
            * gather_page_scales(v_scale, block_tables)[..., None]
    out = decode_attention(q, k, v, kv_len, softcap=softcap)
    return out.astype(q.dtype)


def paged_prefill_attention(q, k_pages, v_pages, block_tables, kv_offset, *,
                            k_scale=None, v_scale=None, softcap=None,
                            window=None):
    """q (B,Hq,S,D); k/v_pages (P,Hkv,ps,D); block_tables (B,nb);
    kv_offset (B,).

    Chunk prefill over a paged cache: query row r of batch b sits at
    absolute position ``kv_offset[b] + r`` and attends causally over
    logical kv positions [0, kv_offset[b] + r].  Gathers physical pages
    into a contiguous cache and applies the masked softmax directly —
    positions above the causal diagonal (which includes everything past
    ``kv_offset + S``) never reach the softmax, so trash-page contents
    are irrelevant.
    """
    b, hq, s, d = q.shape
    k = gather_pages(k_pages, block_tables)
    v = gather_pages(v_pages, block_tables)
    if k_scale is not None:
        k = k.astype(jnp.float32) \
            * gather_page_scales(k_scale, block_tables)[..., None]
        v = v.astype(jnp.float32) \
            * gather_page_scales(v_scale, block_tables)[..., None]
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, s, d).astype(jnp.float32)
    sc = jnp.einsum("bkgsd,bktd->bkgst", qf, k.astype(jnp.float32))
    sc = sc / math.sqrt(d)
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)
    qpos = kv_offset[:, None] + jnp.arange(s)[None, :]     # (B, s)
    kpos = jnp.arange(t)
    ok = kpos[None, None, :] <= qpos[:, :, None]           # (B, s, t)
    if window is not None:
        ok &= kpos[None, None, :] > qpos[:, :, None] - window
    sc = jnp.where(ok[:, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, s, d).astype(q.dtype)


def rmsnorm(x, scale, *, eps=1e-6, plus_one=False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    w = scale.astype(jnp.float32)
    if plus_one:
        w = w + 1.0
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def ssd_chunk(x, dt, a, b, c, *, chunk: int
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the intra-chunk kernel (matches kernels/ssd_chunk.py)."""
    bs, ln, h, p = x.shape
    n = b.shape[-1]
    nc = ln // chunk
    xc = x.reshape(bs, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(bs, nc, chunk, h).astype(jnp.float32)
    bc = b.reshape(bs, nc, chunk, h, n).astype(jnp.float32)
    cc = c.reshape(bs, nc, chunk, h, n).astype(jnp.float32)
    la = dtc * a
    cum = jnp.cumsum(la, axis=2)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    xdt = xc * dtc[..., None]
    cb = jnp.einsum("bnkhs,bnlhs->bnklh", cc, bc)
    y = jnp.einsum("bnklh,bnklh,bnlhp->bnkhp", cb, decay, xdt)
    tail = jnp.exp(cum[:, :, -1:, :] - cum)
    sc = jnp.einsum("bnkh,bnkhs,bnkhp->bnhps", tail, bc, xdt)
    return (y.reshape(bs, ln, h, p).astype(x.dtype), sc,
            cum.reshape(bs, ln, h))
