"""Mamba2 SSD intra-chunk kernel (the compute-heavy third of SSD).

The chunked SSD algorithm (models/ssm.py:ssd_chunked) splits into:

  1. intra-chunk "attention-like" compute:  Y_intra = (C B^T ∘ decay) (dt X)
  2. per-chunk state contribution:          S_c = (decay_tail ∘ dt B)^T X
  3. the sequential inter-chunk carry (tiny; stays a lax.scan outside)

(1) and (2) are matmul-shaped over (K x K) and (K x N x P) tiles — this
kernel fuses them per (batch, head, chunk) grid cell, keeping the chunk's
x / B / C tiles and the decay matrix in VMEM.  The Triton reference splits
the same way (chunk_scan / chunk_state); on TPU one fused kernel per cell
keeps the MXU fed without materializing the (K, K) decay tensor in HBM.

Outputs: y_intra (B,L,H,P), state contribution (B,nc,H,P,N), and the
inclusive log-decay cumsum (B,L,H) the outer carry needs.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, cum_ref,
                *, chunk):
    # refs per (batch, head, chunk) cell:
    #   x (K, P), dt (K, 1), a (1, 1), b (K, N), c (K, N)
    x = x_ref[0].astype(jnp.float32)
    dt = dt_ref[0].astype(jnp.float32)            # (K, 1)
    a = a_ref[0].astype(jnp.float32)              # (1, 1)
    bm = b_ref[0].astype(jnp.float32)
    cm = c_ref[0].astype(jnp.float32)

    la = dt * a                                   # (K, 1) log decay
    cum = jnp.cumsum(la, axis=0)                  # inclusive
    seg = cum - cum.T                             # (K, K) cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    xdt = x * dt                                  # dt_j * x_j  (K, P)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (K, K)
    y = jax.lax.dot_general(cb * decay, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (K, P)
    y_ref[0] = y.astype(y_ref.dtype)

    tail = jnp.exp(cum[-1:] - cum)                # (K, 1)
    sc = jax.lax.dot_general(xdt, bm * tail, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    s_ref[0, 0] = sc.astype(s_ref.dtype)
    cum_ref[0] = cum.astype(cum_ref.dtype)


def ssd_chunk(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
              c: jax.Array, *, chunk: int,
              interpret: bool = False
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Intra-chunk SSD.

    x (B,L,H,P); dt (B,L,H) post-softplus; a (H) negative; b/c (B,L,H,N)
    (groups already broadcast).  L % chunk == 0.
    Returns (y_intra (B,L,H,P), state_c (B,nc,H,P,N), cum (B,L,H)).
    """
    bs, ln, h, p = x.shape
    n = b.shape[-1]
    assert ln % chunk == 0
    nc = ln // chunk

    # layout: (B*H, nc, K, ...) so each grid cell reads contiguous tiles
    xg = x.transpose(0, 2, 1, 3).reshape(bs * h, nc, chunk, p)
    dtg = dt.transpose(0, 2, 1).reshape(bs * h, nc, chunk, 1)
    bg = b.transpose(0, 2, 1, 3).reshape(bs * h, nc, chunk, n)
    cg = c.transpose(0, 2, 1, 3).reshape(bs * h, nc, chunk, n)
    ag = jnp.tile(a.reshape(1, h), (bs, 1)).reshape(bs * h, 1, 1)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, sc, cum = pl.pallas_call(
        kernel,
        grid=(bs * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, 1, 1), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, i: (g, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, 1, p, n), lambda g, i: (g, i, 0, 0)),
            pl.BlockSpec((1, chunk, 1), lambda g, i: (g, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs * h, nc * chunk, p), x.dtype),
            jax.ShapeDtypeStruct((bs * h, nc, p, n), jnp.float32),
            jax.ShapeDtypeStruct((bs * h, nc * chunk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xg.reshape(bs * h, nc * chunk, p), dtg.reshape(bs * h, nc * chunk, 1),
      ag, bg.reshape(bs * h, nc * chunk, n), cg.reshape(bs * h, nc * chunk, n))

    y = y.reshape(bs, h, ln, p).transpose(0, 2, 1, 3)
    sc = sc.reshape(bs, h, nc, p, n).transpose(0, 2, 1, 3, 4)
    cum = cum.reshape(bs, h, ln).transpose(0, 2, 1)
    return y, sc, cum
