"""Optimizers: AdamW and Adafactor, with sharding-aware state layout.

Implemented directly (no optax in the container).  Both return
``(init_fn, update_fn)``:

    state = init_fn(params)
    new_params, new_state = update_fn(grads, state, params, lr)

State dtypes are configurable — the big-arch configs keep moments in
bfloat16 (halves optimizer HBM, the standard large-scale trade) while small
models default to fp32.  Adafactor stores factored second moments (row+col
statistics) for >=2-D parameters: O(n+m) instead of O(nm) state — the
default for the 100B+ assigned architectures.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"             # adamw | adafactor | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    moment_dtype: str = "float32"   # bfloat16 halves optimizer memory
    # adafactor
    decay_rate: float = 0.8
    min_dim_factored: int = 128


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def make_adamw(cfg: OptimizerConfig):
    mdt = jnp.dtype(cfg.moment_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        if cfg.grad_clip:
            grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        count = state["count"] + 1
        b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
            v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
            mhat = m2 / b1c
            vhat = v2 / b2c
            step = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if cfg.weight_decay and p.ndim >= 2:     # no decay on norms/bias
                step = step + cfg.weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr * step
            return p2.astype(p.dtype), m2.astype(mdt), v2.astype(mdt)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "count": count}

    return init, update


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, first moment omitted)
# ---------------------------------------------------------------------------

def _factored(shape, min_dim: int) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def make_adafactor(cfg: OptimizerConfig):
    mdt = jnp.dtype(cfg.moment_dtype)

    def init(params):
        def st(p):
            if _factored(p.shape, cfg.min_dim_factored):
                return {"vr": jnp.zeros(p.shape[:-1], mdt),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], mdt)}
            return {"v": jnp.zeros(p.shape, mdt)}
        return {"s": jax.tree.map(st, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        if cfg.grad_clip:
            grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** (-cfg.decay_rate)

        def upd(p, g, s):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + 1e-30
            if "vr" in s:
                vr = beta * s["vr"].astype(jnp.float32) \
                    + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"].astype(jnp.float32) \
                    + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + 1e-30)
                cfac = jax.lax.rsqrt(vc + 1e-30)
                step = g32 * rfac[..., None] * cfac[..., None, :]
                s2 = {"vr": vr.astype(mdt), "vc": vc.astype(mdt)}
            else:
                v = beta * s["v"].astype(jnp.float32) + (1 - beta) * g2
                step = g32 * jax.lax.rsqrt(v + 1e-30)
                s2 = {"v": v.astype(mdt)}
            # relative step size (Adafactor update clipping)
            rms = jnp.sqrt(jnp.mean(step * step) + 1e-30)
            step = step / jnp.maximum(1.0, rms)
            if cfg.weight_decay and p.ndim >= 2:
                step = step + cfg.weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr * step
            return p2.astype(p.dtype), s2

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["s"])
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_s = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return new_params, {"s": new_s, "count": count}

    return init, update


def make_sgd(cfg: OptimizerConfig):
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        if cfg.grad_clip:
            grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, {"count": state["count"] + 1}

    return init, update


def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return make_adamw(cfg)
    if cfg.name == "adafactor":
        return make_adafactor(cfg)
    if cfg.name == "sgd":
        return make_sgd(cfg)
    raise ValueError(f"unknown optimizer {cfg.name!r}")


def lr_schedule(step, *, base: float, warmup: int = 100,
                total: int = 10_000, kind: str = "cosine") -> jax.Array:
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (s + 1) / max(warmup, 1))
    if kind == "cosine":
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return base * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return base * warm
