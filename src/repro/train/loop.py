"""Training loop: loss, grad-accum microbatched train_step, Trainer driver.

``make_train_step`` builds the jitted step the dry-run lowers for the
``train_4k`` shapes: cross-entropy (+ MoE load-balance aux), gradient
accumulation over ``accum_steps`` microbatches via ``lax.scan`` (activation
memory scales with the microbatch, the standard large-scale recipe),
global-norm clipping and the configured optimizer.

The ``Trainer`` adds checkpoint/restart, preemption handling, straggler
monitoring and metrics — the fault-tolerance posture for long runs
(tests/test_fault_tolerance.py exercises kill/restore/resume-identical).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.shardings import NO_RULES, ShardingRules
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train.optimizer import (OptimizerConfig, lr_schedule,
                                   make_optimizer)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    accum_steps: int = 1
    accum_dtype: str = "float32"       # bf16 halves the grad buffer (the
                                       # standard >=100B recipe; few-step
                                       # accumulation keeps the error small)
    aux_loss_weight: float = 0.01      # MoE load-balance coefficient
    z_loss_weight: float = 0.0         # logit norm regularizer (optional)
    optimizer: OptimizerConfig = OptimizerConfig()
    warmup: int = 100
    total_steps: int = 10_000


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict,
            rules: ShardingRules = NO_RULES,
            aux_weight: float = 0.01,
            z_weight: float = 0.0) -> Tuple[jax.Array, Dict]:
    """Causal LM cross entropy over the batch (labels = next-token ids)."""
    logits, aux = M.forward_train(cfg, params, batch, rules, return_aux=True)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    loss = jnp.mean(nll)
    metrics = {"nll": loss, "aux": aux}
    if aux_weight and cfg.n_experts:
        loss = loss + aux_weight * aux
    if z_weight:
        loss = loss + z_weight * jnp.mean(jnp.square(logz))
    return loss, metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    rules: ShardingRules = NO_RULES):
    """(state, batch) -> (state, metrics) with grad accumulation.

    ``state`` = {"params", "opt", "step"}.  ``batch`` leaves have leading
    dim ``global_batch``; they are split into ``accum_steps`` microbatches
    scanned sequentially, gradients averaged, one optimizer update applied.
    """
    opt_init, opt_update = make_optimizer(tcfg.optimizer)

    def grads_of(params, mb):
        (l, m), g = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, mb, rules, tcfg.aux_loss_weight,
                              tcfg.z_loss_weight), has_aux=True)(params)
        return g, l, m

    def train_step(state, batch):
        params = state["params"]
        a = tcfg.accum_steps

        if a <= 1:
            grads, loss, metrics = grads_of(params, batch)
        else:
            def resh(x):
                y = x.reshape((a, x.shape[0] // a) + x.shape[1:])
                # re-pin the batch sharding: the reshape (B,) -> (a, B/a)
                # otherwise leaves the microbatch dim unsharded and every
                # chip computes the full microbatch with gathered weights
                # (16x flops / 78 TB/step observed — §Perf hillclimb #2)
                return rules.act(y, None, "batch",
                                 *([None] * (y.ndim - 2)))
            micro = jax.tree.map(resh, batch)

            adt = jnp.dtype(tcfg.accum_dtype)

            def acc(carry, mb):
                g_acc, l_acc = carry
                g, l, _ = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda x, y: (x + y.astype(adt)).astype(adt), g_acc, g)
                return (g_acc, l_acc + l), ()

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (g_sum, l_sum), _ = jax.lax.scan(acc, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: (g / a).astype(jnp.float32), g_sum)
            loss = l_sum / a
            metrics = {"nll": loss, "aux": jnp.zeros((), jnp.float32)}

        lr = lr_schedule(state["step"], base=tcfg.optimizer.lr,
                         warmup=tcfg.warmup, total=tcfg.total_steps)
        new_params, new_opt = opt_update(grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        out_metrics = {"loss": loss, "lr": lr, **metrics}
        return new_state, out_metrics

    return train_step, opt_init


def init_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> Dict:
    params = M.init_params(cfg, key)
    _, opt_init = make_train_step(cfg, tcfg)
    return {"params": params, "opt": opt_init(params),
            "step": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# Trainer driver with fault tolerance
# ---------------------------------------------------------------------------

class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, *,
                 rules: ShardingRules = NO_RULES,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 50,
                 keep: int = 3,
                 async_checkpoint: bool = True,
                 seed: int = 0):
        from repro.checkpoint.manager import CheckpointManager
        from repro.distributed.fault_tolerance import (PreemptionHandler,
                                                       StragglerDetector,
                                                       retry)

        self.cfg, self.tcfg = cfg, tcfg
        step_fn, opt_init = make_train_step(cfg, tcfg, rules)
        self._step = jax.jit(step_fn, donate_argnums=(0,))
        self.state = init_state(cfg, tcfg, jax.random.PRNGKey(seed))
        self.ckpt = (CheckpointManager(checkpoint_dir, keep=keep,
                                       async_save=async_checkpoint)
                     if checkpoint_dir else None)
        self.checkpoint_every = checkpoint_every
        self.preemption = PreemptionHandler()
        self.straggler = StragglerDetector()
        self._retry = retry
        self.metrics_log: list = []
        if self.ckpt is not None:
            restored = self.ckpt.restore_latest(self.state)
            if restored is not None:
                self.state = restored

    @property
    def step(self) -> int:
        return int(self.state["step"])

    def run(self, batches, steps: int) -> Dict:
        it = iter(batches)
        last = {}
        for _ in range(steps):
            batch = next(it)
            t0 = time.perf_counter()
            self.state, metrics = self._retry(
                lambda: self._step(self.state, batch))
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.straggler.update("host0", dt)
            metrics["step_time_s"] = dt
            metrics["step"] = self.step
            self.metrics_log.append(metrics)
            last = metrics
            if self.ckpt is not None and \
                    (self.step % self.checkpoint_every == 0
                     or self.preemption.triggered):
                self.ckpt.save(self.step, self.state)
                if self.preemption.triggered:
                    break
        if self.ckpt is not None:
            self.ckpt.wait()
        return last
