"""Sharded, asynchronous, elastic checkpointing.

Layout of one checkpoint::

    <dir>/step_000123/
        manifest.json      tree structure, global shapes/dtypes, shard index
        leaf_<i>_shard_<j>.npy

* **Sharded**: each host writes only its addressable shards (on this
  single-host container that is the whole array, but the index-map code
  path is the multi-host one: every shard records its global index ranges).
* **Asynchronous**: ``save`` snapshots device arrays to host memory and
  returns; a writer thread persists in the background, so the train loop
  never blocks on storage.
* **Atomic**: written to ``step_N.tmp`` then renamed; a crash never leaves
  a half checkpoint that ``restore_latest`` would pick up.
* **Elastic**: ``restore`` rebuilds arrays through
  ``jax.make_array_from_callback`` against the *current* sharding — a
  checkpoint written on one topology restores onto any other (shards are
  assembled from overlapping saved index ranges).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _tree_paths(tree) -> List[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(kp) for kp, _ in flat]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state) -> None:
        """Snapshot to host, then write in the background (if async)."""
        self.wait()
        flat, treedef = jax.tree_util.tree_flatten_with_path(state)
        snap = []
        for kp, leaf in flat:
            arr = leaf
            shards = []
            if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
                for sh in arr.addressable_shards:
                    idx = _index_to_json(sh.index, arr.shape)
                    shards.append((idx, np.asarray(sh.data)))
            else:
                shards.append((_index_to_json((), np.shape(arr)),
                               np.asarray(arr)))
            snap.append((jax.tree_util.keystr(kp), arr.dtype if
                         hasattr(arr, "dtype") else np.asarray(arr).dtype,
                         np.shape(arr), shards))

        def write():
            try:
                self._write(step, snap)
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            self._raise_if_failed()

    def _write(self, step: int, snap) -> None:
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest: Dict[str, Any] = {"step": step, "leaves": []}
        for i, (path, dtype, shape, shards) in enumerate(snap):
            entry = {"path": path, "dtype": str(np.dtype(dtype)),
                     "shape": list(shape), "shards": []}
            for j, (idx, data) in enumerate(shards):
                fname = f"leaf_{i:05d}_shard_{j:03d}.npy"
                np.save(os.path.join(tmp, fname), data)
                entry["shards"].append({"file": fname, "index": idx})
            manifest["leaves"].append(entry)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self) -> None:
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {e!r}") from e

    # ------------------------------------------------------------------
    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, step: int, template) -> Any:
        """Restore onto the *current* shardings of ``template`` (elastic)."""
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for kp, leaf in flat:
            path = jax.tree_util.keystr(kp)
            e = by_path[path]
            shape = tuple(e["shape"])
            dtype = np.dtype(e["dtype"])
            shards = [(_index_from_json(s["index"], shape),
                       os.path.join(d, s["file"])) for s in e["shards"]]

            def make(idx, _shards=shards, _shape=shape, _dtype=dtype):
                # assemble the requested global slice from saved shards
                want = _normalize(idx, _shape)
                block = np.zeros([sl.stop - sl.start for sl in want], _dtype)
                for sidx, fname in _shards:
                    have = _normalize(sidx, _shape)
                    inter = [slice(max(a.start, b.start), min(a.stop, b.stop))
                             for a, b in zip(want, have)]
                    if any(s.start >= s.stop for s in inter):
                        continue
                    data = np.load(fname, mmap_mode="r")
                    src = tuple(slice(i.start - h.start, i.stop - h.start)
                                for i, h in zip(inter, have))
                    dst = tuple(slice(i.start - w.start, i.stop - w.start)
                                for i, w in zip(inter, want))
                    block[dst] = data[src]
                return block

            if isinstance(leaf, jax.Array) and leaf.shape == shape:
                arr = jax.make_array_from_callback(shape, leaf.sharding, make)
            else:
                arr = jnp.asarray(make(tuple(slice(0, s) for s in shape)))
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, template) -> Optional[Any]:
        steps = self.list_steps()
        if not steps:
            return None
        return self.restore(steps[-1], template)


# ---------------------------------------------------------------------------

def _index_to_json(index, shape) -> list:
    idx = _normalize(index, shape)
    return [[s.start, s.stop] for s in idx]


def _index_from_json(j, shape):
    return tuple(slice(a, b) for a, b in j)


def _normalize(index, shape):
    if not index:
        index = tuple(slice(None) for _ in shape)
    out = []
    for sl, n in zip(index, shape):
        start = 0 if sl.start is None else sl.start
        stop = n if sl.stop is None else sl.stop
        out.append(slice(start, stop))
    return tuple(out)
