"""Import shim so benchmark modules run via ``python -m benchmarks.run``
with PYTHONPATH=src (keeps benchmarks/ importable without installing)."""
